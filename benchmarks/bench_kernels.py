"""Kernel microbenchmarks: Pallas (interpret on CPU — indicative only) vs
the jnp reference path; plus the blockwise flash vs naive attention, the
masked-tile skip fractions of the fused backward, and the shard_map'd
(mesh-dispatched) fwd+bwd path."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.distributed import ctx
from repro.kernels import dispatch, ref
from repro.kernels.flash_attention import masked_tile_fraction


def run() -> list:
    key = jax.random.key(0)
    rows = []
    b, s, hq, hkv, d = 1, 1024, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))

    ref_fn = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us_ref = common.timed(ref_fn, q, k, v, iters=3)
    rows.append({"name": "attention_ref_jnp", "us_per_call": us_ref,
                 "derived": f"s={s}"})
    from repro.models.flash_jnp import flash_attention_jnp
    fl_fn = jax.jit(lambda q, k, v: flash_attention_jnp(q, k, v, True,
                                                        None, 256))
    us_fl = common.timed(fl_fn, q, k, v, iters=3)
    rows.append({"name": "attention_flash_jnp", "us_per_call": us_fl,
                 "derived": f"vs_ref={us_ref/us_fl:.2f}x"})

    # fwd+bwd through the Pallas kernel's custom VJP (interpret on CPU) vs
    # AD through the blockwise-jnp path — the training hot-path comparison
    grad_pl = jax.jit(jax.grad(lambda q, k, v: jnp.sum(
        dispatch.flash_attention(q, k, v, causal=True, backend="pallas")),
        argnums=(0, 1, 2)))
    us_gpl = common.timed(grad_pl, q, k, v, iters=3)
    rows.append({"name": "attention_pallas_fwd_bwd", "us_per_call": us_gpl,
                 "derived": f"s={s} dq+dk+dv"})
    grad_jnp = jax.jit(jax.grad(lambda q, k, v: jnp.sum(
        flash_attention_jnp(q, k, v, True, None, 256)), argnums=(0, 1, 2)))
    us_gj = common.timed(grad_jnp, q, k, v, iters=3)
    rows.append({"name": "attention_flash_jnp_fwd_bwd", "us_per_call": us_gj,
                 "derived": f"vs_pallas={us_gpl/us_gj:.2f}x"})

    # masked-tile skip fractions: the share of (bq x bk) score tiles the
    # fused backward predicates away instead of computing zero tiles
    for name, win, blk in (("causal", None, 128), ("causal", None, 512),
                           ("window128", 128, 128)):
        frac = masked_tile_fraction(s, blk, blk, True, win)
        rows.append({"name": f"bwd_skipped_tiles_{name}_b{blk}",
                     "us_per_call": 0.0,
                     "derived": f"s={s} skipped={frac:.3f}"})

    # shard_map'd dispatch (mesh over local devices): fwd and fwd+bwd —
    # on a multi-device host this is the path backend="auto" picks under
    # a mesh; on one device it is the same kernels through a trivial mesh
    n_dev = len(jax.devices())
    if hkv % n_dev == 0:
        mesh_shape = (1, n_dev)      # heads over model
    elif b % n_dev == 0:
        mesh_shape = (n_dev, 1)      # batch over data
    else:
        mesh_shape = (1, 1)          # trivial mesh, same kernels
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    with ctx.use_mesh(mesh):
        sh_fwd = jax.jit(lambda q, k, v: dispatch.flash_attention(
            q, k, v, causal=True, backend="pallas_shard_map"))
        us_sf = common.timed(sh_fwd, q, k, v, iters=3)
        rows.append({"name": "attention_sharded_fwd", "us_per_call": us_sf,
                     "derived": f"mesh={dict(mesh.shape)}"})
        sh_grad = jax.jit(jax.grad(lambda q, k, v: jnp.sum(
            dispatch.flash_attention(q, k, v, causal=True,
                                     backend="pallas_shard_map")),
            argnums=(0, 1, 2)))
        us_sg = common.timed(sh_grad, q, k, v, iters=3)
        rows.append({"name": "attention_sharded_fwd_bwd",
                     "us_per_call": us_sg,
                     "derived": f"vs_single={us_gpl/us_sg:.2f}x"})

    # decode attention: serving tokens/sec for both cache layouts through
    # the dispatch layer (one fast path serves both — PR 3)
    L = 4096
    kc = jax.random.normal(ks[1], (b, L, hkv, d))
    vc = jax.random.normal(ks[2], (b, L, hkv, d))
    pos = jnp.asarray(L - 1)
    kpos = jnp.arange(L)
    qd = jax.random.normal(ks[0], (b, hq, d))
    dec_ref = jax.jit(lambda q, k, v, kp, p: ref.decode_attention_ref(
        q, k, v, kp, p))
    us_dref = common.timed(dec_ref, qd, kc, vc, kpos, pos, iters=3)
    rows.append({"name": "decode_ref_jnp", "us_per_call": us_dref,
                 "derived": f"L={L} tok_s={b * 1e6 / us_dref:.1f}"})

    # replicated-cache layout: shard_map over (batch, heads)
    with ctx.use_mesh(mesh):
        dec_sh = jax.jit(lambda q, k, v, kp, p: dispatch.decode_attention(
            q, k, v, kp, p, backend="pallas_shard_map"))
        us_dsh = common.timed(dec_sh, qd, kc, vc, kpos, pos, iters=3)
        rows.append({"name": "decode_sharded_bh", "us_per_call": us_dsh,
                     "derived": f"L={L} mesh={dict(mesh.shape)} "
                                f"tok_s={b * 1e6 / us_dsh:.1f}"})

    # context-parallel layout: seq-sharded cache, partials kernel + psum
    # combine (the pallas_cp arm the decode_cp rules resolve to)
    n_cp = mesh.shape["model"]
    cp_rules = {"decode_cp": {"mesh": mesh, "seq_axes": ("model",),
                              "dp_axes": ("data",), "n_shards": n_cp}}
    with ctx.sharding_rules(cp_rules):
        dec_cp = jax.jit(lambda q, k, v, kp, p: dispatch.decode_attention(
            q, k, v, kp, p))
        us_dcp = common.timed(dec_cp, qd, kc, vc, kpos, pos, iters=3)
        d = dispatch.last_decision("decode_attention")
        rows.append({"name": "decode_cp_seqshard", "us_per_call": us_dcp,
                     "derived": f"L={L} shards={n_cp} "
                                f"backend={d.backend if d else '?'} "
                                f"tok_s={b * 1e6 / us_dcp:.1f}"})

    # decode cache-dtype sweep through auto dispatch: measured tok/s next
    # to the analytic cache bytes each decoded token streams (the int8
    # win is the bytes column — off-TPU the jnp arm dequantizes up front,
    # so the wall-time ratio is indicative, the bytes ratio is the
    # roofline term).  int8 rows carry the f32 scale reads too.
    from repro.kernels import kv_quant
    hd = qd.shape[-1]
    for kvname in ("f32", "int8"):
        if kvname == "int8":
            k8, ksc = kv_quant.quantize(kc)
            v8, vsc = kv_quant.quantize(vc)
            fn = jax.jit(lambda q, k, v, kp, p, ks, vs:
                         dispatch.decode_attention(q, k, v, kp, p,
                                                   k_scale=ks, v_scale=vs))
            us_kv = common.timed(fn, qd, k8, v8, kpos, pos, ksc, vsc,
                                 iters=3)
            cache_b = 2 * b * L * hkv * (hd + 4)
        else:
            fn = jax.jit(lambda q, k, v, kp, p:
                         dispatch.decode_attention(q, k, v, kp, p))
            us_kv = common.timed(fn, qd, kc, vc, kpos, pos, iters=3)
            cache_b = 2 * b * L * hkv * hd * 4
        rows.append({"name": f"decode_kv_{kvname}", "us_per_call": us_kv,
                     "derived": f"L={L} tok_s={b * 1e6 / us_kv:.1f} "
                                f"cache_B_tok={cache_b}"})

    # fused rmsprop (jnp ref — the pallas path is interpret-mode on CPU)
    g = jnp.abs(jax.random.normal(ks[0], (1024, 1024)))
    dg = jax.random.normal(ks[1], (1024, 1024))
    rms_ref = jax.jit(lambda g, d: ref.rmsprop_update_ref(g, d, lr=1e-3))
    us_rms = common.timed(rms_ref, g, dg, iters=5)
    rows.append({"name": "rmsprop_ref_jnp", "us_per_call": us_rms,
                 "derived": "1M params"})
    common.save_rows("kernels_micro", rows)
    return rows
