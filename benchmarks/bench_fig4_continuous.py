"""Fig. 3/4 analogue: continuous-action A3C (Gaussian heads) on the MuJoCo-
proxy domains (pointmass2d, pendulum)."""
from __future__ import annotations

from benchmarks import common


def run(frames: int = 30_000, envs=("pointmass", "pendulum")) -> list:
    rows = []
    for env_name in envs:
        # tuned: lr 1e-3, differential-entropy coefficient 1e-2 (the
        # paper's 1e-4 under-explores at our tiny frame budgets)
        env, st, round_fn, cfg = common.make_rl_runner(
            "a3c", env_name, workers=8, lr=1e-3, hidden=128)
        st, hist = common.run_frames(st, round_fn, cfg, frames,
                                     trace_every=100)
        rows.append({"bench": "fig4", "env": env_name, "frames": frames,
                     "final_ep_ret": round(hist[-1][1], 3),
                     "curve": hist[-8:]})
    common.save_rows("fig4_continuous", rows)
    return rows
