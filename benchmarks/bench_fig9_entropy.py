"""Fig. 9 analogue: effect of the entropy-regularization coefficient."""
from __future__ import annotations

import numpy as np

from benchmarks import common


def run(n_trials: int = 4, frames: int = 25_000) -> list:
    rng = np.random.RandomState(1)
    lrs = np.exp(rng.uniform(np.log(3e-3), np.log(3e-2), n_trials))
    rows = []
    for beta in (0.0, 0.01):
        for t in range(n_trials):
            env, st, round_fn, cfg = common.make_rl_runner(
                "a3c", "gridmaze", workers=8, lr=float(lrs[t]), seed=t,
                beta=beta)
            st, hist = common.run_frames(st, round_fn, cfg, frames)
            rows.append({"bench": "fig9", "beta": beta,
                         "lr": round(float(lrs[t]), 5),
                         "final_ep_ret": round(hist[-1][1], 3)})
    common.save_rows("fig9_entropy", rows)
    return rows
