"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the scaffold contract; rich
records land in benchmarks/results/*.json.  Budgets here are CPU-smoke
sized; pass --full for paper-scale budgets (hours).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale budgets (hours on 1 CPU)")
    ap.add_argument("--quick", action="store_true",
                    help="CI perf-trajectory leg: the prefill and serve "
                    "benches, writing the root-level BENCH_prefill.json "
                    "and BENCH_serve.json artifacts")
    ap.add_argument("--chaos", action="store_true",
                    help="CI chaos-smoke leg: the serve overload bench "
                    "only (undersized page pool + fault injection); any "
                    "shed, crash, or greedy-token divergence raises")
    ap.add_argument("--spec", action="store_true",
                    help="CI speculative-decode smoke leg: the serve "
                    "spec bench only (off vs n-gram vs draft-model on "
                    "the probed high-acceptance trace); any greedy "
                    "divergence or a tok/s ratio <= 1.5x raises")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args = ap.parse_args()
    mult = 8 if args.full else 1

    from benchmarks import (bench_fig1_learning, bench_fig4_continuous,
                            bench_fig8_optimizers, bench_fig9_entropy,
                            bench_fig10_lr_robustness, bench_kernels,
                            bench_llm_train, bench_prefill,
                            bench_replay_ablation, bench_roofline,
                            bench_serve, bench_stability,
                            bench_table1_scores, bench_table2_scaling)

    benches = {
        "kernels": lambda: bench_kernels.run(),
        "serve": lambda: bench_serve.run(),
        "prefill": lambda: bench_prefill.run(),
        "llm_train": lambda: bench_llm_train.run(),
        "fig1": lambda: bench_fig1_learning.run(frames=120_000 * mult),
        "table1": lambda: bench_table1_scores.run(frames=100_000 * mult),
        "table2": lambda: bench_table2_scaling.run(
            max_frames=150_000 * mult),
        "fig8": lambda: bench_fig8_optimizers.run(
            n_trials=6 if not args.full else 18, frames=30_000 * mult),
        "fig9": lambda: bench_fig9_entropy.run(frames=60_000 * mult),
        "fig10": lambda: bench_fig10_lr_robustness.run(
            frames=60_000 * mult),
        "fig4": lambda: bench_fig4_continuous.run(frames=80_000 * mult),
        "replay": lambda: bench_replay_ablation.run(frames=40_000 * mult),
        "stability": lambda: bench_stability.run(frames=40_000 * mult),
        "roofline": lambda: bench_roofline.run(),
        "chaos": lambda: bench_serve.run_chaos(),
        "spec": lambda: bench_serve.run_spec(),
    }
    if args.chaos:
        only = ["chaos"]
    elif args.spec:
        only = ["spec"]
    elif args.quick:
        only = ["prefill", "serve"]
        # one-line invariant status next to the perf rows: the cheap
        # repro-audit families (AST lints + dispatch contracts), so a
        # perf run that rode on a contract violation is visible in the
        # same log (the full suite runs as its own CI job)
        import os
        sys.path.insert(0, os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        try:
            from tools.audit import quick_summary
            print(quick_summary(), flush=True)
        except Exception as e:       # never let the audit sink the bench
            print(f"audit,error,{e!r}", flush=True)
    else:
        only = args.only.split(",") if args.only else list(benches)
    print("name,us_per_call,derived")
    for name in only:
        t0 = time.time()
        rows = benches[name]()
        wall = time.time() - t0
        for r in rows:
            if "us_per_call" in r:
                print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
        n = len(rows)
        print(f"bench_{name},{1e6 * wall / max(n,1):.0f},rows={n}",
              flush=True)


if __name__ == "__main__":
    main()
