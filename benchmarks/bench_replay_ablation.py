"""Beyond-paper ablation (paper Conclusions §6): does mixing experience
replay into the asynchronous framework improve data efficiency of the
value-based methods?  Compares async n-step Q with replay_weight in
{0.0 (paper-faithful), 0.5, 1.0} at equal frame budgets."""
from __future__ import annotations

import jax

from benchmarks import common
from repro.core import agents, replay_async
from repro.envs import make
from repro.envs.api import flatten_obs
from repro.models import atari as nets


def run(frames: int = 30_000, weights=(0.0, 0.5, 1.0)) -> list:
    env = flatten_obs(make("catch"))
    rows = []
    for w in weights:
        algo = agents.ALGORITHMS["n_step_q"]()
        params = nets.init_mlp_agent_params(
            jax.random.key(0), env.obs_shape[0], env.n_actions, hidden=64)
        cfg = replay_async.ReplayAsyncConfig(
            n_workers=8, t_max=5, lr0=1e-2, replay_weight=w)
        init_state, round_fn = replay_async.make_replay_runner(
            algo, env, params, cfg)
        st = init_state(jax.random.key(1))
        ema = None
        rounds = frames // (cfg.n_workers * cfg.t_max)
        for _ in range(rounds):
            st, m = round_fn(st)
            r = float(m["ep_ret"])
            ema = r if ema is None else 0.98 * ema + 0.02 * r
        rows.append({"bench": "replay_ablation", "replay_weight": w,
                     "frames": frames, "final_ep_ret": round(ema, 3)})
    common.save_rows("replay_ablation", rows)
    return rows
