"""Fig. 8 analogue: robustness of Momentum SGD vs RMSProp vs Shared RMSProp
across learning rates and initializations (sorted final-score curves)."""
from __future__ import annotations

import numpy as np

from benchmarks import common

SETUPS = [
    ("momentum_sgd", False),
    ("rmsprop", False),       # per-worker statistics
    ("shared_rmsprop", True),
]


def run(n_trials: int = 6, frames: int = 25_000, algo: str = "a3c") -> list:
    rng = np.random.RandomState(0)
    lrs = np.exp(rng.uniform(np.log(1e-3), np.log(1e-1), n_trials))
    rows = []
    for opt, shared in SETUPS:
        finals = []
        for t in range(n_trials):
            env, st, round_fn, cfg = common.make_rl_runner(
                algo, "catch", workers=8, lr=float(lrs[t]), seed=t,
                optimizer=opt, shared_stats=shared)
            st, hist = common.run_frames(st, round_fn, cfg, frames)
            finals.append(hist[-1][1])
        finals.sort(reverse=True)
        rows.append({
            "bench": "fig8", "optimizer": opt, "shared_stats": shared,
            "sorted_final_scores": [round(f, 3) for f in finals],
            "mean": round(float(np.mean(finals)), 3),
            "area_under_curve": round(float(np.sum(finals)), 3),
        })
    common.save_rows("fig8_optimizers", rows)
    return rows
