"""Fig. 10 analogue: final-score scatter over learning rates for all four
methods (robustness / stability: no collapse in the good-lr band)."""
from __future__ import annotations

import numpy as np

from benchmarks import common

ALGOS = ["a3c", "n_step_q", "one_step_q", "one_step_sarsa"]


def run(n_lrs: int = 5, frames: int = 20_000) -> list:
    rng = np.random.RandomState(2)
    lrs = np.exp(rng.uniform(np.log(1e-3), np.log(3e-2), n_lrs))
    rows = []
    for algo in ALGOS:
        for lr in lrs:
            env, st, round_fn, cfg = common.make_rl_runner(
                algo, "catch", workers=8, lr=float(lr))
            st, hist = common.run_frames(st, round_fn, cfg, frames)
            rows.append({"bench": "fig10", "algo": algo,
                         "lr": round(float(lr), 5),
                         "final_ep_ret": round(hist[-1][1], 3)})
    common.save_rows("fig10_lr", rows)
    return rows
