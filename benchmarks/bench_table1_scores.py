"""Table 1 analogue: A3C vs the DQN-with-replay baseline at equal frame
budgets (the "parallel actors replace experience replay" headline claim)."""
from __future__ import annotations

import time

import jax

from benchmarks import common


def run_dqn(env_name: str, frames: int, seed: int = 0) -> float:
    from repro.core import dqn_replay
    from repro.envs import make
    from repro.envs.api import flatten_obs
    from repro.models import atari as nets

    env = make(env_name)
    if len(env.obs_shape) > 1:
        env = flatten_obs(env)
    params = nets.init_mlp_agent_params(jax.random.key(seed),
                                        env.obs_shape[0], env.n_actions,
                                        hidden=64)
    cfg = dqn_replay.DQNConfig(buffer_size=5_000, batch_size=32, lr=1e-3,
                               warmup=500, train_every=4,
                               target_interval=1_000)
    init_state, step_fn = dqn_replay.make_dqn(env, params, cfg)
    st = init_state(jax.random.key(seed + 1))
    ema = None
    for _ in range(frames):
        st = step_fn(st)
        r = float(st["last_ep_ret"])
        ema = r if ema is None else 0.999 * ema + 0.001 * r
    return ema


def run(frames: int = 30_000, envs=("catch",)) -> list:
    rows = []
    for env_name in envs:
        t0 = time.time()
        env, st, round_fn, cfg = common.make_rl_runner(
            "a3c", env_name, workers=8, lr=1e-2)
        st, hist = common.run_frames(st, round_fn, cfg, frames)
        rows.append({"bench": "table1", "env": env_name, "method": "a3c",
                     "frames": frames, "score": round(hist[-1][1], 3),
                     "wall_s": round(time.time() - t0, 1)})
        t0 = time.time()
        score = run_dqn(env_name, frames)
        rows.append({"bench": "table1", "env": env_name,
                     "method": "dqn_replay", "frames": frames,
                     "score": round(score, 3),
                     "wall_s": round(time.time() - t0, 1)})
    common.save_rows("table1_scores", rows)
    return rows
