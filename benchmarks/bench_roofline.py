"""Roofline table from the dry-run records (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import json
import os

from benchmarks import common


def load(path=None) -> list:
    path = path or os.path.join(common.RESULTS_DIR, "dryrun.jsonl")
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def table(recs) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'dominant':>10s} {'useful':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:24s} {r['shape']:12s} "
                         f"{r.get('status'):>9s} ({r.get('reason', '')[:40]})")
            continue
        t = r["roofline"]
        ur = r.get("useful_flops_ratio")
        lines.append(
            f"{r['variant']:24s} {r['shape']:12s} "
            f"{t['t_compute']:9.2e} {t['t_memory']:9.2e} "
            f"{t['t_collective']:9.2e} {t['dominant']:>10s} "
            f"{(f'{ur:7.2f}' if ur else '    n/a')}")
    return "\n".join(lines)


def run() -> list:
    recs = load()
    print(table(recs))
    return recs
