"""Chunked-prefill benchmark: fused append path vs the masked-sdpa prefix
baseline (the PR-4 path this PR deletes).

Three legs, all landing in a root-level ``BENCH_prefill.json`` (uploaded
as a CI artifact — the start of the per-PR prefill perf trajectory):

  * **measured** — multi-chunk prefill tokens/s through the real engine
    path (``llm_a3c.make_prefill_step``) at prompt 512 / 2048, against a
    faithful in-bench reconstruction of the masked-sdpa prefix branch.
    On TPU the fused number rides the append kernel; off-TPU auto
    dispatch (correctly) serves the jnp append oracle, so the measured
    CPU ratio reflects the oracle, not the kernel — interpret-mode
    kernel timings are emulation-only (see bench_kernels.py).
  * **analytic_hbm** — the attention term's HBM bytes from the traffic
    model (``traffic.prefill_attn_bytes``): the masked path materializes
    f32 (C, Sk) scores + Hq-repeated K/V streams every chunk, the fused
    kernel keeps score tiles in VMEM — the ratio that governs the TPU
    roofline.
  * **serve_demo** — a 3-chunk prompt-2048 serve run on a 2-device host
    mesh (subprocess with forced host devices): the dispatch decision log
    must show every chunk on a pallas append arm.

  PYTHONPATH=src python -m benchmarks.run --quick
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Optional

import jax
import jax.numpy as jnp

from benchmarks import common

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(ROOT, "BENCH_prefill.json")


# ---------------------------------------------------------------------------
# masked-sdpa baseline: faithful reconstruction of the pre-append
# attend_prefill (PR 4) — chunk 0 through the flash path, later chunks
# over the cache prefix via concat + repeat_kv + masked dense sdpa
# ---------------------------------------------------------------------------

def _attend_prefill_masked(params, x, cache, pos0, cfg, *, window=None,
                           use_rope=True, backend="auto", true_len=None):
    from repro.kernels import dispatch
    from repro.models import attention as attn
    from repro.models import common as cm

    n_h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b, c, _ = x.shape
    q = attn._split_heads(cm.linear(params["wq"], x), n_h, hd)
    k = attn._split_heads(cm.linear(params["wk"], x), n_kv, hd)
    v = attn._split_heads(cm.linear(params["wv"], x), n_kv, hd)
    if use_rope:
        positions = pos0 + jnp.arange(c)[None]
        cos, sin = cm.rope_cos_sin(positions, hd, cfg.rope_theta)
        rd = getattr(cfg, "rotary_dim", None)
        q = cm.apply_rope(q, cos, sin, rotary_dim=rd)
        k = cm.apply_rope(k, cos, sin, rotary_dim=rd)
    cache_len = cache["k"].shape[1]
    ck = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, pos0, 0, 0))
    cv = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, pos0, 0, 0))
    new_cache = {"k": ck, "v": cv,
                 "index": jnp.asarray(pos0 + c, jnp.int32)}
    if pos0 == 0:
        o = dispatch.flash_attention(q, k, v, causal=True, window=window,
                                     backend=backend)
    else:
        k_pre = cache["k"][:, :min(pos0, cache_len)].astype(q.dtype)
        v_pre = cache["v"][:, :min(pos0, cache_len)].astype(q.dtype)
        k_all = jnp.concatenate([k_pre, k], axis=1)
        v_all = jnp.concatenate([v_pre, v], axis=1)
        kpos_all = jnp.concatenate([jnp.arange(k_pre.shape[1]),
                                    pos0 + jnp.arange(c)])
        qpos = pos0 + jnp.arange(c)
        mask = (kpos_all[None, :] >= 0) & \
            (kpos_all[None, :] <= qpos[:, None])
        n_rep = n_h // n_kv
        o = attn.sdpa(q, attn._repeat_kv(k_all, n_rep),
                      attn._repeat_kv(v_all, n_rep), mask[None, None])
    return cm.linear(params["wo"], o.reshape(b, c, n_h * hd)), new_cache


def _prefill_tok_s(cfg, params, prompt_len: int, chunk: int,
                   masked: bool) -> float:
    """Wall tok/s for one full multi-chunk prefill chain (B=1)."""
    from repro.core import llm_a3c
    from repro.models import attention as attn
    from repro.models import model as M

    cache_len = prompt_len + 128
    prompt = jax.random.randint(jax.random.key(1), (1, prompt_len), 0,
                                cfg.vocab_size)
    orig = attn.attend_prefill
    if masked:
        attn.attend_prefill = _attend_prefill_masked
    try:
        step = llm_a3c.make_prefill_step(cfg)

        def chain():
            cache = M.init_cache(cfg, 1, cache_len, dtype=jnp.float32)
            for p0 in range(0, prompt_len, chunk):
                logits, cache = step(params, cache,
                                     {"tokens": prompt[:, p0:p0 + chunk]},
                                     pos0=p0)
            return logits

        us = common.timed(chain, iters=3)
    finally:
        attn.attend_prefill = orig
    return prompt_len * 1e6 / us


def _serve_demo(timeout_s: int = 420) -> Optional[dict]:
    """3-chunk prompt-2048 serve run on a forced 2-device host mesh; the
    returned record carries the dispatch decision summary."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2").strip()
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--arch", "stablelm-1.6b", "--slots", "1", "--requests", "1",
           "--prompt-range", "2048,2048", "--gen-range", "2,2",
           "--cache-len", "2304", "--chunk", "768", "--greedy",
           "--decode-cp"]
    try:
        out = subprocess.run(cmd, capture_output=True, text=True,
                             timeout=timeout_s, env=env, cwd=ROOT)
        rec = json.loads(out.stdout.strip().splitlines()[-1])
    except Exception as e:  # noqa: BLE001 — demo leg degrades, not fails
        return {"error": f"{type(e).__name__}: {e}"}
    append_rows = [r for r in rec.get("kernel_dispatch", [])
                   if r["op"] == "flash_append"]
    n_chunks = 3
    fused = sum(r["count"] for r in append_rows
                if r["backend"].startswith("pallas"))
    return {
        "prompt": 2048, "chunk": 768, "n_chunks": n_chunks,
        "decode_layout": rec.get("decode_layout"),
        "kernel_dispatch": rec.get("kernel_dispatch"),
        "append_chunks_on_pallas": fused >= n_chunks,
    }


def run(*, arch: str = "stablelm-1.6b", demo: bool = True) -> list:
    from repro.configs import get_config
    from repro.launch import traffic
    from repro.models import model as M

    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    chunk = 128                          # the engine's default grid
    rows = [{"name": "prefill_meta", "us_per_call": 0.0,
             "derived": f"arch={cfg.name} backend={jax.default_backend()}"}]
    measured, analytic = [], []
    for prompt_len in (512, 2048):
        tok_m = _prefill_tok_s(cfg, params, prompt_len, chunk, masked=True)
        tok_f = _prefill_tok_s(cfg, params, prompt_len, chunk,
                               masked=False)
        measured.append({
            "prompt": prompt_len, "chunk": chunk,
            "masked_sdpa_tok_s": round(tok_m, 1),
            "fused_append_tok_s": round(tok_f, 1),
            "ratio": round(tok_f / tok_m, 3),
        })
        bm = traffic.prefill_attn_bytes(cfg, 1, prompt_len, chunk,
                                        fused=False)
        bf = traffic.prefill_attn_bytes(cfg, 1, prompt_len, chunk,
                                        fused=True)
        analytic.append({
            "prompt": prompt_len, "chunk": chunk,
            "masked_sdpa_attn_bytes": bm, "fused_append_attn_bytes": bf,
            "ratio": round(bm / bf, 2),
        })
        rows.append({
            "name": f"prefill_masked_sdpa_p{prompt_len}",
            "us_per_call": prompt_len * 1e6 / tok_m,
            "derived": f"tok_s={tok_m:.1f}"})
        rows.append({
            "name": f"prefill_fused_append_p{prompt_len}",
            "us_per_call": prompt_len * 1e6 / tok_f,
            "derived": f"tok_s={tok_f:.1f} vs_masked={tok_f / tok_m:.2f}x "
                       f"hbm_ratio={bm / bf:.1f}x"})

    demo_rec = _serve_demo() if demo else None
    if demo_rec is not None:
        rows.append({
            "name": "prefill_serve_demo_2048x3",
            "us_per_call": 0.0,
            "derived": "append_chunks_on_pallas="
                       f"{demo_rec.get('append_chunks_on_pallas')}"})

    record = {
        "arch": cfg.name,
        "platform": jax.default_backend(),
        "provenance": common.provenance(),
        "note": ("fused_append numbers ride the Pallas append kernel on "
                 "TPU; off-TPU auto dispatch serves the jnp append "
                 "oracle (Pallas runs interpret-only there), so the "
                 "measured off-TPU ratio is oracle-vs-masked — the "
                 "analytic_hbm ratio is the kernel's roofline term"),
        "measured": measured,
        "analytic_hbm": analytic,
        "serve_demo": demo_rec,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=1)
        f.write("\n")
    common.save_rows("prefill_append", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        common.emit(r["name"], r["us_per_call"], r["derived"])
