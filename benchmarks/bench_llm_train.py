"""Reduced-architecture train/serve step timings on CPU (per-step us and
derived tokens/s) — one row per assigned architecture family."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common

ARCHS = ["stablelm-1.6b", "granite-moe-1b-a400m", "zamba2-1.2b",
         "xlstm-1.3b", "whisper-base", "qwen2-vl-72b"]


def run(seq: int = 64, batch: int = 4) -> list:
    from repro.configs import get_config
    from repro.core import llm_a3c
    from repro.data.pipeline import TokenPipeline
    from repro.models import model as M
    from repro.optim import optimizers as opt_mod

    rows = []
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        params = M.init_params(cfg, jax.random.key(0))
        opt = opt_mod.shared_rmsprop()
        opt_state = opt.init(params)
        pipe = TokenPipeline(vocab=cfg.vocab_size, seq_len=seq,
                             global_batch=batch)
        batch_data = pipe.batch(jax.random.key(1))
        if cfg.family == "vlm":
            batch_data["embeds"] = jnp.zeros((batch, seq, cfg.d_model))
            batch_data["positions"] = jnp.broadcast_to(
                jnp.arange(seq)[None, None], (3, batch, seq)).astype(
                jnp.int32)
            batch_data["actions"] = batch_data.pop("tokens")
        if cfg.is_encdec:
            batch_data["enc_frames"] = jnp.zeros(
                (batch, cfg.encoder_seq, cfg.d_model))
        step = jax.jit(llm_a3c.make_train_step(cfg, opt))

        def call(p, o, b):
            return step(p, o, b, jnp.asarray(0))

        us = common.timed(call, params, opt_state, batch_data, iters=3)
        rows.append({"name": f"train_step_{arch}", "us_per_call": us,
                     "derived": f"tok/s={1e6 * seq * batch / us:.0f}"})
    common.save_rows("llm_train_micro", rows)
    return rows
