"""Serve-engine benchmark: chunked flash prefill vs the token-by-token
loop, and continuous batching vs lockstep waves under mixed-length traffic.

Emits ``benchmarks/results/serve_engine.json`` (next to
``kernels_micro.json``) with tokens/s and latency percentiles, plus the
root ``BENCH_serve.json`` CI artifact (tokens/s, TTFT/latency
percentiles, page occupancy, prefix dedup ratio) — the numbers backing
the serve-engine acceptance criteria:

  * chunked prefill >= 5x faster than the single-token loop at
    prompt_len 128;
  * the continuous-batching engine sustains higher aggregate tokens/s
    than lockstep wave batching on the same mixed-length trace;
  * the paged KV cache dedups a shared-prefix trace (> 1.5x page dedup,
    skipped prefill chunks) with tokens identical to no-sharing, and
    the capacity model sustains >= 4x the slot count on the contiguous
    layout's HBM budget.

  PYTHONPATH=src python -m benchmarks.run --only serve
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(ROOT, "BENCH_serve.json")


def bench_prefill(cfg, params, *, prompt_len: int, chunk: int) -> tuple:
    """Token-by-token loop vs chunked flash prefill for one prompt."""
    from repro.core import llm_a3c
    from repro.launch import traffic
    from repro.models import model as M

    rows = []
    cache_len = prompt_len + 16
    prompt = jax.random.randint(jax.random.key(1), (1, prompt_len), 0,
                                cfg.vocab_size)
    serve_step = jax.jit(llm_a3c.make_serve_step(cfg, sample=False))
    key = jax.random.key(0)

    def loop_prefill():
        cache = M.init_cache(cfg, 1, cache_len, dtype=jnp.float32)
        for i in range(prompt_len):
            tok, _, cache = serve_step(params, cache,
                                       {"tokens": prompt[:, i:i + 1]},
                                       jnp.asarray(i), key)
        return tok

    prefill_step = llm_a3c.make_prefill_step(cfg)

    def chunked_prefill():
        cache = M.init_cache(cfg, 1, cache_len, dtype=jnp.float32)
        for p0 in range(0, prompt_len, chunk):
            logits, cache = prefill_step(
                params, cache, {"tokens": prompt[:, p0:p0 + chunk]},
                pos0=p0)
        return logits

    us_loop = common.timed(loop_prefill, iters=3)
    us_chunk = common.timed(chunked_prefill, iters=3)
    speedup = us_loop / us_chunk
    rows.append({"name": "prefill_token_loop", "us_per_call": us_loop,
                 "derived": f"prompt={prompt_len} "
                            f"tok_s={prompt_len * 1e6 / us_loop:.1f}"})
    rows.append({"name": "prefill_chunked_flash", "us_per_call": us_chunk,
                 "derived": f"prompt={prompt_len} chunk={chunk} "
                            f"tok_s={prompt_len * 1e6 / us_chunk:.1f} "
                            f"speedup={speedup:.1f}x"})
    rows.append({"name": "prefill_chunk_hbm_model",
                 "us_per_call": 0.0,
                 "derived": "analytic bytes loop(C=1)="
                 f"{traffic.prefill_chunk_bytes(cfg, 1, prompt_len, 1):.3e}"
                 " chunked="
                 f"{traffic.prefill_chunk_bytes(cfg, 1, prompt_len, chunk):.3e}"})
    return rows, speedup


def bench_engine_vs_lockstep(cfg, params, *, n_slots: int, n_requests: int,
                             seed: int, reps: int = 3) -> list:
    """Same mixed-length trace through both batching disciplines.

    Paired design: each rep runs engine then lockstep back-to-back on an
    identical trace and the ratio is taken per rep (shared-machine noise
    on this box swings absolute wall time far more than the structural
    margin, but hits a back-to-back pair roughly equally); the reported
    records come from the median-ratio rep.  Occupancy — the
    deterministic slot-efficiency metric — is identical across reps."""
    from repro.launch import serve as serve_mod

    # wide generation-length dispersion is the regime continuous batching
    # exists for: lockstep burns a slot-step per finished-but-waiting row
    # until the wave's slowest request drains
    def one_rep():
        recs = {}
        for mode, runner in (("engine", serve_mod.run_engine),
                             ("lockstep", serve_mod.run_lockstep)):
            trace = serve_mod.gen_trace(
                n_requests, vocab=cfg.vocab_size, prompt_range=(16, 64),
                gen_range=(4, 64), arrival_rate=0.0, seed=seed)
            recs[mode] = runner(cfg, params, trace, n_slots=n_slots,
                                cache_len=128, chunk=64, sample=True,
                                seed=seed)
        return recs

    all_recs = [one_rep() for _ in range(reps)]
    ratios = [r["engine"]["tokens_per_s"] /
              max(r["lockstep"]["tokens_per_s"], 1e-9) for r in all_recs]
    median = sorted(ratios)[len(ratios) // 2]
    recs = all_recs[ratios.index(median)]

    rows = []
    for mode in ("engine", "lockstep"):
        rec = recs[mode]
        rows.append({
            "name": f"serve_{mode}_mixed",
            "us_per_call": rec["wall_s"] * 1e6,
            "derived": f"tok_s={rec['tokens_per_s']} "
                       f"occupancy={rec['occupancy']} "
                       f"p50={rec['latency_s'].get('p50')} "
                       f"p99={rec['latency_s'].get('p99')}",
            "tokens_per_s": rec["tokens_per_s"],
            "latency_s": rec["latency_s"],
            "ttft_s": rec["ttft_s"],
            "occupancy": rec["occupancy"],
            "warmup_s": rec["warmup_s"],
        })
    rows.append({"name": "engine_vs_lockstep", "us_per_call": 0.0,
                 "derived": f"aggregate_tok_s_ratio={median:.2f}x "
                            f"(per-rep {[round(r, 2) for r in ratios]})"})
    return rows


def shared_prefix_trace(vocab: int, *, shared_len: int, n_requests: int,
                        seed: int) -> list:
    """Mixed trace built for prefix reuse: every prompt opens with the
    same ``shared_len`` tokens; two requests are exact duplicates (their
    shared partial page forks via copy-on-write at first decode write);
    generation lengths are staggered so early finishers free slots while
    the shared pages are still referenced by live requests — the regime
    cross-admission prefix hits need."""
    from repro.launch import serve as serve_mod

    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, shared_len).astype(np.int32)
    dup_tail = rng.integers(0, vocab, 9).astype(np.int32)
    trace = []
    for rid in range(n_requests):
        if rid in (1, 2):
            prompt = np.concatenate([shared, dup_tail])
        else:
            tail = rng.integers(0, vocab,
                                1 + (rid % 4) * 7).astype(np.int32)
            prompt = np.concatenate([shared, tail])
        trace.append(serve_mod.Request(
            rid=rid, prompt=prompt, max_new=2 + (rid % 3) * 8,
            arrival=0.0))
    return trace


def bench_paged_sharing(cfg, params, *, n_slots: int, n_requests: int,
                        seed: int) -> tuple:
    """Shared-prefix trace through the paged engine with the prefix cache
    on and off: identical tokens, fewer prefill chunks and fewer live
    pages with sharing on.  Returns (rows, record) — the record feeds the
    root BENCH_serve.json artifact."""
    from repro.kernels import dispatch
    from repro.launch import serve as serve_mod
    from repro.launch import traffic

    recs, toks, ops = {}, {}, {}
    for mode, pc in (("share", True), ("noshare", False)):
        trace = shared_prefix_trace(cfg.vocab_size, shared_len=192,
                                    n_requests=n_requests, seed=seed)
        dispatch.clear_decision_log()
        recs[mode] = serve_mod.run_engine(
            cfg, params, trace, n_slots=n_slots, cache_len=256, chunk=64,
            sample=False, seed=seed, prefix_cache=pc)
        toks[mode] = {r.rid: list(r.tokens) for r in trace}
        ops[mode] = sorted({d.op for d in dispatch.decision_log()
                            if d.op in ("append_paged", "decode_paged")})

    rows = []
    for mode in ("share", "noshare"):
        rec = recs[mode]
        rows.append({
            "name": f"serve_paged_{mode}",
            "us_per_call": rec["wall_s"] * 1e6,
            "derived": f"tok_s={rec['tokens_per_s']} "
                       f"dedup={rec['dedup_ratio']} "
                       f"chunks_skipped={rec['prefill_chunks_skipped']} "
                       f"cow={rec['cow_events']} "
                       f"pages={rec['pages_alloced']}/"
                       f"{rec['pages_requested']} "
                       f"paged_ops={ops[mode]}",
        })
    cap = traffic.paged_capacity(
        cfg, n_slots=n_slots, cache_len=1024, page_size=128,
        resident_tokens_per_req=256, shared_tokens=128)
    rows.append({
        "name": "paged_capacity_model", "us_per_call": 0.0,
        "derived": f"slots {cap['slots_contiguous']} -> "
                   f"{cap['slots_paged']} "
                   f"(ratio={cap['slot_ratio']:.2f}x) on the same "
                   f"{cap['budget_bytes']:.3e} B budget, "
                   f"model_dedup={cap['dedup_ratio_model']:.2f}"})

    share = recs["share"]
    record = {
        "arch": cfg.name,
        "backend": jax.default_backend(),
        "n_slots": n_slots,
        "n_requests": n_requests,
        "tokens_per_s": share["tokens_per_s"],
        "ttft_s": share["ttft_s"],
        "latency_s": share["latency_s"],
        "occupancy": share["occupancy"],
        "page_occupancy": share.get("page_occupancy"),
        "page_size": share.get("page_size"),
        "dedup_ratio": share["dedup_ratio"],
        "cow_events": share["cow_events"],
        "prefill_chunks_skipped": share["prefill_chunks_skipped"],
        "noshare_chunks_skipped": recs["noshare"]["prefill_chunks_skipped"],
        "noshare_pages_alloced": recs["noshare"]["pages_alloced"],
        "tokens_identical_vs_noshare": toks["share"] == toks["noshare"],
        "kernel_dispatch": ops["share"],
        "capacity_model": cap,
    }
    return rows, record


def bench_kv_dtypes(cfg, params, *, n_slots: int, n_requests: int,
                    seed: int) -> tuple:
    """The same greedy paged trace with an f32 and an int8 KV cache, side
    by side: measured tok/s next to the analytic decode bytes/token, and
    the capacity model's slot count per dtype on the identical bf16
    contiguous HBM budget.  Fresh trace objects per run (Request.tokens
    accumulates in place across runs).  Returns (rows, record) — the
    record lands in BENCH_serve.json as the ``kv_dtype`` section."""
    from repro.launch import serve as serve_mod
    from repro.launch import traffic

    rows, recs, first = [], {}, {}
    for kv in ("f32", "int8"):
        trace = serve_mod.gen_trace(
            n_requests, vocab=cfg.vocab_size, prompt_range=(16, 64),
            gen_range=(4, 32), arrival_rate=0.0, seed=seed)
        recs[kv] = serve_mod.run_engine(
            cfg, params, trace, n_slots=n_slots, cache_len=256, chunk=64,
            sample=False, seed=seed, prefix_cache=True, kv_dtype=kv)
        first[kv] = [int(r.tokens[0]) for r in trace if r.tokens]
    match = float(np.mean([a == b for a, b in
                           zip(first["f32"], first["int8"])]))
    caps = {kv: traffic.paged_capacity(
        cfg, n_slots=n_slots, cache_len=1024, page_size=128,
        resident_tokens_per_req=256, shared_tokens=128, kv_dtype=kv)
        for kv in ("f32", "bf16", "int8")}
    dtype_rows = []
    for kv in ("f32", "int8"):
        rec = recs[kv]
        # contiguous-equivalent analytic stream (params + cache incl.
        # scales) — the roofline denominator next to the measured rate
        bpt = traffic.decode_bytes_per_token(cfg, n_slots, 256,
                                             kv_dtype=kv)
        dtype_rows.append({
            "kv_dtype": kv,
            "tokens_per_s": rec["tokens_per_s"],
            "decode_bytes_per_token": bpt,
            "slots_on_same_budget": caps[kv]["slots_paged"],
        })
        rows.append({
            "name": f"serve_kv_{kv}",
            "us_per_call": rec["wall_s"] * 1e6,
            "derived": f"tok_s={rec['tokens_per_s']} "
                       f"decode_B_tok={bpt:.3e} "
                       f"slots_on_same_budget={caps[kv]['slots_paged']}"})
    ratio = caps["int8"]["slots_paged"] / max(caps["f32"]["slots_paged"], 1)
    rows.append({
        "name": "kv_dtype_capacity", "us_per_call": 0.0,
        "derived": f"slots f32={caps['f32']['slots_paged']} "
                   f"bf16={caps['bf16']['slots_paged']} "
                   f"int8={caps['int8']['slots_paged']} "
                   f"(int8/f32={ratio:.2f}x) "
                   f"first_tok_match={match:.2f}"})
    record = {
        "rows": dtype_rows,
        "first_token_match_int8_vs_f32": match,
        "int8_vs_f32_slot_ratio": ratio,
        "capacity_model_per_dtype": {k: caps[k] for k in caps},
    }
    return rows, record


def overload_trace(vocab: int, *, page_size: int, n_requests: int,
                   seed: int) -> list:
    """Shared-prefix trace engineered for page pressure: every prompt
    opens with one full shared page, tails differ (rids 1,2 are exact
    duplicates, so their shared partial page COW-forks at first decode
    write), and every generation runs long enough to cross into a third
    page — decode-time growth is guaranteed, so an undersized pool must
    preempt."""
    from repro.launch import serve as serve_mod

    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, page_size).astype(np.int32)
    dup_tail = rng.integers(0, vocab, 11).astype(np.int32)
    trace = []
    for rid in range(n_requests):
        tail = dup_tail if rid in (1, 2) else rng.integers(
            0, vocab, 8 + (rid % 4) * 7).astype(np.int32)
        prompt = np.concatenate([shared, tail])
        # crosses pos 2*page_size mid-decode: prompt < 1.5 pages and
        # max_new == page_size lands the tail firmly in page 3
        trace.append(serve_mod.Request(
            rid=rid, prompt=prompt, max_new=page_size - (rid % 3) * 4,
            arrival=0.0))
    return trace


def bench_overload(cfg, params, *, n_slots: int = 4, n_requests: int = 6,
                   seed: int = 0) -> tuple:
    """The robustness acceptance gate: the same greedy shared-prefix
    trace on (a) an ample pool, (b) a pool at 50% of the slots'
    worst-case demand under optimistic admission — must complete every
    request through preempt-and-requeue with bit-identical tokens, (c)
    the same tight pool under reserve admission — pure backpressure,
    zero preemptions, and (d) the ample pool under a seeded FaultPlan
    (injected alloc failures, forced preemptions, virtual-clock latency)
    — still bit-identical.  Raises AssertionError on any miss, so the CI
    chaos leg fails on crash or token mismatch.  Returns (rows, record)
    for the BENCH_serve.json ``overload`` section."""
    from repro.launch import serve as serve_mod
    from repro.launch import traffic

    ps, cache_len, chunk = 64, 192, 64
    max_pages = cache_len // ps                       # 3 per slot
    tight = 1 + (n_slots * max_pages) // 2            # 6 usable = 50%
    legs = {
        "ample": dict(n_pages=0, admission="reserve"),
        "tight_optimistic": dict(n_pages=tight, admission="optimistic"),
        "tight_reserve": dict(n_pages=tight, admission="reserve"),
        "faulted": dict(n_pages=0, admission="optimistic",
                        fault_plan=serve_mod.FaultPlan.random(
                            seed + 1, n_steps=160, n_alloc_calls=48,
                            alloc_fail_p=0.15, preempt_p=0.04,
                            latency_p=0.1, max_latency=0.005,
                            hold_pages=2),
                        clock=lambda: 0.0),
    }
    recs, toks = {}, {}
    for leg, kw in legs.items():
        trace = overload_trace(cfg.vocab_size, page_size=ps,
                               n_requests=n_requests, seed=seed)
        recs[leg] = serve_mod.run_engine(
            cfg, params, trace, n_slots=n_slots, cache_len=cache_len,
            chunk=chunk, sample=False, seed=seed, page_size=ps, **kw)
        toks[leg] = {r.rid: list(r.tokens) for r in trace}
        rec = recs[leg]
        rb = rec["robustness"]
        assert rec["requests"] == n_requests, \
            f"{leg}: only {rec['requests']}/{n_requests} completed " \
            f"(sheds={rb['sheds']})"
        assert sum(len(t) for t in toks[leg].values()) == \
            sum(r.max_new for r in trace), f"{leg}: token count drifted"
    for leg in ("tight_optimistic", "tight_reserve", "faulted"):
        assert toks[leg] == toks["ample"], \
            f"{leg}: greedy tokens diverged from the ample-pool run"
    rb = recs["tight_optimistic"]["robustness"]
    assert rb["preemptions"] >= 1 and rb["requeues"] >= 1, \
        f"tight pool never preempted (counters: {rb})"
    assert recs["tight_optimistic"]["pool_high_water"] <= tight - 1
    assert recs["tight_reserve"]["robustness"]["preemptions"] == 0, \
        "reserve admission must make decode exhaustion impossible"
    fb = recs["faulted"]["robustness"]
    assert fb["injected_alloc_failures"] >= 1 \
        or fb["forced_preemptions"] >= 1, \
        f"fault plan injected nothing (counters: {fb})"

    cap = traffic.reservation_capacity(
        n_pages=tight, page_size=ps,
        prompt_tokens=ps + 22, max_new=ps, shared_tokens=ps)
    rows = []
    for leg in legs:
        rec, rb = recs[leg], recs[leg]["robustness"]
        rows.append({
            "name": f"serve_overload_{leg}",
            "us_per_call": rec["wall_s"] * 1e6,
            "derived": f"tok_s={rec['tokens_per_s']} "
                       f"pages={rec['n_pages']} "
                       f"high_water={rec['pool_high_water']} "
                       f"preempt={rb['preemptions']} "
                       f"requeue={rb['requeues']} "
                       f"shed={rb['sheds']} "
                       f"inject={rb['injected_alloc_failures']}"
                       f"+{rb['forced_preemptions']}f "
                       f"tokens_ok={toks[leg] == toks['ample']}"})
    rows.append({
        "name": "reservation_capacity_model", "us_per_call": 0.0,
        "derived": f"usable={cap['usable_pages']} worst="
                   f"{cap['worst_case_pages_per_req']}/req "
                   f"slots reserve={cap['slots_reserve']} "
                   f"optimistic={cap['slots_optimistic']} "
                   f"(overcommit={cap['overcommit_ratio']:.2f}x)"})
    record = {
        "n_requests": n_requests,
        "pool_pages": {"ample": recs["ample"]["n_pages"],
                       "tight": tight},
        "all_completed": True,
        "tokens_identical_vs_ample": True,
        "capacity_model": cap,
        "legs": {leg: {
            "tokens_per_s": recs[leg]["tokens_per_s"],
            "pool_high_water": recs[leg]["pool_high_water"],
            "robustness": recs[leg]["robustness"],
        } for leg in legs},
    }
    return rows, record


def _sim_ngram_rounds(prompt, stream, kmax):
    """Replay the engine's accept rule against a recorded greedy stream
    using the real ``NgramDraft`` — a host-only predictor of speculative
    round count (no model calls).  Returns (accept_rate, rounds,
    tokens_per_round); tokens_per_round is the quantity that drives the
    off-vs-spec tok/s ratio, so candidate selection maximises it."""
    from repro.launch import serve as serve_mod

    d = serve_mod.NgramDraft()
    hist = list(prompt) + [int(stream[0])]
    i, acc, drafted, rounds = 1, 0, 0, 0
    while i < len(stream):
        props = d.propose_one(hist, kmax)
        ke = min(kmax, 1 + len(props))
        a = 0
        while a < ke - 1 and i + a < len(stream) \
                and props[a] == stream[i + a]:
            a += 1
        na = min(a + 1, len(stream) - i)
        drafted += ke - 1
        acc += na - 1
        hist.extend(stream[i:i + na])
        i += na
        rounds += 1
    return (acc / max(drafted, 1), rounds,
            (len(stream) - 1) / max(rounds, 1))


def spec_trace(cfg, params, *, shared_len: int = 16, n_cand: int = 24,
               n_requests: int = 4, max_new: int = 48, fold: int = 8,
               spec_k: int = 6, seed: int = 7):
    """High-acceptance shared-prefix trace for the speculative bench.

    Speculation pays off exactly when the target's stream is locally
    predictable, so the trace is built by *probing*: ``n_cand``
    shared-prefix candidate prompts run ``fold + max_new`` greedy tokens
    through the plain engine, then each candidate's recorded stream is
    replayed through ``_sim_ngram_rounds`` and the one needing the
    fewest speculative rounds (max tokens/round) wins.  Its first
    ``fold`` generated tokens are folded into the bench prompt — greedy
    decode continues the identical stream, but the n-gram drafter now
    sees the repeating pattern from round 0 instead of burning
    draft-less ramp-up rounds, and ``max_new`` stops before the stream
    wanders out of its predictable regime (long horizons drift into
    chaotic stretches that pay full verify cost for 1-token rounds).
    The chosen prompt is duplicated ``n_requests`` times with staggered
    generation lengths — the prefix-reuse shape the paged engine
    deduplicates (and COW-forks at first decode write).  Returns
    (make_trace, probe_info); ``make_trace()`` builds a fresh trace
    (Request.tokens accumulates in place across runs)."""
    from repro.launch import serve as serve_mod

    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, shared_len).astype(np.int32)
    cands = [np.concatenate([shared, rng.integers(
        0, cfg.vocab_size, 8 + i % 7).astype(np.int32)])
        for i in range(n_cand)]
    probe = [serve_mod.Request(rid=i, prompt=c, max_new=fold + max_new,
                               arrival=0.0)
             for i, c in enumerate(cands)]
    serve_mod.run_engine(cfg, params, probe, n_slots=4, cache_len=128,
                         chunk=128, sample=False, seed=0)
    best, best_sim = 0, (0.0, 10 ** 9, 0.0)
    for r in probe:
        t = [int(x) for x in r.tokens]
        if len(t) < fold + max_new:
            continue
        sim = _sim_ngram_rounds(
            [int(x) for x in cands[r.rid]] + t[:fold],
            t[fold:fold + max_new], spec_k)
        if sim[2] > best_sim[2]:
            best, best_sim = r.rid, sim
    base = np.concatenate([
        cands[best],
        np.asarray(probe[best].tokens[:fold], np.int32)])

    def make_trace():
        return [serve_mod.Request(rid=i, prompt=base.copy(),
                                  max_new=max_new - 4 * (i % 3),
                                  arrival=0.0)
                for i in range(n_requests)]

    info = {"n_candidates": n_cand, "fold": fold,
            "sim_accept": round(best_sim[0], 3),
            "sim_tokens_per_round": round(best_sim[2], 2),
            "prompt_len": len(base),
            "shared_len": shared_len, "n_requests": n_requests,
            "max_new": max_new, "spec_k": spec_k}
    return make_trace, info


def bench_speculative(cfg, *, spec_k: int = 6, reps: int = 3,
                      seed: int = 1) -> tuple:
    """The speculative-decoding acceptance gate: the probed
    high-acceptance shared-prefix trace through the engine with
    speculation off vs on.

    Legs: (a) contiguous off vs n-gram drafts — paired reps, the
    median-ratio rep must clear the >1.5x decode tok/s bar; (b) the
    same pair on the paged layout (prefix dedup + COW forks + page
    pre-map/rewind live under the verify chunks); (c) a draft-model
    leg (independently initialised tiny draft, so acceptance is floor
    — the leg proves the plumbing, not a speedup).  Every speculative
    leg's greedy tokens must be bit-identical to the plain engine's.
    Params are initialised here (key(seed)) rather than shared with
    the other benches: the probe selection is calibrated against this
    parameterisation.  Returns (rows, record) for the
    BENCH_serve.json ``speculative`` section."""
    from repro.launch import serve as serve_mod
    from repro.models import model as M

    params = M.init_params(cfg, jax.random.key(seed))
    make_trace, info = spec_trace(cfg, params, spec_k=spec_k)

    def run_leg(spec, **kw):
        trace = make_trace()
        rec = serve_mod.run_engine(
            cfg, params, trace, n_slots=info["n_requests"], cache_len=384,
            chunk=128, sample=False, seed=0, spec=spec, spec_k=spec_k,
            **kw)
        return rec, {r.rid: list(r.tokens) for r in trace}

    # (a) contiguous, paired: off then ngram back-to-back per rep;
    # shared-machine noise hits a pair roughly equally, so the per-rep
    # ratio is the stable statistic (same design as engine_vs_lockstep)
    pairs = []
    for _ in range(reps):
        r_off, t_off = run_leg("off")
        r_ng, t_ng = run_leg("ngram")
        assert t_ng == t_off, \
            "ngram spec diverged from plain greedy decode (contiguous)"
        pairs.append((r_ng["decode_tokens_per_s"] /
                      max(r_off["decode_tokens_per_s"], 1e-9),
                      r_off, r_ng))
    pairs.sort(key=lambda p: p[0])
    ratio, r_off, r_ng = pairs[len(pairs) // 2]
    ratios = [round(p[0], 2) for p in pairs]
    assert ratio > 1.5, \
        f"speculative decode tok/s ratio {ratio:.2f} <= 1.5 " \
        f"(per-rep {ratios}; accept_rate=" \
        f"{r_ng['speculative']['accept_rate']})"

    # (b) paged: dedup + COW + spec page pre-map/rewind under verify;
    # page_size 64 so rejected tokens actually cross page boundaries
    rp_off, tp_off = run_leg("off", prefix_cache=True, page_size=64)
    rp_ng, tp_ng = run_leg("ngram", prefix_cache=True, page_size=64)
    assert tp_ng == tp_off, \
        "ngram spec diverged from plain greedy decode (paged)"
    assert tp_ng == t_ng, "paged greedy stream diverged from contiguous"
    paged_ratio = rp_ng["decode_tokens_per_s"] / max(
        rp_off["decode_tokens_per_s"], 1e-9)

    # (c) draft-model source: random-init draft, acceptance floor
    rd, td = run_leg("draft")
    assert td == t_off, \
        "draft-model spec diverged from plain greedy decode"

    def leg_cols(rec):
        s = rec["speculative"]
        return {"tokens_per_s": rec["tokens_per_s"],
                "decode_tokens_per_s": rec["decode_tokens_per_s"],
                "accept_rate": s.get("accept_rate"),
                "mean_accepted_k": s.get("mean_accepted_k"),
                "wasted_tokens": s.get("wasted_tokens"),
                "wasted_bytes": s.get("wasted_bytes"),
                "pages_rewound": s.get("pages_rewound"),
                "rounds": s.get("rounds")}

    rows = []
    for name, rec in (("serve_spec_off", r_off),
                      ("serve_spec_ngram", r_ng),
                      ("serve_spec_off_paged", rp_off),
                      ("serve_spec_ngram_paged", rp_ng),
                      ("serve_spec_draft", rd)):
        s = rec["speculative"]
        rows.append({
            "name": name, "us_per_call": rec["wall_s"] * 1e6,
            "derived": f"tok_s={rec['tokens_per_s']} "
                       f"accept={s.get('accept_rate')} "
                       f"mean_k={s.get('mean_accepted_k')} "
                       f"wasted={s.get('wasted_tokens')}"})
    rows.append({
        "name": "spec_vs_off", "us_per_call": 0.0,
        "derived": f"tok_s_ratio={ratio:.2f}x (per-rep {ratios}) "
                   f"paged={paged_ratio:.2f}x "
                   f"accept={r_ng['speculative']['accept_rate']} "
                   f"sim_tpr={info['sim_tokens_per_round']} "
                   f"cow={rp_ng['cow_events']}"})
    # numerics-health column: the smallest top-2 logit gap along the
    # off leg's greedy streams.  Identity asserts above are only as
    # strong as this margin — a value near the ~1e-6 lowering noise
    # would mean the trace no longer pins argmax ties (recalibrate the
    # probe), while a flip at a healthy margin is a logic bug
    mtrace = make_trace()
    for r in mtrace:
        r.tokens = list(t_off[r.rid])
    margin = serve_mod.min_accept_margin(cfg, params, mtrace, 384)
    record = {
        "trace": info,
        "spec_k": spec_k,
        "ngram_vs_off_tok_s_ratio": ratio,
        "min_accept_margin": round(margin, 6),
        "per_rep_ratios": ratios,
        "paged_ngram_vs_off_tok_s_ratio": round(paged_ratio, 2),
        "tokens_identical_vs_off": {"ngram": True, "ngram_paged": True,
                                    "draft": True},
        "paged_cow_events": rp_ng["cow_events"],
        "legs": {"off": leg_cols(r_off), "ngram": leg_cols(r_ng),
                 "off_paged": leg_cols(rp_off),
                 "ngram_paged": leg_cols(rp_ng), "draft": leg_cols(rd)},
    }
    return rows, record


def run(*, arch: str = "stablelm-1.6b", prompt_len: int = 128,
        chunk: int = 128, n_slots: int = 4, n_requests: int = 24,
        seed: int = 0) -> list:
    from repro.configs import get_config
    from repro.models import model as M

    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.key(seed))

    rows = [{"name": "serve_meta", "us_per_call": 0.0,
             "derived": f"arch={cfg.name} devices={len(jax.devices())} "
                        f"backend={jax.default_backend()}"}]
    pf_rows, _ = bench_prefill(cfg, params, prompt_len=prompt_len,
                               chunk=chunk)
    rows += pf_rows
    rows += bench_engine_vs_lockstep(cfg, params, n_slots=n_slots,
                                     n_requests=n_requests, seed=seed)
    sh_rows, record = bench_paged_sharing(cfg, params, n_slots=n_slots,
                                          n_requests=12, seed=seed)
    rows += sh_rows
    kv_rows, kv_record = bench_kv_dtypes(cfg, params, n_slots=n_slots,
                                         n_requests=8, seed=seed)
    rows += kv_rows
    ov_rows, ov_record = bench_overload(cfg, params, n_slots=n_slots,
                                        seed=seed)
    rows += ov_rows
    sp_rows, sp_record = bench_speculative(cfg)
    rows += sp_rows
    record["kv_dtype"] = kv_record
    record["overload"] = ov_record
    record["speculative"] = sp_record
    record["provenance"] = common.provenance()
    common.save_rows("serve_engine", rows)
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=1)
        f.write("\n")
    return rows


def run_chaos(*, arch: str = "stablelm-1.6b", seed: int = 0) -> list:
    """CI chaos smoke: just the overload/fault legs (every assertion in
    ``bench_overload`` is live, so a crash, shed, or token divergence
    fails the job).  Does NOT rewrite BENCH_serve.json."""
    from repro.configs import get_config
    from repro.models import model as M

    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.key(seed))
    rows, _ = bench_overload(cfg, params, seed=seed)
    return rows


def run_spec(*, arch: str = "stablelm-1.6b", reps: int = 3) -> list:
    """CI speculative smoke: just the spec legs (every assertion in
    ``bench_speculative`` is live — token divergence or a tok/s ratio
    under 1.5x fails the job).  Median-of-``reps`` pairs is the gated
    statistic — a single pair is too exposed to the first-pair warm-up
    dip.  Does NOT rewrite BENCH_serve.json."""
    from repro.configs import get_config

    cfg = get_config(arch).reduced()
    rows, _ = bench_speculative(cfg, reps=reps)
    return rows


if __name__ == "__main__":
    for r in run():
        common.emit(r["name"], r["us_per_call"], r["derived"])
