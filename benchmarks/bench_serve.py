"""Serve-engine benchmark: chunked flash prefill vs the token-by-token
loop, and continuous batching vs lockstep waves under mixed-length traffic.

Emits ``benchmarks/results/serve_engine.json`` (next to
``kernels_micro.json``) with tokens/s and latency percentiles — the
numbers backing the serve-engine acceptance criteria:

  * chunked prefill >= 5x faster than the single-token loop at
    prompt_len 128;
  * the continuous-batching engine sustains higher aggregate tokens/s
    than lockstep wave batching on the same mixed-length trace.

  PYTHONPATH=src python -m benchmarks.run --only serve
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common


def bench_prefill(cfg, params, *, prompt_len: int, chunk: int) -> tuple:
    """Token-by-token loop vs chunked flash prefill for one prompt."""
    from repro.core import llm_a3c
    from repro.launch import traffic
    from repro.models import model as M

    rows = []
    cache_len = prompt_len + 16
    prompt = jax.random.randint(jax.random.key(1), (1, prompt_len), 0,
                                cfg.vocab_size)
    serve_step = jax.jit(llm_a3c.make_serve_step(cfg, sample=False))
    key = jax.random.key(0)

    def loop_prefill():
        cache = M.init_cache(cfg, 1, cache_len, dtype=jnp.float32)
        for i in range(prompt_len):
            tok, _, cache = serve_step(params, cache,
                                       {"tokens": prompt[:, i:i + 1]},
                                       jnp.asarray(i), key)
        return tok

    prefill_step = llm_a3c.make_prefill_step(cfg)

    def chunked_prefill():
        cache = M.init_cache(cfg, 1, cache_len, dtype=jnp.float32)
        for p0 in range(0, prompt_len, chunk):
            logits, cache = prefill_step(
                params, cache, {"tokens": prompt[:, p0:p0 + chunk]},
                pos0=p0)
        return logits

    us_loop = common.timed(loop_prefill, iters=3)
    us_chunk = common.timed(chunked_prefill, iters=3)
    speedup = us_loop / us_chunk
    rows.append({"name": "prefill_token_loop", "us_per_call": us_loop,
                 "derived": f"prompt={prompt_len} "
                            f"tok_s={prompt_len * 1e6 / us_loop:.1f}"})
    rows.append({"name": "prefill_chunked_flash", "us_per_call": us_chunk,
                 "derived": f"prompt={prompt_len} chunk={chunk} "
                            f"tok_s={prompt_len * 1e6 / us_chunk:.1f} "
                            f"speedup={speedup:.1f}x"})
    rows.append({"name": "prefill_chunk_hbm_model",
                 "us_per_call": 0.0,
                 "derived": "analytic bytes loop(C=1)="
                 f"{traffic.prefill_chunk_bytes(cfg, 1, prompt_len, 1):.3e}"
                 " chunked="
                 f"{traffic.prefill_chunk_bytes(cfg, 1, prompt_len, chunk):.3e}"})
    return rows, speedup


def bench_engine_vs_lockstep(cfg, params, *, n_slots: int, n_requests: int,
                             seed: int, reps: int = 3) -> list:
    """Same mixed-length trace through both batching disciplines.

    Paired design: each rep runs engine then lockstep back-to-back on an
    identical trace and the ratio is taken per rep (shared-machine noise
    on this box swings absolute wall time far more than the structural
    margin, but hits a back-to-back pair roughly equally); the reported
    records come from the median-ratio rep.  Occupancy — the
    deterministic slot-efficiency metric — is identical across reps."""
    from repro.launch import serve as serve_mod

    # wide generation-length dispersion is the regime continuous batching
    # exists for: lockstep burns a slot-step per finished-but-waiting row
    # until the wave's slowest request drains
    def one_rep():
        recs = {}
        for mode, runner in (("engine", serve_mod.run_engine),
                             ("lockstep", serve_mod.run_lockstep)):
            trace = serve_mod.gen_trace(
                n_requests, vocab=cfg.vocab_size, prompt_range=(16, 64),
                gen_range=(4, 64), arrival_rate=0.0, seed=seed)
            recs[mode] = runner(cfg, params, trace, n_slots=n_slots,
                                cache_len=128, chunk=64, sample=True,
                                seed=seed)
        return recs

    all_recs = [one_rep() for _ in range(reps)]
    ratios = [r["engine"]["tokens_per_s"] /
              max(r["lockstep"]["tokens_per_s"], 1e-9) for r in all_recs]
    median = sorted(ratios)[len(ratios) // 2]
    recs = all_recs[ratios.index(median)]

    rows = []
    for mode in ("engine", "lockstep"):
        rec = recs[mode]
        rows.append({
            "name": f"serve_{mode}_mixed",
            "us_per_call": rec["wall_s"] * 1e6,
            "derived": f"tok_s={rec['tokens_per_s']} "
                       f"occupancy={rec['occupancy']} "
                       f"p50={rec['latency_s'].get('p50')} "
                       f"p99={rec['latency_s'].get('p99')}",
            "tokens_per_s": rec["tokens_per_s"],
            "latency_s": rec["latency_s"],
            "ttft_s": rec["ttft_s"],
            "occupancy": rec["occupancy"],
            "warmup_s": rec["warmup_s"],
        })
    rows.append({"name": "engine_vs_lockstep", "us_per_call": 0.0,
                 "derived": f"aggregate_tok_s_ratio={median:.2f}x "
                            f"(per-rep {[round(r, 2) for r in ratios]})"})
    return rows


def run(*, arch: str = "stablelm-1.6b", prompt_len: int = 128,
        chunk: int = 128, n_slots: int = 4, n_requests: int = 24,
        seed: int = 0) -> list:
    from repro.configs import get_config
    from repro.models import model as M

    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.key(seed))

    rows = [{"name": "serve_meta", "us_per_call": 0.0,
             "derived": f"arch={cfg.name} devices={len(jax.devices())} "
                        f"backend={jax.default_backend()}"}]
    pf_rows, _ = bench_prefill(cfg, params, prompt_len=prompt_len,
                               chunk=chunk)
    rows += pf_rows
    rows += bench_engine_vs_lockstep(cfg, params, n_slots=n_slots,
                                     n_requests=n_requests, seed=seed)
    common.save_rows("serve_engine", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        common.emit(r["name"], r["us_per_call"], r["derived"])
