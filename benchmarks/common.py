"""Shared benchmark harness utilities."""
from __future__ import annotations

import csv
import json
import os
import sys
import time
from typing import Any, Dict, List

import jax

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def provenance() -> Dict[str, Any]:
    """Environment stamp for every root BENCH_*.json artifact, so the
    per-PR perf trajectory rows are attributable: which commit, which jax,
    which backend produced the number."""
    import subprocess
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, cwd=REPO_ROOT, timeout=10).stdout.strip() or None
    except Exception:  # noqa: BLE001 — no git is a degraded stamp, not a crash
        sha = None
    return {
        "git_sha": sha,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
    }


def save_rows(name: str, rows: List[Dict[str, Any]]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    return path


def timed(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return 1e6 * ts[len(ts) // 2]


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The scaffold's CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def make_rl_runner(algo_name: str, env_name: str, *, workers: int = 8,
                   lr: float = 1e-2, hidden: int = 64, seed: int = 0,
                   optimizer: str = "shared_rmsprop", shared_stats=True,
                   mode: str = "hogwild", beta: float = 0.01,
                   beta_continuous: float = 1e-2,
                   continuous: bool = False):
    from repro.core import agents, async_runner
    from repro.envs import make
    from repro.envs.api import flatten_obs
    from repro.models import atari as nets

    env = make(env_name)
    if len(env.obs_shape) > 1:
        env = flatten_obs(env)
    kwargs = {}
    if continuous or env.continuous:
        kwargs["continuous"] = True
        kwargs["beta_continuous"] = beta_continuous
    if algo_name == "a3c":
        kwargs["beta"] = beta
    algo = agents.ALGORITHMS[algo_name](**kwargs)
    params = nets.init_mlp_agent_params(
        jax.random.key(seed), env.obs_shape[0], env.n_actions,
        hidden=hidden, continuous=env.continuous)
    cfg = async_runner.RunnerConfig(
        n_workers=workers, t_max=5, lr0=lr, total_frames=10**9,
        mode=mode, optimizer=optimizer, shared_stats=shared_stats,
        target_interval=2_000, anneal_frames=20_000)
    init_state, round_fn = async_runner.make_runner(algo, env, params, cfg)
    return env, init_state(jax.random.key(seed + 1)), round_fn, cfg


def run_frames(state, round_fn, cfg, frames: int, *, trace_every: int = 0):
    """Advance the runner; returns (state, history of (frames, ep_ret))."""
    rounds = max(1, frames // (cfg.n_workers * cfg.t_max))
    hist = []
    ema = None
    for i in range(rounds):
        state, m = round_fn(state)
        r = float(m["ep_ret"])
        ema = r if ema is None else 0.95 * ema + 0.05 * r
        if trace_every and i % trace_every == 0:
            hist.append((int(state["frames"]), ema))
    hist.append((int(state["frames"]), ema))
    return state, hist
