"""Fig. 1 analogue: learning-speed comparison of the four asynchronous
methods (and DQN-replay) on the Catch (Atari-proxy) and GridMaze
(Labyrinth-proxy) environments."""
from __future__ import annotations

import time

from benchmarks import common

ALGOS = ["a3c", "n_step_q", "one_step_q", "one_step_sarsa"]


def run(frames: int = 40_000, envs=("catch",)) -> list:
    rows = []
    for env_name in envs:
        for algo in ALGOS:
            env, st, round_fn, cfg = common.make_rl_runner(
                algo, env_name, workers=8, lr=1e-2)
            t0 = time.time()
            st, hist = common.run_frames(st, round_fn, cfg, frames,
                                         trace_every=50)
            rows.append({
                "bench": "fig1", "env": env_name, "algo": algo,
                "frames": frames, "final_ep_ret": hist[-1][1],
                "curve": hist, "wall_s": round(time.time() - t0, 1),
            })
    common.save_rows("fig1_learning", rows)
    return rows
