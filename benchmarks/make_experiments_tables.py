"""Regenerate the §Dry-run / §Roofline tables in EXPERIMENTS.md from
benchmarks/results/dryrun*.jsonl.  Sections outside the AUTOGEN markers
(§Perf iteration log, §Repro) are preserved.

  PYTHONPATH=src python -m benchmarks.make_experiments_tables
"""
from __future__ import annotations

import json
import os

HERE = os.path.dirname(__file__)
RESULTS = os.path.join(HERE, "results")
EXP = os.path.join(HERE, "..", "EXPERIMENTS.md")

BEGIN = "<!-- AUTOGEN:DRYRUN BEGIN -->"
END = "<!-- AUTOGEN:DRYRUN END -->"


def load(*names):
    """Load one or more jsonl files; later files/records override earlier
    ones for the same (arch, shape, mode) key."""
    recs = {}
    for name in names:
        path = os.path.join(RESULTS, name)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            for line in f:
                if line.strip():
                    r = json.loads(line)
                    recs[(r["arch"], r["shape"], r.get("mode", "sync"))] = r
    return list(recs.values())


def fmt_bytes(n):
    if n is None:
        return "n/a"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def dryrun_table(recs):
    out = ["| arch | shape | kind | compile_s | per-dev peak | "
           "collective/dev | status |",
           "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} |  |  |  |  | "
                       f"{r['status']}: {r.get('reason', r.get('error',''))[:60]} |")
            continue
        mem = r["memory"]
        peak = (mem.get("peak_bytes") or 0) + (mem.get("argument_bytes") or 0)
        out.append(
            f"| {r['variant']} | {r['shape']} | {r['kind']} | "
            f"{r['t_compile_s']} | {fmt_bytes(peak)} | "
            f"{fmt_bytes(r['collective_bytes']['total'])} | ok |")
    return "\n".join(out)


def roofline_table(recs):
    out = ["| arch | shape | t_compute | t_memory | t_collective | "
           "dominant | MODEL/HLO flops | one-line lever |",
           "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok":
            continue
        t = r["roofline"]
        ur = r.get("useful_flops_ratio")
        lever = LEVERS.get((r["arch"], r["shape"]),
                           LEVERS.get(r["shape"], ""))
        out.append(
            f"| {r['variant']} | {r['shape']} | {t['t_compute']:.2e} | "
            f"{t['t_memory']:.2e} | {t['t_collective']:.2e} | "
            f"**{t['dominant']}** | "
            f"{(f'{ur:.2f}' if ur else 'n/a')} | {lever} |")
    return "\n".join(out)


LEVERS = {
    "train_4k": "cut AR->RS conversions + param-gather reuse across remat",
    "prefill_32k": "head-local attention already; overlap FSDP gathers",
    "decode_32k": "context-parallel cache read is the floor; batch decode "
                  "steps to amortize weight reads",
    "long_500k": "ring/native state already sub-quadratic; shard state not "
                 "sequence for SSM",
}


def main():
    single = load("dryrun.jsonl")
    multi = load("dryrun_multipod.jsonl")
    opt = load("dryrun_optimized.jsonl", "dryrun_optimized2.jsonl",
               "dryrun_optimized3.jsonl")

    parts = [BEGIN, "", "## §Dry-run — single pod (16x16 = 256 chips)", "",
             dryrun_table(single), ""]
    if multi:
        parts += ["## §Dry-run — multi-pod (2x16x16 = 512 chips)", "",
                  dryrun_table(multi), ""]
    parts += ["## §Roofline — per (arch x shape), single-pod baseline", "",
              "Terms in seconds/step (hardware: 197 TFLOP/s bf16, 819 GB/s "
              "HBM, 50 GB/s/link ICI).  MODEL/HLO = 6·N·D (or 2·N·D for "
              "inference) over trip-count-weighted compiled dot FLOPs — "
              "values < 1 expose remat/attention/capacity overhead; the "
              "memory term uses the analytic per-device traffic model "
              "(launch/traffic.py).", "",
              roofline_table(single), ""]
    if opt:
        parts += ["## §Roofline — optimized variants (see §Perf)", "",
                  roofline_table(opt), ""]
    parts += [END]
    block = "\n".join(parts)

    if os.path.exists(EXP):
        text = open(EXP).read()
        if BEGIN in text and END in text:
            pre = text.split(BEGIN)[0]
            post = text.split(END)[1]
            text = pre + block + post
        else:
            text = text + "\n" + block + "\n"
    else:
        text = block + "\n"
    open(EXP, "w").write(text)
    n_ok = sum(r["status"] == "ok" for r in single)
    print(f"wrote {EXP}: {n_ok} ok single-pod records, "
          f"{sum(r['status'] == 'ok' for r in multi)} multi-pod")


if __name__ == "__main__":
    main()
