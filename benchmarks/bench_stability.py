"""The paper's central claim (§4, §6): parallel actor-learners have a
STABILIZING effect — multi-worker async Q-learning avoids the collapse /
divergence single-worker online Q-learning suffers.

Protocol: async one-step Q with 1 vs 16 workers, several seeds at a hot
learning rate; report per-seed final scores and the collapse rate (final
score below the random baseline after training)."""
from __future__ import annotations

import numpy as np

from benchmarks import common

RANDOM_BASELINE = -0.6   # catch random policy


def run(frames: int = 40_000, seeds: int = 4, lr: float = 3e-2) -> list:
    rows = []
    for workers in (1, 16):
        finals = []
        for seed in range(seeds):
            env, st, round_fn, cfg = common.make_rl_runner(
                "one_step_q", "catch", workers=workers, lr=lr, seed=seed)
            st, hist = common.run_frames(st, round_fn, cfg, frames)
            finals.append(round(hist[-1][1], 3))
        collapsed = sum(f < RANDOM_BASELINE + 0.05 for f in finals)
        rows.append({"bench": "stability", "workers": workers,
                     "lr": lr, "final_scores": finals,
                     "mean": round(float(np.mean(finals)), 3),
                     "collapse_rate": f"{collapsed}/{seeds}"})
    common.save_rows("stability", rows)
    return rows
