"""Table 2 analogue: data efficiency / training speed-up vs number of
parallel actor-learners.

On 1 physical CPU wall-clock speedup is meaningless, so we measure the
paper's *data-efficiency* claim (Fig. 6): frames needed to reach a reference
score with k in {1,2,4,8,16} workers.  The paper's speedup = (frames-to-score
with 1 worker) / (frames-to-score with k), assuming constant per-worker
throughput (their Table 2 folds in compute; ours isolates the data term)."""
from __future__ import annotations

import numpy as np

from benchmarks import common

WORKER_COUNTS = (1, 2, 4, 8, 16)


def frames_to_score(algo: str, workers: int, target: float,
                    max_frames: int, seed: int = 0) -> int:
    env, st, round_fn, cfg = common.make_rl_runner(
        algo, "catch", workers=workers, lr=1e-2, seed=seed)
    ema, n = None, 0
    while n < max_frames:
        st, m = round_fn(st)
        n = int(st["frames"])
        r = float(m["ep_ret"])
        ema = r if ema is None else 0.98 * ema + 0.02 * r
        if ema is not None and ema >= target:
            return n
    return max_frames


def run(algos=("a3c", "one_step_q"), target: float = 0.5,
        max_frames: int = 120_000) -> list:
    rows = []
    for algo in algos:
        base = None
        for k in WORKER_COUNTS:
            f = frames_to_score(algo, k, target, max_frames)
            if k == 1:
                base = f
            rows.append({
                "bench": "table2", "algo": algo, "workers": k,
                "frames_to_target": f,
                "data_speedup": round(base / f, 2) if base else None,
            })
    common.save_rows("table2_scaling", rows)
    return rows
