"""A3C at LLM scale: the paper's algorithm driving an assigned-architecture
backbone as a token-level policy (TokenMDP).  Uses the reduced Granite MoE
config so the run (including the MoE router + load-balance loss) finishes
in ~2 minutes on CPU.  The same train_step lowers on the 256-chip production
mesh in the dry-run.

  PYTHONPATH=src python examples/llm_policy_a3c.py [--arch stablelm-1.6b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import llm_a3c
from repro.data.pipeline import TokenPipeline
from repro.models import model as M
from repro.optim import optimizers as opt_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    opt = opt_mod.shared_rmsprop()
    opt_state = opt.init(params)
    pipe = TokenPipeline(vocab=cfg.vocab_size, seq_len=64, global_batch=4)
    step = jax.jit(llm_a3c.make_train_step(cfg, opt, lr0=3e-3,
                                           total_steps=10**9))
    for i in range(args.steps):
        batch = pipe.batch(jax.random.key(7), i % 4)
        params, opt_state, m = step(params, opt_state, batch,
                                    jnp.asarray(i))
        if i % 10 == 0:
            print(f"step {i:3d}  loss={float(m['loss']):8.3f}  "
                  f"mean_return={float(m['mean_return']):6.2f}  "
                  f"aux={float(m['aux']):.4f}")
    print("\npolicy return should trend up as the policy learns the "
          "successor-token task")


if __name__ == "__main__":
    main()
