"""Continuous-action A3C (paper §5.2.3): Gaussian policy heads on the
MuJoCo-proxy point-mass domain.

  PYTHONPATH=src python examples/continuous_control.py
"""
import jax

from repro.core import agents, async_runner
from repro.envs import make
from repro.models import atari as nets


def main():
    env = make("pointmass")
    algo = agents.ALGORITHMS["a3c"](continuous=True)
    params = nets.init_mlp_agent_params(
        jax.random.key(0), env.obs_shape[0], env.n_actions,
        hidden=128, continuous=True)
    cfg = async_runner.RunnerConfig(n_workers=8, t_max=5, lr0=3e-3,
                                    total_frames=10**9)
    init_state, round_fn = async_runner.make_runner(algo, env, params, cfg)
    st = init_state(jax.random.key(1))
    for i in range(3001):
        st, m = round_fn(st)
        if i % 500 == 0:
            print(f"frames={int(st['frames']):6d}  "
                  f"avg_episode_return={float(m['ep_ret']):+7.1f}")
    print("\n(point-mass: random ~ -70; reaching-and-holding ~ > -30)")


if __name__ == "__main__":
    main()
