"""The Labyrinth experiment (paper §5.2.4) at CPU scale: A3C on procedurally
generated GridMaze — a new random maze every episode, apples (+1) and a
portal (+10, respawn).  The agent must learn a *general* exploration
strategy, not one maze's layout.

  PYTHONPATH=src python examples/labyrinth_maze.py
"""
import jax

from repro.core import agents, async_runner
from repro.envs import make
from repro.envs.api import flatten_obs
from repro.models import atari as nets


def main():
    env = flatten_obs(make("gridmaze"))
    algo = agents.ALGORITHMS["a3c"](beta=0.01)
    params = nets.init_mlp_agent_params(
        jax.random.key(0), env.obs_shape[0], env.n_actions, hidden=128)
    cfg = async_runner.RunnerConfig(n_workers=8, t_max=5, lr0=7e-3,
                                    total_frames=10**9)
    init_state, round_fn = async_runner.make_runner(algo, env, params, cfg)
    st = init_state(jax.random.key(1))
    for i in range(5001):
        st, m = round_fn(st)
        if i % 500 == 0:
            print(f"frames={int(st['frames']):6d}  "
                  f"avg_episode_return={float(m['ep_ret']):6.1f}")


if __name__ == "__main__":
    main()
