"""Quickstart: asynchronous advantage actor-critic (A3C) on Catch.

Reproduces the paper's core loop at laptop scale: 8 parallel actor-learners
with Hogwild-style staleness (T1), Shared RMSProp, per-worker exploration,
t_max=5 forward-view updates.  ~1 minute on one CPU core.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import agents, async_runner
from repro.envs import make
from repro.envs.api import flatten_obs
from repro.models import atari as nets


def main():
    env = flatten_obs(make("catch"))
    algo = agents.ALGORITHMS["a3c"]()
    params = nets.init_mlp_agent_params(
        jax.random.key(0), env.obs_shape[0], env.n_actions, hidden=64)
    cfg = async_runner.RunnerConfig(
        n_workers=8, t_max=5, lr0=1e-2, total_frames=10**9,
        mode="hogwild", optimizer="shared_rmsprop")
    init_state, round_fn = async_runner.make_runner(algo, env, params, cfg)
    st = init_state(jax.random.key(1))
    for i in range(4001):
        st, m = round_fn(st)
        if i % 500 == 0:
            print(f"frames={int(st['frames']):6d}  "
                  f"avg_episode_return={float(m['ep_ret']):+.2f}  "
                  f"entropy={float(m['entropy']):.3f}")
    final = float(m["ep_ret"])
    print(f"\nfinal avg return: {final:+.2f}  "
          f"(random ~= -0.6, perfect = +1.0)")
    assert final > 0.5, "did not learn — check the setup"


if __name__ == "__main__":
    main()
