"""All four asynchronous methods (paper §4) on one environment — the Fig. 1
learning-speed comparison at CPU scale, plus the DQN-replay baseline the
paper positions against.

  PYTHONPATH=src python examples/four_methods_shootout.py [frames]
"""
import sys

import jax

from repro.core import agents, async_runner, dqn_replay
from repro.envs import make
from repro.envs.api import flatten_obs
from repro.models import atari as nets


def run_async(algo_name, env, frames):
    algo = agents.ALGORITHMS[algo_name]()
    params = nets.init_mlp_agent_params(
        jax.random.key(0), env.obs_shape[0], env.n_actions, hidden=64)
    cfg = async_runner.RunnerConfig(n_workers=8, t_max=5, lr0=1e-2,
                                    total_frames=10**9)
    init_state, round_fn = async_runner.make_runner(algo, env, params, cfg)
    st = init_state(jax.random.key(1))
    ema = 0.0
    while int(st["frames"]) < frames:
        st, m = round_fn(st)
        ema = 0.98 * ema + 0.02 * float(m["ep_ret"])
    return ema


def run_dqn(env, frames):
    params = nets.init_mlp_agent_params(
        jax.random.key(0), env.obs_shape[0], env.n_actions, hidden=64)
    init_state, step_fn = dqn_replay.make_dqn(env, params,
                                              dqn_replay.DQNConfig())
    st = init_state(jax.random.key(1))
    ema = 0.0
    for _ in range(frames):
        st = step_fn(st)
        ema = 0.999 * ema + 0.001 * float(st["last_ep_ret"])
    return ema


def main():
    frames = int(sys.argv[1]) if len(sys.argv) > 1 else 40_000
    env = flatten_obs(make("catch"))
    print(f"{'method':18s} score@{frames} frames")
    for algo in ["a3c", "n_step_q", "one_step_q", "one_step_sarsa"]:
        print(f"{algo:18s} {run_async(algo, env, frames):+.2f}")
    print(f"{'dqn_replay':18s} {run_dqn(env, frames):+.2f}")


if __name__ == "__main__":
    main()
