"""Model-layer unit tests: RoPE, M-RoPE, SSD scan vs naive recurrence,
mLSTM chunked vs step recurrence, MoE routing conservation."""
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.models import common as cm
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod


def test_rope_rotation_preserves_norm():
    key = jax.random.key(0)
    x = jax.random.normal(key, (2, 8, 4, 64))
    cos, sin = cm.rope_cos_sin(jnp.arange(8)[None], 64, 10000.0)
    y = cm.apply_rope(x, cos, sin)
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                               jnp.linalg.norm(y, axis=-1), rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m-n."""
    key = jax.random.key(1)
    q = jax.random.normal(key, (1, 1, 1, 32))
    k = jax.random.normal(jax.random.key(2), (1, 1, 1, 32))

    def dot_at(m, n):
        cq, sq = cm.rope_cos_sin(jnp.array([[m]]), 32, 100.0)
        ck, sk = cm.rope_cos_sin(jnp.array([[n]]), 32, 100.0)
        return float(jnp.sum(cm.apply_rope(q, cq, sq) *
                             cm.apply_rope(k, ck, sk)))

    np.testing.assert_allclose(dot_at(5, 3), dot_at(12, 10), rtol=1e-4)


def test_mrope_sections_match_standard_when_positions_equal():
    """If t/h/w positions are identical, M-RoPE == standard RoPE."""
    pos = jnp.broadcast_to(jnp.arange(6)[None, None], (3, 1, 6))
    c1, s1 = cm.mrope_cos_sin(pos, 64, 1e4, (16, 8, 8))
    c2, s2 = cm.rope_cos_sin(jnp.arange(6)[None], 64, 1e4)
    np.testing.assert_allclose(c1, c2, rtol=1e-6)
    np.testing.assert_allclose(s1, s2, rtol=1e-6)


def test_partial_rotary_passthrough():
    x = jnp.ones((1, 2, 1, 8))
    cos, sin = cm.rope_cos_sin(jnp.arange(2)[None], 8, 10.0)
    y = cm.apply_rope(x, cos, sin, rotary_dim=4)
    np.testing.assert_array_equal(y[..., 4:], x[..., 4:])


def _naive_ssd(x, log_a, b, c):
    """Step-by-step recurrence oracle for the chunked SSD scan."""
    bs, s, h, p = x.shape
    n = b.shape[-1]
    hstate = jnp.zeros((bs, h, n, p))
    ys = []
    for t in range(s):
        hstate = jnp.exp(log_a[:, t])[:, :, None, None] * hstate + \
            jnp.einsum("bhn,bhp->bhnp", b[:, t], x[:, t])
        ys.append(jnp.einsum("bhn,bhnp->bhp", c[:, t], hstate))
    return jnp.stack(ys, 1), hstate


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), s=st.sampled_from([8, 16, 32]),
       chunk=st.sampled_from([4, 8]))
def test_ssd_chunked_matches_recurrence(seed, s, chunk):
    key = jax.random.key(seed)
    ks = jax.random.split(key, 4)
    bs, h, p, n = 2, 3, 4, 5
    x = jax.random.normal(ks[0], (bs, s, h, p))
    log_a = -jnp.abs(jax.random.normal(ks[1], (bs, s, h))) * 0.3
    b = jax.random.normal(ks[2], (bs, s, h, n))
    c = jax.random.normal(ks[3], (bs, s, h, n))
    y_fast, h_fast = ssm_mod.ssd_chunked(x, log_a, b, c, chunk=chunk)
    y_ref, h_ref = _naive_ssd(x, log_a, b, c)
    np.testing.assert_allclose(y_fast, y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h_fast, h_ref, atol=1e-4, rtol=1e-4)


def test_mamba2_train_decode_consistency():
    """mamba2_train over a sequence == repeated mamba2_decode."""
    class Cfg:
        ssm_heads = 4; ssm_head_dim = 8; ssm_state = 16; ssm_groups = 1
        ssm_conv_width = 4; ssm_chunk = 8
    cfg = Cfg()
    d_model = 16
    p = ssm_mod.init_mamba2(jax.random.key(0), d_model,
                            d_state=cfg.ssm_state, n_heads=cfg.ssm_heads,
                            head_dim=cfg.ssm_head_dim)
    x = 0.1 * jax.random.normal(jax.random.key(1), (2, 16, d_model))
    y_train = ssm_mod.mamba2_train(p, x, cfg)
    state = ssm_mod.init_mamba2_state(2, cfg)
    ys = []
    for t in range(16):
        y, state = ssm_mod.mamba2_decode(p, x[:, t:t + 1], state, cfg)
        ys.append(y)
    y_dec = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_dec),
                               atol=1e-4, rtol=1e-3)


def test_mlstm_train_decode_consistency():
    class Cfg:
        n_heads = 2; lstm_expand = 2; ssm_conv_width = 4; ssm_chunk = 8
    cfg = Cfg()
    d_model = 16
    p = xlstm_mod.init_mlstm(jax.random.key(0), d_model, n_heads=2)
    x = 0.1 * jax.random.normal(jax.random.key(1), (2, 16, d_model))
    y_train = xlstm_mod.mlstm_train(p, x, cfg)
    state = xlstm_mod.init_mlstm_state(2, d_model, 2)
    ys = []
    for t in range(16):
        y, state = xlstm_mod.mlstm_decode(p, x[:, t:t + 1], state, cfg)
        ys.append(y)
    y_dec = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_dec),
                               atol=1e-3, rtol=1e-2)


def test_slstm_train_decode_consistency():
    class Cfg:
        n_heads = 2
    p = xlstm_mod.init_slstm(jax.random.key(0), 16, n_heads=2)
    x = 0.1 * jax.random.normal(jax.random.key(1), (1, 8, 16))
    y_train = xlstm_mod.slstm_train(p, x, Cfg())
    state = xlstm_mod.init_slstm_state(1, 16, 2)
    ys = []
    for t in range(8):
        y, state = xlstm_mod.slstm_decode(p, x[:, t:t + 1], state, Cfg())
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_train),
                               np.asarray(jnp.concatenate(ys, 1)),
                               atol=1e-4, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), top_k=st.sampled_from([1, 2]))
def test_moe_gate_weights_and_lb_loss(seed, top_k):
    key = jax.random.key(seed)
    e, d, f = 4, 8, 16
    p = moe_mod.init_moe(key, d, f, e)
    x = jax.random.normal(jax.random.key(seed + 1), (2, 6, d))
    y, lb = moe_mod.moe_apply(p, x, top_k=top_k, capacity_factor=2.0)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(lb))
    assert float(lb) >= 0.99  # E * sum f_e p_e >= 1 for any routing


def test_moe_capacity_drops_tokens():
    """With capacity_factor ~0, (almost) everything is dropped -> y ~ 0."""
    key = jax.random.key(0)
    p = moe_mod.init_moe(key, 8, 16, 4)
    x = jax.random.normal(jax.random.key(1), (2, 16, 8))
    y, _ = moe_mod.moe_apply(p, x, top_k=2, capacity_factor=1e-9)
    # capacity floor is top_k slots per expert; most tokens dropped
    y_full, _ = moe_mod.moe_apply(p, x, top_k=2, capacity_factor=4.0)
    assert float(jnp.abs(y).mean()) < float(jnp.abs(y_full).mean())
