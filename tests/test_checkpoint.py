import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3)),
                                        "d": jnp.asarray(3)}}
    path = str(tmp_path / "ckpt.npz")
    checkpoint.save(path, tree)
    out = checkpoint.restore(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
