"""Overload-safe serving: reservation accounting, admission
backpressure, preempt-and-requeue, deadlines, and the deterministic
fault-injection harness.

The contract under test: page-pool exhaustion is a recoverable
scheduling event, never a crash — and recovery is INVISIBLE in the
output.  A preempted-and-resumed request must emit exactly the greedy
tokens of an uncontended run (generated-so-far tokens fold into the
re-prefill prompt), injected allocation failures must leave the
allocator's books balanced, and deadline sheds must free every page the
victim held.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import serve as serve_mod
from repro.launch import traffic
from repro.models import model as M

PS = 64        # small pages keep pool pressure cheap to reach


def _cfg():
    return get_config("stablelm-1.6b").reduced()


def _copy_trace(trace):
    return [serve_mod.Request(
        rid=r.rid, prompt=np.asarray(r.prompt).copy(), max_new=r.max_new,
        arrival=r.arrival, deadline_ttft=r.deadline_ttft,
        deadline_total=r.deadline_total, max_retries=r.max_retries)
        for r in trace]


def _pressure_trace(vocab, *, n=4, seed=0):
    """Shared one-page prefix, distinct tails (rids 1,2 duplicate —
    their shared partial page COW-forks at first decode write), and
    generations long enough to cross into a third page."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, PS).astype(np.int32)
    dup_tail = rng.integers(0, vocab, 9).astype(np.int32)
    out = []
    for rid in range(n):
        tail = dup_tail if rid in (1, 2) else rng.integers(
            0, vocab, 5 + (rid % 3) * 6).astype(np.int32)
        # uniform max_new keeps co-admitted slots in flight together, so
        # the later page-boundary crossing finds the pool already drained
        out.append(serve_mod.Request(
            rid=rid, prompt=np.concatenate([shared, tail]),
            max_new=PS, arrival=0.0))
    return out


def _drive_robust(eng, trace, max_iters=5000):
    """The engine's own scheduling loop, inlined: enqueue everything,
    then alternate schedule/admit/decode, advancing the (virtual) clock
    only when idle with pending backoff entries."""
    eng.start_clock()
    for r in trace:
        eng.enqueue(r)
    expect = len(eng.queue) + sum(r is not None for r in eng.req_of)
    done = []
    for _ in range(max_iters):
        if len(done) + len(eng.shed_requests) >= expect:
            return done
        now = eng.now()
        done.extend(eng.admit(eng.schedule_admissions(now), now))
        if any(r is not None for r in eng.req_of):
            done.extend(eng.decode_step_all())
        elif eng.queue:
            nxt = min(r.eff_arrival for r in eng.queue)
            eng.advance(max(nxt - eng.now(), 1e-3))
        else:
            break
    raise AssertionError(
        f"engine wedged: {len(done)} done, {len(eng.shed_requests)} "
        f"shed, queue={len(eng.queue)} of {expect}")


def _assert_books_balanced(eng):
    """Post-drain allocator invariants: every page back on the free
    list exactly once, no refs, no reservations, sink untouched."""
    al = eng.alloc
    assert al.reserved == 0
    assert int(eng.resv_of.sum()) == 0
    assert al.used_pages == 0, f"leaked {al.used_pages} pages"
    assert len(set(al.free)) == len(al.free) == al.n_pages - 1
    assert 0 not in al.free
    assert all(int(r) >= 0 for r in al.ref)
    assert all(int(al.ref[p]) == 0 for p in range(1, al.n_pages))


# ---------------------------------------------------------------------------
# PageAllocator: try_alloc + reservation accounting
# ---------------------------------------------------------------------------

def test_allocator_reservation_accounting():
    al = serve_mod.PageAllocator(5)          # 4 usable
    assert not al.reserve(5)                 # over capacity: refused...
    assert al.reserved == 0                  # ...with no side effect
    assert al.reserve(3)
    assert al.free_unreserved == 1
    p1 = al.try_alloc()                      # optimistic headroom: 1 page
    assert p1 is not None
    assert al.try_alloc() is None            # free == reserved: held back
    p2 = al.try_alloc(reserved=True)         # reserved units still flow
    assert p2 is not None and al.reserved == 2
    assert not al.reserve(1)                 # free 2 - reserved 2 == 0
    al.unreserve(2)
    with pytest.raises(RuntimeError, match="exceeds outstanding"):
        al.unreserve(1)
    with pytest.raises(RuntimeError, match="out of sync"):
        al.try_alloc(reserved=True)          # no reservation to consume
    assert al.high_water == 2
    while al.try_alloc() is not None:
        pass
    with pytest.raises(RuntimeError, match="page pool exhausted"):
        al.alloc()                           # legacy surface still raises
    assert al.high_water == 4
    al.decref(p1)
    assert al.high_water == 4                # high-water never recedes
    assert p1 in al.free


# ---------------------------------------------------------------------------
# S2: trace validation rejects can-never-fit requests
# ---------------------------------------------------------------------------

def test_validate_trace_worst_case_page_demand():
    big = serve_mod.Request(rid=0, prompt=np.zeros(100, np.int32),
                            max_new=92, arrival=0.0)
    # ceil(192 / 64) -> 3 pages: the largest that fits 3 usable
    serve_mod._validate_trace([big], 192, page_size=PS, usable_pages=3)
    with pytest.raises(ValueError, match="can never be served"):
        serve_mod._validate_trace([big], 192, page_size=PS,
                                  usable_pages=2)
    # unpaged engines skip the page check entirely
    serve_mod._validate_trace([big], 192)


def test_reservation_capacity_model():
    cap = traffic.reservation_capacity(n_pages=7, page_size=PS,
                                       prompt_tokens=PS + 22, max_new=PS,
                                       shared_tokens=PS)
    assert cap["usable_pages"] == 6
    assert cap["shared_pages"] == 1
    assert cap["worst_case_pages_per_req"] == 3
    assert cap["optimistic_pages_per_req"] == 2
    # shared page costs the pool once: 1 + 2k <= 6 -> 2 ... 1 + k <= 6 -> 5
    assert cap["slots_reserve"] == 2
    assert cap["slots_optimistic"] == 5
    assert cap["overcommit_ratio"] == pytest.approx(2.5)


# ---------------------------------------------------------------------------
# S1: mid-admission allocation failure unwinds cleanly
# ---------------------------------------------------------------------------

def test_admission_unwind_restores_refcounts():
    """A 2-page prompt whose SECOND page allocation fails (injected at
    global call index 1) must unwind the first: refcounts back to the
    pre-admission state, reservation released, request requeued — and a
    clean retry then produces the uncontended tokens."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    kw = dict(n_slots=2, cache_len=128, chunk=64, sample=False, seed=0,
              page_size=PS)
    trace = [serve_mod.Request(rid=0,
                               prompt=np.arange(70, dtype=np.int32) % 97,
                               max_new=6, arrival=0.0)]
    plan = serve_mod.FaultPlan(fail_alloc_at=frozenset({1}))
    eng = serve_mod.ServeEngine(cfg, params, fault_plan=plan,
                                clock=lambda: 0.0, **kw)
    assert eng.paged
    eng.enqueue(trace[0])
    pairs = eng.schedule_admissions(0.0)
    assert len(pairs) == 1 and eng.alloc.reserved == 2
    done = eng.admit(pairs, 0.0)
    assert done == [] and eng.injected_alloc_failures == 1
    assert eng.admission_alloc_failures == 1 and eng.requeues == 1
    assert list(eng.queue) == [trace[0]]          # requeued, not lost
    assert eng.alloc.used_pages == 0              # partial row unwound
    assert eng.alloc.reserved == 0                # reservation released
    assert (eng.pt_host == -1).all()
    assert eng.pages_requested == 0               # dedup stats unwound too
    done = _drive_robust(eng, [])                 # already enqueued
    assert [r.rid for r in done] == [0]
    _assert_books_balanced(eng)

    clean = _copy_trace(trace)
    eng2 = serve_mod.ServeEngine(cfg, params, clock=lambda: 0.0, **kw)
    _drive_robust(eng2, clean)
    assert list(trace[0].tokens) == list(clean[0].tokens)


# ---------------------------------------------------------------------------
# tentpole: preempt-and-requeue under page pressure, token identity
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_preemption_token_identity():
    """Optimistic admission on an undersized pool: decode growth
    exhausts the pool, slots preempt and requeue, and every request
    still emits the exact greedy tokens of an ample-pool run.  Reserve
    admission on the same pool never needs preemption at all."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    trace = _pressure_trace(cfg.vocab_size, n=4)
    kw = dict(n_slots=2, cache_len=3 * PS, chunk=PS, sample=False,
              seed=0, page_size=PS, clock=lambda: 0.0)

    ample = _copy_trace(trace)
    eng = serve_mod.ServeEngine(cfg, params, **kw)       # 7 pages
    _drive_robust(eng, ample)
    assert eng.preemptions == 0
    want = {r.rid: list(r.tokens) for r in ample}
    assert all(len(t) for t in want.values())

    tight = _copy_trace(trace)
    eng = serve_mod.ServeEngine(cfg, params, n_pages=5,
                                admission="optimistic", **kw)
    _drive_robust(eng, tight)
    assert eng.preemptions >= 1 and eng.requeues >= 1
    assert any(r.preemptions > 0 for r in tight)
    assert {r.rid: list(r.tokens) for r in tight} == want
    assert not eng.shed_requests
    assert eng.alloc.high_water <= 4
    _assert_books_balanced(eng)

    resv = _copy_trace(trace)
    eng = serve_mod.ServeEngine(cfg, params, n_pages=5,
                                admission="reserve", **kw)
    _drive_robust(eng, resv)
    assert eng.preemptions == 0          # worst case reserved up front
    assert {r.rid: list(r.tokens) for r in resv} == want
    _assert_books_balanced(eng)


# ---------------------------------------------------------------------------
# S3: every exhaustion edge under an injected FaultPlan
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fault_injection_preserves_tokens_and_books():
    """A dense random FaultPlan (injected try_alloc failures across
    admission mapping, decode growth and COW forks, forced preemptions,
    virtual latency, standing pool pressure) may slow the run down but
    must not change its output: all requests complete, greedy tokens
    match the fault-free run, and the allocator's books balance."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    trace = _pressure_trace(cfg.vocab_size, n=4, seed=1)
    kw = dict(n_slots=2, cache_len=3 * PS, chunk=PS, sample=False,
              seed=0, page_size=PS, clock=lambda: 0.0)

    clean = _copy_trace(trace)
    eng = serve_mod.ServeEngine(cfg, params, **kw)    # reserve policy
    _drive_robust(eng, clean)
    want = {r.rid: list(r.tokens) for r in clean}

    # explicit indices so every edge fires deterministically: calls 1/3
    # hit admission mapping (fresh alloc + prefix-miss retry), later
    # ones land in decode growth and COW forks; steps 6/40 force
    # preemptions mid-decode; steps 3/10 inject virtual latency
    plan = serve_mod.FaultPlan(
        fail_alloc_at=frozenset({1, 3, 8, 15, 22, 30}),
        preempt_at=(6, 40), latency_at=((3, 0.2), (10, 0.1)),
        hold_pages=1)
    faulted = _copy_trace(trace)
    eng = serve_mod.ServeEngine(cfg, params, fault_plan=plan,
                                admission="optimistic", **kw)
    assert eng.usable_pages == eng.n_pages - 2       # standing pressure
    _drive_robust(eng, faulted)
    assert eng.injected_alloc_failures >= 1          # plan actually bit
    assert eng.forced_preemptions >= 1
    assert eng.now() > 0.0                           # latency injected
    assert not eng.shed_requests
    assert {r.rid: list(r.tokens) for r in faulted} == want
    al = eng.alloc
    assert al.reserved == 0 and al.used_pages == len(eng._fault_held)
    assert not set(al.free) & set(eng._fault_held)
    eng.reset()
    assert al is not eng.alloc and eng.alloc.reserved == 0


# ---------------------------------------------------------------------------
# deadlines: TTFT shed + bounded retry, total-deadline mid-flight shed
# ---------------------------------------------------------------------------

def test_ttft_deadline_shed_and_retry():
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    eng = serve_mod.ServeEngine(cfg, params, n_slots=1, cache_len=128,
                                chunk=64, sample=False, seed=0,
                                page_size=PS, clock=lambda: 0.0,
                                retry_backoff=0.05)
    lag = serve_mod.Request(rid=1, prompt=np.zeros(8, np.int32),
                            max_new=4, arrival=0.0, deadline_ttft=0.5,
                            max_retries=1)
    eng.enqueue(lag)
    # scheduled late (e.g. slots were busy): TTFT already blown -> shed,
    # retried with exponential backoff, TTFT clock restarted
    assert eng.schedule_admissions(2.0) == []
    assert eng.retries == 1 and lag.retry_count == 1
    assert lag.eff_arrival == pytest.approx(2.05)
    assert list(eng.queue) == [lag]
    # backoff pending: skipped without blocking the line
    assert eng.schedule_admissions(2.01) == []
    assert not eng.shed_requests
    # second miss: retries exhausted -> terminal shed
    assert eng.schedule_admissions(5.0) == []
    assert eng.shed_requests == [lag]
    assert lag.shed_reason == "ttft-deadline"
    assert eng.sheds_admission == 2 and not eng.queue
    # queue-depth samples feed the report percentiles
    assert len(eng.queue_depths) == 3
    # a request scheduled in time admits normally under the same deadline
    ok = serve_mod.Request(rid=2, prompt=np.zeros(8, np.int32),
                           max_new=2, arrival=5.0, deadline_ttft=0.5)
    eng.enqueue(ok)
    pairs = eng.schedule_admissions(5.1)
    assert [r.rid for r, _ in pairs] == [2]


def test_total_deadline_sheds_mid_flight():
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    eng = serve_mod.ServeEngine(cfg, params, n_slots=1, cache_len=128,
                                chunk=64, sample=False, seed=0,
                                page_size=PS, clock=lambda: 0.0)
    eng.start_clock()
    req = serve_mod.Request(rid=0, prompt=np.zeros(8, np.int32),
                            max_new=50, arrival=0.0, deadline_total=0.5)
    eng.enqueue(req)
    assert eng.admit(eng.schedule_admissions(0.0), 0.0) == []
    eng.decode_step_all()
    n_before = len(req.tokens)
    assert n_before >= 1 and req.shed_reason is None
    eng.advance(1.0)                      # virtual: deadline now blown
    # the step in flight still lands its token, then the slot sheds
    assert eng.decode_step_all() == []    # shed, not finished
    assert req.shed_reason == "total-deadline"
    assert eng.sheds_decode == 1 and eng.shed_requests == [req]
    assert len(req.tokens) == n_before + 1
    assert req.t_done == pytest.approx(1.0)
    assert eng.req_of[0] is None
    _assert_books_balanced(eng)           # victim's pages all came back


# ---------------------------------------------------------------------------
# FaultPlan determinism + serialization
# ---------------------------------------------------------------------------

def test_fault_plan_semantics_and_roundtrip():
    plan = serve_mod.FaultPlan(fail_alloc_at=frozenset({2, 7}),
                               preempt_at=(5, 5, 9),
                               latency_at=((3, 0.5), (3, 0.25), (4, 0.1)),
                               hold_pages=2)
    assert plan.alloc_fails(2) and not plan.alloc_fails(3)
    assert plan.forced_preempts(5) == 2 and plan.forced_preempts(6) == 0
    assert plan.step_latency(3) == pytest.approx(0.75)
    assert plan.step_latency(99) == 0.0
    back = serve_mod.FaultPlan.from_json(plan.to_json())
    assert back == plan
    json.loads(plan.to_json())            # valid JSON, CLI-pasteable
    assert serve_mod.FaultPlan.random(3) == serve_mod.FaultPlan.random(3)
    assert serve_mod.FaultPlan.random(3) != serve_mod.FaultPlan.random(4)
