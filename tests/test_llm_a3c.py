"""Token-level A3C loss tests (the LLM-scale algorithm layer)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import llm_a3c
from repro.core.returns import n_step_returns
from repro.data.pipeline import TokenPipeline
from repro.models import model as M
from repro.optim import optimizers as opt_mod


def test_loss_components_finite_and_aux_for_moe():
    cfg = get_config("granite-moe-1b-a400m").reduced()
    params = M.init_params(cfg, jax.random.key(0))
    pipe = TokenPipeline(vocab=cfg.vocab_size, seq_len=32, global_batch=2)
    batch = pipe.batch(jax.random.key(1))
    loss, m = llm_a3c.a3c_token_loss(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    assert float(m["aux"]) > 0.0   # MoE load-balance loss present


def test_training_reduces_loss():
    cfg = get_config("stablelm-1.6b").reduced()
    params = M.init_params(cfg, jax.random.key(0))
    opt = opt_mod.shared_rmsprop()
    opt_state = opt.init(params)
    step = jax.jit(llm_a3c.make_train_step(cfg, opt, lr0=3e-3,
                                           total_steps=10**9))
    pipe = TokenPipeline(vocab=cfg.vocab_size, seq_len=64, global_batch=4)
    losses = []
    for i in range(30):
        batch = pipe.batch(jax.random.key(42), i % 2)  # small data reuse
        params, opt_state, metrics = step(params, opt_state, batch,
                                          jnp.asarray(i))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_returns_computed_over_sequence_axis():
    """The loss's internal returns must equal n_step_returns on the seq
    axis (spot-check via a linear-value model contrivance)."""
    r = jnp.array([[1.0, 0.0, 1.0, 0.0]])
    d = jnp.full((1, 4), 0.5)
    boot = jnp.array([2.0])
    rets = n_step_returns(jnp.moveaxis(r, 1, 0), jnp.moveaxis(d, 1, 0), boot)
    rets = jnp.moveaxis(rets, 0, 1)
    # R3 = 0 + .5*2 = 1; R2 = 1+.5 = 1.5; R1 = .75; R0 = 1.375
    np.testing.assert_allclose(rets[0], [1.375, 0.75, 1.5, 1.0])
