"""Per-slot decode attention: every batch row at its own decode depth.

The continuous-batching engine decodes a slot table where a just-admitted
request (pos = its prompt length) sits next to sequences thousands of
tokens deep and next to drained slots.  These tests sweep ragged ``pos
(B,)`` / ``kpos (B, L)`` through every dispatch arm against the jnp
oracle; the multi-device arms need
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` (CI's host-mesh
leg).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import ctx
from repro.kernels import dispatch, ref

KEY = jax.random.key(7)
MULTI = len(jax.devices()) >= 2


def _ragged_kpos(pos, length):
    idx = jnp.arange(length)
    return jnp.where(idx[None, :] <= pos[:, None], idx[None, :], -1)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize(
    "b,length,hq,hkv,d,poss",
    [
        # just-admitted (0), mid-stream, cache-full (L-1 = finished depth)
        (3, 256, 8, 2, 64, (0, 130, 255)),          # GQA g=4
        (2, 512, 4, 4, 64, (17, 400)),              # MHA
        (4, 128, 4, 1, 128, (0, 1, 64, 127)),       # MQA, wide head
    ])
def test_perslot_parity(backend, b, length, hq, hkv, d, poss):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    kc = jax.random.normal(ks[1], (b, length, hkv, d))
    vc = jax.random.normal(ks[2], (b, length, hkv, d))
    pos = jnp.asarray(poss, jnp.int32)
    kpos = _ragged_kpos(pos, length)
    out = dispatch.decode_attention(q, kc, vc, kpos, pos, backend=backend)
    want = ref.decode_attention_ref(q, kc, vc, kpos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_perslot_ring_kpos():
    """Per-row ring-buffer kpos: each slot map rotated by its own pos."""
    b, length, h, d = 3, 256, 4, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d))
    kc = jax.random.normal(ks[1], (b, length, h, d))
    vc = jax.random.normal(ks[2], (b, length, h, d))
    pos = jnp.asarray([1000, 300, 255], jnp.int32)
    idx = jnp.arange(length)
    cand = pos[:, None] - (pos[:, None] % length) + idx[None, :]
    cand = jnp.where(cand > pos[:, None], cand - length, cand)
    kpos = jnp.where(cand >= 0, cand, -1)
    out = dispatch.decode_attention(q, kc, vc, kpos, pos, backend="pallas")
    want = ref.decode_attention_ref(q, kc, vc, kpos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_lockstep_is_thin_wrapper():
    """Scalar pos / (L,) kpos must produce bit-identical results to the
    broadcast per-slot layout (existing train/dryrun callers untouched)."""
    b, length, hq, hkv, d = 2, 256, 8, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    kc = jax.random.normal(ks[1], (b, length, hkv, d))
    vc = jax.random.normal(ks[2], (b, length, hkv, d))
    pos = jnp.asarray(100, jnp.int32)
    kpos = jnp.where(jnp.arange(length) <= pos, jnp.arange(length), -1)
    a = dispatch.decode_attention(q, kc, vc, kpos, pos, backend="pallas")
    bcast = dispatch.decode_attention(
        q, kc, vc, jnp.broadcast_to(kpos, (b, length)),
        jnp.full((b,), 100, jnp.int32), backend="pallas")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(bcast))


@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
def test_perslot_shard_map_parity():
    """(batch, heads) shard_map arm with ragged pos, batch on 'data'."""
    mesh = jax.make_mesh((2, 1), ("data", "model"))
    ks = jax.random.split(KEY, 3)
    b, length, hq, hkv, d = 4, 512, 4, 2, 64
    q = jax.random.normal(ks[0], (b, hq, d))
    kc = jax.random.normal(ks[1], (b, length, hkv, d))
    vc = jax.random.normal(ks[2], (b, length, hkv, d))
    pos = jnp.asarray([0, 511, 300, 64], jnp.int32)
    kpos = _ragged_kpos(pos, length)
    with ctx.use_mesh(mesh):
        dispatch.clear_decision_log()
        out = jax.jit(lambda *a: dispatch.decode_attention(*a))(
            q, kc, vc, kpos, pos)
        assert dispatch.last_decision("decode_attention").backend == \
            "pallas_shard_map"
    want = ref.decode_attention_ref(q, kc, vc, kpos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
def test_perslot_pallas_cp_parity():
    """Seq-sharded cache: the pallas_cp combine with ragged per-slot pos —
    a freshly-admitted row whose whole second shard is masked must coexist
    with a deep row that reads both shards."""
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    ks = jax.random.split(KEY, 3)
    b, length, hq, hkv, d = 2, 512, 8, 2, 64     # GQA g=4
    q = jax.random.normal(ks[0], (b, hq, d))
    kc = jax.random.normal(ks[1], (b, length, hkv, d))
    vc = jax.random.normal(ks[2], (b, length, hkv, d))
    pos = jnp.asarray([5, 501], jnp.int32)
    kpos = _ragged_kpos(pos, length)
    rules = {"decode_cp": {"mesh": mesh, "seq_axes": ("model",),
                           "dp_axes": ("data",), "n_shards": 2}}
    with ctx.sharding_rules(rules):
        dispatch.clear_decision_log()
        out = jax.jit(lambda *a: dispatch.decode_attention(*a))(
            q, kc, vc, kpos, pos)
        d_ = dispatch.last_decision("decode_attention")
        assert d_.backend == "pallas_cp", d_
    want = ref.decode_attention_ref(q, kc, vc, kpos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
