"""Pin every assigned architecture config to its exact assigned spec."""
import pytest

from repro.configs import ALIASES, get_config

SPEC = {
    # arch: (L, d_model, H, kv, d_ff, vocab, family)
    "qwen2-72b": (80, 8192, 64, 8, 29568, 152064, "dense"),
    "minicpm-2b": (40, 2304, 36, 36, 5760, 122753, "dense"),
    "yi-6b": (32, 4096, 32, 4, 11008, 64000, "dense"),
    "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155, "moe"),
    "whisper-base": (6, 512, 8, 8, 2048, 51865, "audio"),
    "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000, "hybrid"),
    "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304, "ssm"),
    "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048, "moe"),
    "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064, "vlm"),
    "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352, "dense"),
}


@pytest.mark.parametrize("arch", list(SPEC))
def test_exact_assigned_config(arch):
    cfg = get_config(arch)
    L, d, h, kv, dff, v, fam = SPEC[arch]
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    assert (cfg.d_ff or cfg.d_ff_expert) == dff
    assert cfg.vocab_size == v
    assert cfg.family == fam
    assert cfg.source  # citation present


def test_moe_specifics():
    g = get_config("granite-moe-1b-a400m")
    assert g.n_experts == 32 and g.top_k == 8
    l4 = get_config("llama4-scout-17b-a16e")
    assert l4.n_experts == 16 and l4.top_k == 1
    assert l4.block_cycle.count("attn_local") == 3  # iRoPE 3:1


def test_qwen2_qkv_bias_and_mrope():
    assert get_config("qwen2-72b").qkv_bias
    assert get_config("qwen2-vl-72b").mrope_sections == (16, 24, 24)


def test_zamba2_hybrid_structure():
    z = get_config("zamba2-1.2b")
    assert z.shared_attn_every == 6 and z.ssm_state == 64


def test_stablelm_partial_rotary():
    assert get_config("stablelm-1.6b").rotary_dim == 16  # 25% of hd 64


def test_whisper_encdec():
    w = get_config("whisper-base")
    assert w.is_encdec and w.encoder_layers == 6 and w.encoder_seq == 1500


def test_all_archs_have_reduced_variants():
    for arch in ALIASES:
        r = get_config(arch).reduced()
        assert r.n_layers <= 4 and r.d_model <= 512
        assert r.n_experts <= 4
