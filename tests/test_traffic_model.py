"""Analytic HBM-traffic model sanity (roofline memory term)."""
import pytest

from repro.configs import get_config
from repro.launch import traffic


def test_train_traffic_dominated_by_optimizer_and_params():
    cfg = get_config("stablelm-1.6b")
    per_chip = traffic.hbm_bytes(cfg, "train_4k", "train", 256)
    p = cfg.param_count()
    assert per_chip > 30 * p / 256  # at least the param/optimizer traffic


def test_decode_traffic_scales_with_cache():
    cfg = get_config("qwen2-72b")
    small = traffic.hbm_bytes(cfg, "decode_32k", "decode", 256)
    # long_500k has batch 1 but 16x the seq: cache term differs
    big = traffic.cache_bytes(cfg, 128, 32_768)
    assert big > 0
    assert small >= (2 * cfg.param_count()) / 256


def test_decode_cp_combine_is_tiny_vs_cache_gather():
    """The flash-decoding (m, l, acc) psum must move orders of magnitude
    fewer bytes than the cache all-gather it replaces, and scale linearly
    in the shard count."""
    cfg = get_config("qwen2-72b")
    b, seq, shards = 128, 32_768, 16
    combine = traffic.decode_cp_combine_bytes(cfg, b, shards)
    n_attn = sum(1 for k in cfg.layer_kinds()
                 if k in ("attn", "attn_local"))
    assert combine == n_attn * b * cfg.n_heads * (cfg.hd + 2) * 4 * shards
    assert combine == 2 * traffic.decode_cp_combine_bytes(cfg, b,
                                                          shards // 2)
    # vs gathering the cache onto every shard once per token
    gather = traffic.cache_bytes(cfg, b, seq) * (shards - 1)
    assert combine < gather / 1000


def test_sw_variant_cache_is_sublinear():
    from repro.launch import specs as specs_mod
    cfg = get_config("qwen2-72b")
    sw = specs_mod.sliding_window_variant(cfg)
    full = traffic.cache_bytes(cfg, 1, 524_288)
    ring = traffic.cache_bytes(sw, 1, 524_288)
    assert ring < full / 32   # ring buffers: window/seq = 1/64


def test_prefill_attn_bytes_fused_vs_masked():
    """The append kernel removes the masked path's f32 score
    materialization and Hq-repeated K/V streams; the attention-term
    traffic ratio must grow with prompt length (the quadratic score term)
    and the fused term must stay linear in Sk per chunk."""
    cfg = get_config("qwen2-72b")
    masked = traffic.prefill_attn_bytes(cfg, 1, 2048, 128, fused=False)
    fused = traffic.prefill_attn_bytes(cfg, 1, 2048, 128, fused=True)
    assert fused < masked / 2      # the BENCH_prefill acceptance ratio
    r_short = traffic.prefill_attn_bytes(cfg, 1, 512, 128, fused=False) \
        / traffic.prefill_attn_bytes(cfg, 1, 512, 128, fused=True)
    assert masked / fused > r_short
