"""Mesh-aware kernel dispatch: resolution, lowering, and parity.

The shard_map tests need a multi-device host; CI runs a matrix leg with
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` so they execute on
every PR (they skip on a plain single-device run).  The full GQA x mask
parity sweep carries the ``slow`` marker; one case per mesh orientation
stays fast.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import ctx
from repro.kernels import dispatch, ref
from repro.models import attention as attn
from repro.models.flash_jnp import flash_attention_jnp

MULTI = len(jax.devices()) >= 2
KEY = jax.random.key(7)


class _Cfg:
    n_heads, n_kv_heads, head_dim = 4, 2, 64
    rope_theta = 10000.0


def _qkv(b, s, hq, hkv, d, dtype=jnp.float32):
    ks = jax.random.split(KEY, 4)
    return (jax.random.normal(ks[0], (b, s, hq, d), dtype),
            jax.random.normal(ks[1], (b, s, hkv, d), dtype),
            jax.random.normal(ks[2], (b, s, hkv, d), dtype),
            jax.random.normal(ks[3], (b, s, hq, d), dtype))


# ---------------------------------------------------------------------------
# resolution + fallback reasons (single-device)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(jax.default_backend() != "cpu", reason="cpu-only check")
def test_auto_cpu_single_device_picks_jnp_with_reason():
    q, k, v, _ = _qkv(1, 256, 4, 2, 64)
    dispatch.clear_decision_log()
    out = dispatch.flash_attention(q, k, v, causal=True)
    d = dispatch.last_decision("flash_attention")
    assert d.backend == "jnp"
    assert "interpret-only" in d.reason
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_auto_misaligned_seq_records_reason():
    q, k, v, _ = _qkv(1, 192, 4, 2, 64)
    dispatch.clear_decision_log()
    dispatch.flash_attention(q, k, v, causal=True)
    d = dispatch.last_decision("flash_attention")
    assert d.backend == "jnp"
    assert "MXU-aligned" in d.reason


def test_rules_without_mesh_fall_back():
    from jax.sharding import PartitionSpec as P
    q, k, v, _ = _qkv(1, 256, 4, 2, 64)
    dispatch.clear_decision_log()
    with ctx.sharding_rules({"residual": P()}):
        dispatch.flash_attention(q, k, v, causal=True)
    d = dispatch.last_decision("flash_attention")
    assert d.backend == "jnp"
    assert "without a dispatch mesh" in d.reason


def test_decision_summary_feeds_hlo_analysis():
    from repro.launch import hlo_analysis
    q, k, v, _ = _qkv(1, 192, 4, 2, 64)
    dispatch.clear_decision_log()
    dispatch.flash_attention(q, k, v, causal=True)
    summ = hlo_analysis.kernel_dispatch_summary()
    assert any(r["op"] == "flash_attention" and r["backend"] == "jnp"
               and "MXU-aligned" in r["reason"] for r in summ)


# ---------------------------------------------------------------------------
# lowering inspection (>= 2 host devices)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
@pytest.mark.parametrize("mesh_shape", [(2, 1), (1, 2)])
def test_attend_train_auto_lowers_shard_map_pallas(mesh_shape):
    """backend="auto" under a mesh: attend_train must lower through the
    shard_map'd Pallas kernel (asserted on the lowered module), and fall
    back to jnp with a recorded reason when no mesh is installed."""
    cfg = _Cfg()
    params = attn.init_attention(jax.random.key(0), 256, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.head_dim)
    x = jax.random.normal(KEY, (2, 256, 256))

    def fn(x):
        return attn.attend_train(params, x, None, None, cfg,
                                 use_rope=False)

    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    with ctx.use_mesh(mesh):
        dispatch.clear_decision_log()
        lowered = jax.jit(fn).lower(x)
        d = dispatch.last_decision("flash_attention")
        assert d.backend == "pallas_shard_map", d
        assert "shmap_body" in lowered.as_text()
        assert "shard_map" in str(jax.make_jaxpr(fn)(x))

    # fresh closure: dispatch resolves at trace time, and jax caches traces
    # by function identity — reusing ``fn`` would replay the mesh lowering
    def fn2(x):
        return attn.attend_train(params, x, None, None, cfg,
                                 use_rope=False)

    dispatch.clear_decision_log()
    lowered = jax.jit(fn2).lower(x)
    d = dispatch.last_decision("flash_attention")
    assert d.backend == "jnp" and d.reason
    assert "shmap_body" not in lowered.as_text()


@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
def test_auto_mesh_indivisible_heads_falls_back():
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    q, k, v, _ = _qkv(1, 256, 3, 3, 64)    # 3 heads on a 2-way model axis
    with ctx.use_mesh(mesh):
        dispatch.clear_decision_log()
        out = dispatch.flash_attention(q, k, v, causal=True)
    d = dispatch.last_decision("flash_attention")
    assert d.backend == "jnp"
    assert "do not divide" in d.reason
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# parity: shard_map'd Pallas vs jnp oracle (fwd + grads)
# ---------------------------------------------------------------------------

def _parity_case(mesh_shape, b, s, hq, hkv, d, window, causal, dtype):
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    q, k, v, do = _qkv(b, s, hq, hkv, d, dtype)

    def loss_sharded(q, k, v):
        o = dispatch.flash_attention(q, k, v, causal=causal, window=window)
        return jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32))

    def loss_ref(q, k, v):
        o = flash_attention_jnp(q, k, v, causal, window, 128)
        return jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32))

    with ctx.use_mesh(mesh):
        dispatch.clear_decision_log()
        o = jax.jit(lambda q, k, v: dispatch.flash_attention(
            q, k, v, causal=causal, window=window))(q, k, v)
        assert dispatch.last_decision("flash_attention").backend == \
            "pallas_shard_map"
        g_sh = jax.jit(jax.grad(loss_sharded, argnums=(0, 1, 2)))(q, k, v)
    want = flash_attention_jnp(q, k, v, causal, window, 128)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for got, want_g, name in zip(g_sh, g_ref, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want_g, np.float32),
                                   atol=tol, rtol=tol, err_msg=name)


@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
@pytest.mark.parametrize("mesh_shape,window", [((2, 1), None),
                                               ((1, 2), 128)])
def test_sharded_parity_fast(mesh_shape, window):
    """One causal-GQA case per mesh orientation (data- and head-sharded)."""
    _parity_case(mesh_shape, 2, 256, 4, 2, 64, window, True, jnp.float32)


@pytest.mark.slow
@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "mesh_shape,b,s,hq,hkv,d,window,causal",
    [
        ((2, 1), 2, 256, 4, 1, 64, None, True),    # GQA g=4, data-sharded
        ((1, 2), 2, 512, 8, 2, 64, None, True),    # GQA g=4, head-sharded
        ((1, 2), 1, 512, 4, 2, 64, 256, True),     # GQA + sliding window
        ((2, 2) if len(jax.devices()) >= 4 else (2, 1),
         2, 256, 4, 2, 64, 128, True),             # window, (both axes)
        ((1, 2), 1, 256, 2, 2, 64, None, False),   # bidirectional MHA
    ])
def test_sharded_parity_sweep(mesh_shape, b, s, hq, hkv, d, window, causal,
                              dtype):
    _parity_case(mesh_shape, b, s, hq, hkv, d, window, causal, dtype)


# ---------------------------------------------------------------------------
# decode under a mesh
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
def test_sharded_decode_parity():
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    ks = jax.random.split(KEY, 3)
    b, length, hq, hkv, d = 2, 512, 4, 2, 64
    q = jax.random.normal(ks[0], (b, hq, d))
    kc = jax.random.normal(ks[1], (b, length, hkv, d))
    vc = jax.random.normal(ks[2], (b, length, hkv, d))
    pos = jnp.asarray(300, jnp.int32)
    kpos = jnp.where(jnp.arange(length) <= pos, jnp.arange(length), -1)
    with ctx.use_mesh(mesh):
        dispatch.clear_decision_log()
        out = jax.jit(lambda *a: dispatch.decode_attention(*a))(
            q, kc, vc, kpos, pos)
        assert dispatch.last_decision("decode_attention").backend == \
            "pallas_shard_map"
    want = ref.decode_attention_ref(q, kc, vc, kpos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
