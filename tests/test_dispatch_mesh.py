"""Mesh-aware kernel dispatch: resolution, lowering, and parity.

The shard_map tests need a multi-device host; CI runs a matrix leg with
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` so they execute on
every PR (they skip on a plain single-device run).  The full GQA x mask
parity sweep carries the ``slow`` marker; one case per mesh orientation
stays fast.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import ctx
from repro.kernels import dispatch, ref
from repro.models import attention as attn
from repro.models.flash_jnp import flash_attention_jnp

MULTI = len(jax.devices()) >= 2
KEY = jax.random.key(7)


class _Cfg:
    n_heads, n_kv_heads, head_dim = 4, 2, 64
    rope_theta = 10000.0


def _qkv(b, s, hq, hkv, d, dtype=jnp.float32):
    ks = jax.random.split(KEY, 4)
    return (jax.random.normal(ks[0], (b, s, hq, d), dtype),
            jax.random.normal(ks[1], (b, s, hkv, d), dtype),
            jax.random.normal(ks[2], (b, s, hkv, d), dtype),
            jax.random.normal(ks[3], (b, s, hq, d), dtype))


# ---------------------------------------------------------------------------
# resolution + fallback reasons (single-device)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(jax.default_backend() != "cpu", reason="cpu-only check")
def test_auto_cpu_single_device_picks_jnp_with_reason():
    q, k, v, _ = _qkv(1, 256, 4, 2, 64)
    dispatch.clear_decision_log()
    out = dispatch.flash_attention(q, k, v, causal=True)
    d = dispatch.last_decision("flash_attention")
    assert d.backend == "jnp"
    assert "interpret-only" in d.reason
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_auto_misaligned_seq_records_reason():
    q, k, v, _ = _qkv(1, 192, 4, 2, 64)
    dispatch.clear_decision_log()
    dispatch.flash_attention(q, k, v, causal=True)
    d = dispatch.last_decision("flash_attention")
    assert d.backend == "jnp"
    assert "MXU-aligned" in d.reason


def test_rules_without_mesh_fall_back():
    from jax.sharding import PartitionSpec as P
    q, k, v, _ = _qkv(1, 256, 4, 2, 64)
    dispatch.clear_decision_log()
    with ctx.sharding_rules({"residual": P()}):
        dispatch.flash_attention(q, k, v, causal=True)
    d = dispatch.last_decision("flash_attention")
    assert d.backend == "jnp"
    assert "without a dispatch mesh" in d.reason


def test_decision_summary_feeds_hlo_analysis():
    from repro.launch import hlo_analysis
    q, k, v, _ = _qkv(1, 192, 4, 2, 64)
    dispatch.clear_decision_log()
    dispatch.flash_attention(q, k, v, causal=True)
    summ = hlo_analysis.kernel_dispatch_summary()
    assert any(r["op"] == "flash_attention" and r["backend"] == "jnp"
               and "MXU-aligned" in r["reason"] for r in summ)


# ---------------------------------------------------------------------------
# lowering inspection (>= 2 host devices)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
@pytest.mark.parametrize("mesh_shape", [(2, 1), (1, 2)])
def test_attend_train_auto_lowers_shard_map_pallas(mesh_shape):
    """backend="auto" under a mesh: attend_train must lower through the
    shard_map'd Pallas kernel (asserted on the lowered module), and fall
    back to jnp with a recorded reason when no mesh is installed."""
    cfg = _Cfg()
    params = attn.init_attention(jax.random.key(0), 256, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.head_dim)
    x = jax.random.normal(KEY, (2, 256, 256))

    def fn(x):
        return attn.attend_train(params, x, None, None, cfg,
                                 use_rope=False)

    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    jitted = jax.jit(fn)
    with ctx.use_mesh(mesh):
        dispatch.clear_decision_log()
        lowered = jitted.lower(x)
        d = dispatch.last_decision("flash_attention")
        assert d.backend == "pallas_shard_map", d
        assert "shmap_body" in lowered.as_text()
        assert "shard_map" in str(jax.make_jaxpr(fn)(x))

    # the SAME jitted callable re-lowered outside the mesh must re-resolve
    # (ctx folds a dispatch token into the jit cache key — without it jax
    # would replay the mesh trace by function identity)
    dispatch.clear_decision_log()
    lowered = jitted.lower(x)
    d = dispatch.last_decision("flash_attention")
    assert d.backend == "jnp" and d.reason
    assert "shmap_body" not in lowered.as_text()


@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
def test_auto_mesh_indivisible_heads_falls_back():
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    q, k, v, _ = _qkv(1, 256, 3, 3, 64)    # 3 heads on a 2-way model axis
    with ctx.use_mesh(mesh):
        dispatch.clear_decision_log()
        out = dispatch.flash_attention(q, k, v, causal=True)
    d = dispatch.last_decision("flash_attention")
    assert d.backend == "jnp"
    assert "do not divide" in d.reason
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# parity: shard_map'd Pallas vs jnp oracle (fwd + grads)
# ---------------------------------------------------------------------------

def _parity_case(mesh_shape, b, s, hq, hkv, d, window, causal, dtype):
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    q, k, v, do = _qkv(b, s, hq, hkv, d, dtype)

    def loss_sharded(q, k, v):
        o = dispatch.flash_attention(q, k, v, causal=causal, window=window)
        return jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32))

    def loss_ref(q, k, v):
        o = flash_attention_jnp(q, k, v, causal, window, 128)
        return jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32))

    with ctx.use_mesh(mesh):
        dispatch.clear_decision_log()
        o = jax.jit(lambda q, k, v: dispatch.flash_attention(
            q, k, v, causal=causal, window=window))(q, k, v)
        assert dispatch.last_decision("flash_attention").backend == \
            "pallas_shard_map"
        g_sh = jax.jit(jax.grad(loss_sharded, argnums=(0, 1, 2)))(q, k, v)
    want = flash_attention_jnp(q, k, v, causal, window, 128)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for got, want_g, name in zip(g_sh, g_ref, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want_g, np.float32),
                                   atol=tol, rtol=tol, err_msg=name)


@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
@pytest.mark.parametrize("mesh_shape,window", [((2, 1), None),
                                               ((1, 2), 128)])
def test_sharded_parity_fast(mesh_shape, window):
    """One causal-GQA case per mesh orientation (data- and head-sharded)."""
    _parity_case(mesh_shape, 2, 256, 4, 2, 64, window, True, jnp.float32)


@pytest.mark.slow
@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "mesh_shape,b,s,hq,hkv,d,window,causal",
    [
        ((2, 1), 2, 256, 4, 1, 64, None, True),    # GQA g=4, data-sharded
        ((1, 2), 2, 512, 8, 2, 64, None, True),    # GQA g=4, head-sharded
        ((1, 2), 1, 512, 4, 2, 64, 256, True),     # GQA + sliding window
        ((2, 2) if len(jax.devices()) >= 4 else (2, 1),
         2, 256, 4, 2, 64, 128, True),             # window, (both axes)
        ((1, 2), 1, 256, 2, 2, 64, None, False),   # bidirectional MHA
    ])
def test_sharded_parity_sweep(mesh_shape, b, s, hq, hkv, d, window, causal,
                              dtype):
    _parity_case(mesh_shape, b, s, hq, hkv, d, window, causal, dtype)


# ---------------------------------------------------------------------------
# decode under a mesh
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
def test_sharded_decode_parity():
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    ks = jax.random.split(KEY, 3)
    b, length, hq, hkv, d = 2, 512, 4, 2, 64
    q = jax.random.normal(ks[0], (b, hq, d))
    kc = jax.random.normal(ks[1], (b, length, hkv, d))
    vc = jax.random.normal(ks[2], (b, length, hkv, d))
    pos = jnp.asarray(300, jnp.int32)
    kpos = jnp.where(jnp.arange(length) <= pos, jnp.arange(length), -1)
    with ctx.use_mesh(mesh):
        dispatch.clear_decision_log()
        out = jax.jit(lambda *a: dispatch.decode_attention(*a))(
            q, kc, vc, kpos, pos)
        assert dispatch.last_decision("decode_attention").backend == \
            "pallas_shard_map"
    want = ref.decode_attention_ref(q, kc, vc, kpos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
def test_decode_shard_map_misaligned_is_logged_fallback():
    """Explicit backend="pallas_shard_map": non-divisible heads / misaligned
    cache length fall back to jnp with a logged reason instead of raising
    (serving batch/head counts vary per request)."""
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    ks = jax.random.split(KEY, 3)
    pos = jnp.asarray(100, jnp.int32)
    with ctx.use_mesh(mesh):
        # 3 heads on a 2-way model axis, batch 1 on a 1-way data axis
        q = jax.random.normal(ks[0], (1, 3, 64))
        kc = jax.random.normal(ks[1], (1, 256, 3, 64))
        vc = jax.random.normal(ks[2], (1, 256, 3, 64))
        kpos = jnp.where(jnp.arange(256) <= pos, jnp.arange(256), -1)
        dispatch.clear_decision_log()
        out = dispatch.decode_attention(q, kc, vc, kpos, pos,
                                        backend="pallas_shard_map")
        d = dispatch.last_decision("decode_attention")
        assert d.backend == "jnp"
        assert "explicit shard_map but" in d.reason
        want = ref.decode_attention_ref(q, kc, vc, kpos, pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

        # misaligned cache length (192): fallback, not ValueError
        kc2 = jax.random.normal(ks[1], (2, 192, 2, 64))
        vc2 = jax.random.normal(ks[2], (2, 192, 2, 64))
        q2 = jax.random.normal(ks[0], (2, 4, 64))
        kpos2 = jnp.where(jnp.arange(192) <= pos, jnp.arange(192), -1)
        dispatch.clear_decision_log()
        dispatch.decode_attention(q2, kc2, vc2, kpos2, pos,
                                  backend="pallas_shard_map")
        d = dispatch.last_decision("decode_attention")
        assert d.backend == "jnp" and "not MXU-aligned" in d.reason


# ---------------------------------------------------------------------------
# context-parallel (pallas_cp) decode: the unified flash-decoding path
# ---------------------------------------------------------------------------

def _cp_rule(mesh, seq_axes=("model",), dp_axes=("data",)):
    n = 1
    for a in seq_axes:
        n *= mesh.shape[a]
    return {"decode_cp": {"mesh": mesh, "seq_axes": tuple(seq_axes),
                          "dp_axes": tuple(dp_axes), "n_shards": n}}


@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
def test_decode_cp_pallas_parity():
    """Seq-sharded cache + GQA + ragged kpos: the pallas_cp combine must
    match the jnp oracle to <= 1e-5 and the decision must record it — the
    'context-parallel rules own the cache -> jnp' fallback is gone."""
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    ks = jax.random.split(KEY, 3)
    b, length, hq, hkv, d = 2, 512, 8, 2, 64     # GQA g=4
    q = jax.random.normal(ks[0], (b, hq, d))
    kc = jax.random.normal(ks[1], (b, length, hkv, d))
    vc = jax.random.normal(ks[2], (b, length, hkv, d))
    pos = jnp.asarray(300, jnp.int32)
    # ragged validity: every 3rd slot unwritten (ring-style holes)
    kpos = jnp.where((jnp.arange(length) % 3 != 0)
                     & (jnp.arange(length) <= pos), jnp.arange(length), -1)
    with ctx.sharding_rules(_cp_rule(mesh)):
        dispatch.clear_decision_log()
        out = jax.jit(lambda *a: dispatch.decode_attention(*a))(
            q, kc, vc, kpos, pos)
        d = dispatch.last_decision("decode_attention")
        assert d.backend == "pallas_cp", d
        assert "psum combine" in d.reason
    want = ref.decode_attention_ref(q, kc, vc, kpos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
def test_decode_cp_one_shard_fully_masked():
    """pos inside the first shard's slice: the second shard is all-masked
    (m = -inf) and must vanish in the combine, not poison it."""
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    ks = jax.random.split(KEY, 3)
    b, length = 1, 256
    q = jax.random.normal(ks[0], (b, 4, 64))
    kc = jax.random.normal(ks[1], (b, length, 2, 64))
    vc = jax.random.normal(ks[2], (b, length, 2, 64))
    pos = jnp.asarray(5, jnp.int32)       # only slots 0..5 valid
    kpos = jnp.where(jnp.arange(length) <= pos, jnp.arange(length), -1)
    with ctx.sharding_rules(_cp_rule(mesh)):
        dispatch.clear_decision_log()
        out = jax.jit(lambda *a: dispatch.decode_attention(*a))(
            q, kc, vc, kpos, pos)
        assert dispatch.last_decision("decode_attention").backend == \
            "pallas_cp"
    want = ref.decode_attention_ref(q, kc, vc, kpos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.slow
@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
@pytest.mark.parametrize(
    "b,length,hq,hkv,d,pos,dp_axes",
    [
        (2, 512, 8, 2, 64, 300, ("data",)),    # GQA g=4
        (1, 1024, 4, 1, 64, 1023, ()),         # MQA, full cache
        (2, 256, 4, 4, 64, 17, ("data",)),     # MHA, mostly-empty cache
        (4, 512, 8, 4, 128, 400, ("data",)),   # wide head_dim
    ])
def test_decode_cp_parity_sweep(b, length, hq, hkv, d, pos, dp_axes):
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    kc = jax.random.normal(ks[1], (b, length, hkv, d))
    vc = jax.random.normal(ks[2], (b, length, hkv, d))
    pos = jnp.asarray(pos, jnp.int32)
    kpos = jnp.where(jnp.arange(length) <= pos, jnp.arange(length), -1)
    rules = {"decode_cp": {"mesh": mesh, "seq_axes": ("model",),
                           "dp_axes": dp_axes, "n_shards": 2}}
    with ctx.sharding_rules(rules):
        dispatch.clear_decision_log()
        out = jax.jit(lambda *a: dispatch.decode_attention(*a))(
            q, kc, vc, kpos, pos)
        assert dispatch.last_decision("decode_attention").backend == \
            "pallas_cp"
    want = ref.decode_attention_ref(q, kc, vc, kpos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
def test_decode_cp_fallback_reason_sweep():
    """Where the old code had a blanket 'decode_cp -> jnp' branch, the
    resolver now falls back only when the layout cannot serve the call —
    each with a logged reason (and numeric parity through the jnp path)."""
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    ks = jax.random.split(KEY, 3)
    pos = jnp.asarray(100, jnp.int32)
    q = jax.random.normal(ks[0], (2, 4, 64))

    def decode(length, rules):
        kc = jax.random.normal(ks[1], (2, length, 2, 64))
        vc = jax.random.normal(ks[2], (2, length, 2, 64))
        kpos = jnp.where(jnp.arange(length) <= pos,
                         jnp.arange(length), -1)
        with ctx.sharding_rules(rules):
            dispatch.clear_decision_log()
            out = dispatch.decode_attention(q, kc, vc, kpos, pos)
            d = dispatch.last_decision("decode_attention")
        want = ref.decode_attention_ref(q, kc, vc, kpos, pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)
        return d

    # local slice 192 not MXU-aligned
    d = decode(384, _cp_rule(mesh))
    assert d.backend == "jnp"
    assert "decode_cp rules own the cache but" in d.reason
    assert "not MXU-aligned" in d.reason
    # length does not divide the shard count
    bad = _cp_rule(mesh)
    bad["decode_cp"]["n_shards"] = 3
    d = decode(512, bad)
    assert d.backend == "jnp" and "does not divide" in d.reason
    # aligned layout resolves pallas_cp (the old blanket fallback is gone)
    d = decode(512, _cp_rule(mesh))
    assert d.backend == "pallas_cp"
    assert "context-parallel rules own the cache" not in "".join(
        r["reason"] for r in dispatch.decision_summary()
        if r["backend"] == "jnp")


# ---------------------------------------------------------------------------
# trace-cache token: one jitted callable across meshes
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
def test_mesh_switch_relowers_with_new_resolution():
    """Regression for the trace-cache bug: dispatch resolves at trace time
    and jax caches traces by function identity, so without the ctx dispatch
    token a re-lowered jit would replay the stale mesh's decision.  Jit
    once, switch meshes via ctx.use_mesh, assert the new resolution."""
    ks = jax.random.split(KEY, 3)
    b, length = 2, 512
    q = jax.random.normal(ks[0], (b, 4, 64))
    kc = jax.random.normal(ks[1], (b, length, 2, 64))
    vc = jax.random.normal(ks[2], (b, length, 2, 64))
    pos = jnp.asarray(300, jnp.int32)
    kpos = jnp.where(jnp.arange(length) <= pos, jnp.arange(length), -1)
    jitted = jax.jit(lambda *a: dispatch.decode_attention(*a))

    mesh = jax.make_mesh((1, 2), ("data", "model"))
    with ctx.use_mesh(mesh):
        dispatch.clear_decision_log()
        out_mesh = jitted(q, kc, vc, kpos, pos)
        assert dispatch.last_decision("decode_attention").backend == \
            "pallas_shard_map"
    # same callable under decode_cp rules: resolution must flip to
    # pallas_cp, not replay the (batch, heads) shard_map trace
    with ctx.sharding_rules(_cp_rule(mesh)):
        dispatch.clear_decision_log()
        out_cp = jitted(q, kc, vc, kpos, pos)
        d = dispatch.last_decision("decode_attention")
        assert d is not None and d.backend == "pallas_cp", d
    # and back outside any mesh: jnp (re-resolved again)
    dispatch.clear_decision_log()
    out_plain = jitted(q, kc, vc, kpos, pos)
    d = dispatch.last_decision("decode_attention")
    assert d is not None and d.backend == "jnp"
    want = ref.decode_attention_ref(q, kc, vc, kpos, pos)
    for got in (out_mesh, out_cp, out_plain):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


def test_mesh_reentry_hits_trace_cache():
    """The token must key by value, not by entry: re-installing an equal
    mesh/rule state restores the old cache key (no spurious retrace)."""
    q, k, v, _ = _qkv(1, 256, 4, 2, 64)
    traces = []

    @jax.jit
    def fn(q, k, v):
        traces.append(1)
        return dispatch.flash_attention(q, k, v, causal=True)

    fn(q, k, v)
    assert len(traces) == 1
    mesh = jax.make_mesh((len(jax.devices()), 1)
                         if MULTI else (1, 1), ("data", "model"))
    with ctx.use_mesh(mesh):
        fn(q, k, v)
        n_mesh = len(traces)
        assert n_mesh == 2
    fn(q, k, v)                       # restored state: cache hit
    assert len(traces) == n_mesh
    with ctx.use_mesh(mesh):          # equal mesh: cache hit
        fn(q, k, v)
    assert len(traces) == n_mesh


# ---------------------------------------------------------------------------
# rmsnorm under a mesh: row-block shard_map
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
@pytest.mark.parametrize("mesh_shape", [(2, 1), (1, 2)])
def test_rmsnorm_auto_mesh_shard_map_parity(mesh_shape):
    """Under a mesh rmsnorm now shard_maps over row blocks (scale
    replicated, dscale psum'd) instead of silently downgrading to jnp."""
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    x = jax.random.normal(KEY, (4, 8, 128))
    scale = jnp.ones((128,)) * 1.5

    def loss(x, scale):
        return jnp.sum(dispatch.rmsnorm(x, scale) ** 2)

    with ctx.use_mesh(mesh):
        dispatch.clear_decision_log()
        y = jax.jit(lambda x, s: dispatch.rmsnorm(x, s))(x, scale)
        d = dispatch.last_decision("rmsnorm")
        assert d.backend == "pallas_shard_map", d
        g = jax.jit(jax.grad(loss, argnums=(0, 1)))(x, scale)
    want = ref.rmsnorm_ref(x, scale)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    g_ref = jax.grad(lambda x, s: jnp.sum(ref.rmsnorm_ref(x, s) ** 2),
                     argnums=(0, 1))(x, scale)
    for got, want_g, name in zip(g, g_ref, ("dx", "dscale")):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want_g),
                                   atol=1e-4, rtol=1e-4, err_msg=name)


@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices")
def test_rmsnorm_seq_parallel_residual_explicit_fallback():
    """Megatron-SP seq-parallel residual keeps its explicit fallback
    reason (rows are sharded over 'model'; a row-block shard_map would
    re-gather the residual stream)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    x = jax.random.normal(KEY, (4, 8, 128))
    scale = jnp.ones((128,))
    rules = {"residual": NamedSharding(mesh, P(None, "model", None))}
    with ctx.use_mesh(mesh), ctx.sharding_rules(rules):
        dispatch.clear_decision_log()
        out = dispatch.rmsnorm(x, scale)
        d = dispatch.last_decision("rmsnorm")
    assert d.backend == "jnp"
    assert "seq-parallel residual" in d.reason
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.rmsnorm_ref(x, scale)),
                               atol=1e-5, rtol=1e-5)


def test_rmsnorm_rules_without_mesh_fall_back():
    from jax.sharding import PartitionSpec as P
    x = jax.random.normal(KEY, (4, 8, 128))
    scale = jnp.ones((128,))
    with ctx.sharding_rules({"residual": P()}):
        dispatch.clear_decision_log()
        dispatch.rmsnorm(x, scale)
    d = dispatch.last_decision("rmsnorm")
    assert d.backend == "jnp"
    assert "without a dispatch mesh" in d.reason
