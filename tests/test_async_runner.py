"""Runner tests: Hogwild (T1) vs sync (T2), shared vs per-worker statistics,
target-network swaps, and an end-to-end learning check on Catch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import agents, async_runner
from repro.envs import make
from repro.envs.api import flatten_obs
from repro.models import atari as nets

ENV = flatten_obs(make("catch"))


def _make(mode="hogwild", shared=True, algo_name="a3c", workers=4):
    algo = agents.ALGORITHMS[algo_name]()
    params = nets.init_mlp_agent_params(jax.random.key(0),
                                        ENV.obs_shape[0], ENV.n_actions,
                                        hidden=32)
    cfg = async_runner.RunnerConfig(
        n_workers=workers, t_max=5, lr0=1e-2, total_frames=10**9,
        mode=mode, shared_stats=shared, target_interval=100)
    return async_runner.make_runner(algo, ENV, params, cfg)


@pytest.mark.parametrize("mode", ["hogwild", "sync"])
@pytest.mark.parametrize("shared", [True, False])
def test_round_runs(mode, shared):
    init_state, round_fn = _make(mode, shared)
    st = init_state(jax.random.key(1))
    st, m = round_fn(st)
    assert bool(jnp.isfinite(m["loss"]))
    assert int(st["frames"]) == 4 * 5


def test_per_worker_stats_are_stacked():
    init_state, _ = _make(shared=False)
    st = init_state(jax.random.key(1))
    leaf = jax.tree.leaves(st["opt_state"])[0]
    assert leaf.shape[0] == 4   # one g per worker


def test_hogwild_differs_from_sync():
    """Sequential (stale) application != averaged application."""
    outs = {}
    for mode in ["hogwild", "sync"]:
        init_state, round_fn = _make(mode)
        st = init_state(jax.random.key(1))
        for _ in range(3):
            st, _ = round_fn(st)
        outs[mode] = st["params"]
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         outs["hogwild"], outs["sync"])
    assert max(jax.tree.leaves(diffs)) > 1e-7


def test_target_network_swaps():
    init_state, round_fn = _make(algo_name="one_step_q")
    st = init_state(jax.random.key(1))
    t0 = st["target_params"]
    for _ in range(7):   # 7 rounds * 20 frames = 140 > interval 100
        st, _ = round_fn(st)
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         t0, st["target_params"])
    assert max(jax.tree.leaves(moved)) > 0


def test_eps_finals_from_paper_distribution():
    init_state, _ = _make(workers=4)
    st = init_state(jax.random.key(7))
    eps = np.asarray(st["eps_final"])
    allowed = np.array([0.1, 0.01, 0.5], np.float32)
    assert all(np.isclose(e, allowed).any() for e in eps)


@pytest.mark.slow
def test_a3c_learns_catch():
    """End-to-end: A3C beats the random policy (-0.6) decisively."""
    init_state, round_fn = _make(mode="hogwild", workers=8)
    st = init_state(jax.random.key(2))
    rets = []
    for i in range(3500):
        st, m = round_fn(st)
        if i >= 3400:
            rets.append(float(m["ep_ret"]))
    assert np.mean(rets) > 0.3, np.mean(rets)
