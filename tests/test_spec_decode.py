"""Speculative decoding through the serve engine: accepted tokens must
be bit-identical to non-speculative decode on every layout (contiguous,
ring, paged, int8 KV, 2-dev mesh), sampled streams included, and the
accept/rollback bookkeeping must leave the page allocator balanced
through mid-page rejections, ring rotation-boundary rewinds, COW forks
under verify chunks, and preemption mid-speculation.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import serve as serve_mod
from repro.models import model as M


def _cfg():
    return get_config("stablelm-1.6b").reduced()


def _trace(vocab, *, n=4, prompt_range=(12, 24), max_new=16, seed=3,
           shared=0, duplicate=False):
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, vocab, shared).astype(np.int32)
    out = []
    base_tail = rng.integers(0, vocab, prompt_range[0]).astype(np.int32)
    for rid in range(n):
        if duplicate:
            tail = base_tail
        else:
            tail = rng.integers(0, vocab, int(rng.integers(
                prompt_range[0], prompt_range[1] + 1))).astype(np.int32)
        out.append(serve_mod.Request(
            rid=rid, prompt=np.concatenate([pre, tail]),
            max_new=max_new - (rid % 3) * 2, arrival=0.0))
    return out


def _drive(cfg, params, trace, *, spec, spec_k=4, n_slots=2,
           cache_len=64, chunk=16, sample=False, seed=0, **kw):
    """Run a trace through a fresh engine; returns (engine, tokens)."""
    eng = serve_mod.ServeEngine(
        cfg, params, n_slots=n_slots, cache_len=cache_len, chunk=chunk,
        sample=sample, seed=seed, spec=spec, spec_k=spec_k, **kw)
    serve_mod._warmup(eng, trace)
    done = []
    eng.start_clock()
    serve_mod._drain(eng, sorted(trace, key=lambda r: r.arrival), 0, done)
    assert len(done) == len(trace)
    return eng, {r.rid: list(r.tokens) for r in trace}


def _assert_books_balanced(eng):
    """Every request drained -> every page reference dropped: tables
    empty, refcounts zero, the whole pool (minus the sink) back on the
    free list.  A speculative pre-map that rollback misses shows up here
    as a leaked refcount."""
    assert eng.paged
    assert (eng.pt_host == -1).all(), eng.pt_host
    ref = np.asarray(eng.alloc.ref)
    assert (ref == 0).all(), f"leaked refcounts: {np.nonzero(ref)[0]}"
    assert sorted(eng.alloc.free) == list(range(1, eng.n_pages))


class _WrongDraft:
    """Draft source proposing deliberately wrong tokens (cycling the
    vocab away from the true continuation) — forces every verify round
    to reject the whole draft tail, the regime that exercises mid-page
    rollback hardest.  Greedy identity must survive total rejection."""

    kind = "wrong"

    def __init__(self, vocab):
        self.vocab = vocab

    def propose_one(self, history, k):
        last = int(history[-1])
        return [(last + 7 * (i + 1)) % self.vocab for i in range(k - 1)]

    def admit(self, req, j):
        pass

    def reset(self):
        pass


# ---------------------------------------------------------------------------
# token identity across layouts
# ---------------------------------------------------------------------------

def test_spec_identity_contiguous():
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    run = lambda spec: _drive(cfg, params,
                              _trace(cfg.vocab_size, n=4), spec=spec)[1]
    base = run("off")
    assert run("ngram") == base


def test_spec_identity_ring_rotation_boundary():
    """Sliding-window arch: the ring cache rotates every ``window``
    positions, so spec_k=4 chunks from generation-length 20 requests
    straddle rotation boundaries repeatedly.  Verify never writes the
    ring (commit scatters only accepted rows), so a rejected tail needs
    no un-rotation — identity is the proof."""
    cfg = dataclasses.replace(_cfg(), block_cycle=("attn_local",),
                              sliding_window=8)
    params = M.init_params(cfg, jax.random.key(0))
    run = lambda spec: _drive(
        cfg, params, _trace(cfg.vocab_size, n=3, max_new=20),
        spec=spec, chunk=8)[1]
    base = run("off")
    assert run("ngram") == base


def test_spec_identity_paged_and_books():
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    run = lambda spec: _drive(
        cfg, params, _trace(cfg.vocab_size, n=4, shared=32),
        spec=spec, cache_len=128, chunk=32, page_size=32,
        prefix_cache=True)
    _, base = run("off")
    eng, toks = run("ngram")
    assert toks == base
    assert eng.paged and eng.spec_rounds > 0
    _assert_books_balanced(eng)


def test_spec_identity_paged_int8():
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    run = lambda spec: _drive(
        cfg, params, _trace(cfg.vocab_size, n=3, shared=32),
        spec=spec, cache_len=128, chunk=32, page_size=32,
        prefix_cache=True, kv_dtype="int8")
    eng_off, base = run("off")
    eng, toks = run("ngram")
    assert eng.kv_dtype_name == "int8"
    assert toks == base
    _assert_books_balanced(eng)


def test_spec_identity_draft_model():
    """The tiny-config draft model source: acceptance is near zero (the
    draft net is independently initialised) but accepted tokens — i.e.
    the per-round bonus token — must still replay plain decode
    exactly, and the draft's own KV bookkeeping must not desync across
    partial accepts."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    run = lambda spec: _drive(
        cfg, params, _trace(cfg.vocab_size, n=3, max_new=10),
        spec=spec, spec_k=3)[1]
    base = run("off")
    assert run("draft") == base


def test_spec_sampled_streams_invariant():
    """Sampled decode: per-token keys derive from (request id, logical
    position), so a run that commits 3 tokens per verify round and a
    plain run that takes 3 steps draw the same stream — sampled outputs
    must be bit-identical, not just statistically alike."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    run = lambda spec: _drive(
        cfg, params, _trace(cfg.vocab_size, n=4), spec=spec,
        sample=True, seed=11)[1]
    base = run("off")
    assert run("ngram") == base


# ---------------------------------------------------------------------------
# rollback edge cases
# ---------------------------------------------------------------------------

def test_spec_midpage_rejection_rewinds_pages():
    """All-wrong drafts + 8-token pages: verify rounds pre-map pages the
    accept decision then wholly rejects; optimistic admission must
    decref-and-unmap them (counter proves it ran) and the drained books
    must balance — while greedy tokens stay identical to plain decode."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    mk = lambda: _trace(cfg.vocab_size, n=3, max_new=14)
    _, base = _drive(cfg, params, mk(), spec="off", cache_len=64,
                     chunk=16, page_size=8, admission="optimistic")
    eng = serve_mod.ServeEngine(
        cfg, params, n_slots=2, cache_len=64, chunk=16, sample=False,
        seed=0, spec="ngram", spec_k=6, page_size=8,
        admission="optimistic")
    eng.draft_src = _WrongDraft(cfg.vocab_size)
    trace = mk()
    serve_mod._warmup(eng, trace)
    done = []
    eng.start_clock()
    serve_mod._drain(eng, sorted(trace, key=lambda r: r.arrival), 0, done)
    assert {r.rid: list(r.tokens) for r in trace} == base
    assert eng.spec_pages_rewound >= 1, \
        "no page was ever rewound — the rollback arm went unexercised"
    # total rejection: acceptance collapses to the bonus token
    assert eng.spec_drafts_accepted < eng.spec_drafted
    _assert_books_balanced(eng)


def test_spec_reserve_admission_keeps_rejected_pages():
    """Under ``reserve`` admission a wholly-rejected page stays mapped
    (the reservation already paid for it; kpos masks its rows), so the
    rewind counter must stay zero and the books still balance."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    mk = lambda: _trace(cfg.vocab_size, n=3, max_new=14)
    _, base = _drive(cfg, params, mk(), spec="off", cache_len=64,
                     chunk=16, page_size=8, admission="reserve")
    eng = serve_mod.ServeEngine(
        cfg, params, n_slots=2, cache_len=64, chunk=16, sample=False,
        seed=0, spec="ngram", spec_k=6, page_size=8, admission="reserve")
    eng.draft_src = _WrongDraft(cfg.vocab_size)
    trace = mk()
    serve_mod._warmup(eng, trace)
    done = []
    eng.start_clock()
    serve_mod._drain(eng, sorted(trace, key=lambda r: r.arrival), 0, done)
    assert {r.rid: list(r.tokens) for r in trace} == base
    assert eng.spec_pages_rewound == 0
    _assert_books_balanced(eng)


def test_spec_cow_fork_during_verify():
    """Duplicate prompts share their partial prompt page; the first
    verify round's pre-map COW-forks it (the accept rule commits >= 1
    token, so the fork never rolls back).  Tokens must match plain
    decode, the fork must actually happen, and the books balance."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    mk = lambda: _trace(cfg.vocab_size, n=3, shared=32, duplicate=True)
    _, base = _drive(cfg, params, mk(), spec="off", cache_len=128,
                     chunk=32, page_size=32, prefix_cache=True)
    eng, toks = _drive(cfg, params, mk(), spec="ngram", cache_len=128,
                       chunk=32, page_size=32, prefix_cache=True)
    assert toks == base
    assert eng.cow_events >= 1, \
        "shared partial page never forked under speculation"
    _assert_books_balanced(eng)


def test_spec_preemption_mid_speculation():
    """Undersized pool under optimistic admission: speculative pre-maps
    hit exhaustion mid-round, the engine preempts a victim (dropping its
    speculative state with its pages), re-admits it later and must still
    reproduce plain decode exactly, with balanced books after drain."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    mk = lambda: _trace(cfg.vocab_size, n=4, prompt_range=(10, 14),
                        max_new=14, shared=8)
    _, base = _drive(cfg, params, mk(), spec="off", n_slots=3,
                     cache_len=64, chunk=16, page_size=8,
                     admission="optimistic")
    tight = 11                       # 3 slots x 8 pages worst -> starved
    eng, toks = _drive(cfg, params, mk(), spec="ngram", spec_k=6,
                       n_slots=3, cache_len=64, chunk=16, page_size=8,
                       n_pages=tight, admission="optimistic")
    assert toks == base
    assert eng.preemptions >= 1, \
        "pool was never exhausted mid-speculation — tighten n_pages"
    _assert_books_balanced(eng)


# ---------------------------------------------------------------------------
# distributed leg
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
def test_spec_identity_2dev_mesh():
    """Speculative decode on the 2-dev host mesh (model-sharded decode
    layout): verify + commit ride the same sharded cache, tokens match
    the single-host plain run."""
    from repro import compat
    from repro.distributed import ctx, sharding

    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    mk = lambda: _trace(cfg.vocab_size, n=3, prompt_range=(4, 12),
                        max_new=6, seed=2)
    _, base = _drive(cfg, params, mk(), spec="off", cache_len=256,
                     chunk=8)
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    rules = sharding.decode_rules(cfg, mesh, batch_size=2)
    with compat.set_mesh(mesh), ctx.use_mesh(mesh), \
            ctx.sharding_rules(rules):
        _, toks = _drive(cfg, params, mk(), spec="ngram", cache_len=256,
                         chunk=8)
    assert toks == base
