"""Environment invariants (hypothesis property tests) + TokenMDP rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro import envs
from repro.envs.api import flatten_obs
from repro.envs.token_mdp import TokenMDP


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), env_name=st.sampled_from(
    ["catch", "gridmaze", "pointmass", "pendulum"]))
def test_env_step_invariants(seed, env_name):
    env = envs.make(env_name)
    key = jax.random.key(seed)
    state, obs = env.reset(key)
    assert obs.shape == env.obs_shape
    for i in range(5):
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        if env.continuous:
            action = jax.random.uniform(k1, (env.n_actions,), minval=-1,
                                        maxval=1)
        else:
            action = jax.random.randint(k1, (), 0, env.n_actions)
        state, obs, reward, done = env.step(state, action, k2)
        assert obs.shape == env.obs_shape
        assert bool(jnp.all(jnp.isfinite(obs)))
        assert bool(jnp.isfinite(reward))


def test_catch_episode_length():
    env = envs.make("catch")
    key = jax.random.key(0)
    state, obs = env.reset(key)
    done_at = None
    for i in range(12):
        state, obs, r, done = env.step(state, jnp.array(1), 
                                       jax.random.fold_in(key, i))
        if bool(done):
            done_at = i
            break
    assert done_at == 8  # ball falls rows-1 = 9 steps; done on the 9th


def test_gridmaze_portal_reward():
    env = envs.make("gridmaze")
    key = jax.random.key(3)
    state, obs = env.reset(key)
    # exhaustive random walk: rewards must be in {0, 1, 10, 11}
    for i in range(50):
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        a = jax.random.randint(k1, (), 0, 4)
        state, obs, r, done = env.step(state, a, k2)
        assert float(r) in (0.0, 1.0, 10.0, 11.0)


def test_flatten_obs():
    env = flatten_obs(envs.make("catch"))
    state, obs = env.reset(jax.random.key(0))
    assert obs.shape == (50,)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_token_mdp_successor_rule(seed):
    mdp = TokenMDP(vocab=11, context=16, episode_len=16)
    key = jax.random.key(seed)
    tokens = jax.random.randint(key, (2, 16), 0, 11)
    r = mdp.reward_for_sequence(tokens)
    nxt = jnp.roll(tokens, -1, axis=1)
    expect = (nxt == (tokens + 1) % 11).astype(jnp.float32).at[:, -1].set(0.)
    np.testing.assert_array_equal(r, expect)
    assert float(r[:, -1].sum()) == 0.0


def test_token_mdp_step():
    mdp = TokenMDP(vocab=7, context=8, episode_len=4)
    st_ = mdp.reset(jax.random.key(0), batch=3)
    prev = st_.tokens[:, 0]
    good = (prev + 1) % 7
    st2, r, done = mdp.step(st_, good)
    np.testing.assert_allclose(r, 1.0)
    st3, r2, done = mdp.step(st2, good)       # not successor of `good`... 
    # after writing `good` at pos 1, prev is now `good`; emit good+1
    st4, r3, done = mdp.step(st3, (good + 1) % 7)
    assert r3.shape == (3,)
