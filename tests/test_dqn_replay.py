"""The paper's DQN-with-replay comparison baseline."""
import jax
import jax.numpy as jnp

from repro.core import dqn_replay
from repro.envs import make
from repro.envs.api import flatten_obs
from repro.models import atari as nets


def test_dqn_steps_and_buffer():
    env = flatten_obs(make("catch"))
    params = nets.init_mlp_agent_params(jax.random.key(0),
                                        env.obs_shape[0], env.n_actions,
                                        hidden=16)
    cfg = dqn_replay.DQNConfig(buffer_size=64, batch_size=8, warmup=8,
                               train_every=2, target_interval=16)
    init_state, step_fn = dqn_replay.make_dqn(env, params, cfg)
    st = init_state(jax.random.key(1))
    for _ in range(40):
        st = step_fn(st)
    assert int(st["filled"]) == 40
    assert bool(jnp.all(jnp.isfinite(jax.tree.leaves(st["params"])[0])))
