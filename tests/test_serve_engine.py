"""Continuous-batching serve engine: chunked prefill + slot table.

Chunked prefill must reproduce the teacher-forced forward logits (per
chunk, including ring-buffer sliding-window caches), and the engine's
greedy generations must match per-request sequential decoding exactly —
admission order, padding garbage in the cache, and per-slot positions must
not leak between slots.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import llm_a3c
from repro.launch import serve as serve_mod
from repro.models import model as M


def _cfg():
    return get_config("stablelm-1.6b").reduced()


def test_prefill_chunks_match_forward():
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0,
                                cfg.vocab_size)
    full = M.forward(cfg, params, {"tokens": tokens})["logits"]
    cache = M.init_cache(cfg, b, 24, dtype=jnp.float32)
    o1, cache = M.prefill_step(cfg, params, cache,
                               {"tokens": tokens[:, :8]}, 0)
    o2, cache = M.prefill_step(cfg, params, cache,
                               {"tokens": tokens[:, 8:]}, 8)
    got = jnp.concatenate([o1["logits"], o2["logits"]], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               atol=2e-3, rtol=2e-3)
    # and the decode that continues from the prefilled cache agrees with
    # the one continuing from a token-by-token cache
    loop_cache = M.init_cache(cfg, b, 24, dtype=jnp.float32)
    for t in range(s):
        _, loop_cache = M.decode_step(cfg, params, loop_cache,
                                      {"tokens": tokens[:, t:t + 1]},
                                      jnp.asarray(t))
    nxt = jnp.argmax(full[:, -1], -1)[:, None]
    d1, _ = M.decode_step(cfg, params, cache, {"tokens": nxt},
                          jnp.asarray(s))
    d2, _ = M.decode_step(cfg, params, loop_cache, {"tokens": nxt},
                          jnp.asarray(s))
    np.testing.assert_allclose(np.asarray(d1["logits"]),
                               np.asarray(d2["logits"]),
                               atol=2e-3, rtol=2e-3)


def test_prefill_ring_window_cache():
    """Sliding-window arch: chunk writes wrap the ring cache (chunk ==
    window, so chunks 2+ hit the wrap path and the masked prefix read)."""
    cfg = dataclasses.replace(_cfg(), block_cycle=("attn_local",),
                              sliding_window=8)
    params = M.init_params(cfg, jax.random.key(0))
    b, s = 1, 24
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0,
                                cfg.vocab_size)
    full = M.forward(cfg, params, {"tokens": tokens})["logits"]
    cache = M.init_cache(cfg, b, s, dtype=jnp.float32)  # ring len = window
    outs = []
    for p0 in range(0, s, 8):
        o, cache = M.prefill_step(cfg, params, cache,
                                  {"tokens": tokens[:, p0:p0 + 8]}, p0)
        outs.append(o["logits"])
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               atol=2e-3, rtol=2e-3)


def test_supports_chunked_prefill_gating():
    assert M.supports_chunked_prefill(_cfg())
    assert llm_a3c.make_prefill_step(_cfg()) is not None
    xl = get_config("xlstm-1.3b").reduced()
    assert not M.supports_chunked_prefill(xl)
    assert llm_a3c.make_prefill_step(xl) is None
    # ring (sliding-window) archs chunk-prefill too now: per-row true_len
    # masks ring writes past each row's real prompt length, so the padded
    # admission chunks that used to alias ring rows are safe
    ring = dataclasses.replace(_cfg(), block_cycle=("attn_local",),
                               sliding_window=8)
    assert M.supports_chunked_prefill(ring)
    assert llm_a3c.make_prefill_step(ring) is not None


def _reference_greedy(cfg, params, prompt, max_new, cache_len):
    """Per-request sequential decode (scalar pos, argmax)."""
    serve = llm_a3c.make_serve_step(cfg, sample=False)
    cache = M.init_cache(cfg, 1, cache_len, dtype=jnp.float32)
    key = jax.random.key(0)
    tok = None
    for i, t in enumerate(prompt):
        tok, _, cache = serve(params, cache,
                              {"tokens": jnp.asarray([[int(t)]])},
                              jnp.asarray(i), key)
    toks = [int(tok[0])]
    pos = len(prompt)
    while len(toks) < max_new:
        tok, _, cache = serve(params, cache,
                              {"tokens": jnp.asarray([[toks[-1]]])},
                              jnp.asarray(pos), key)
        toks.append(int(tok[0]))
        pos += 1
    return toks


def test_engine_matches_sequential_greedy():
    """Mixed-length requests through the slot table == per-request
    sequential greedy decode, token for token.  gen_range starts at 1 so
    a request satisfied by its prefill token (max_new == 1) is covered;
    chunk > cache_len exercises the clamped chunk grid (the full-cache
    overflow that used to clobber prompt rows)."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    trace = serve_mod.gen_trace(6, vocab=cfg.vocab_size,
                                prompt_range=(4, 20), gen_range=(1, 8),
                                arrival_rate=0.0, seed=3)
    assert min(r.max_new for r in trace) == 1   # seed chosen to cover it
    cache_len = 32
    rec = serve_mod.run_engine(cfg, params, trace, n_slots=2,
                               cache_len=cache_len, chunk=64,
                               sample=False, seed=0)
    assert rec["requests"] == 6
    assert rec["chunked_prefill"]
    assert rec["generated_tokens"] == sum(r.max_new for r in trace)
    for r in trace:
        want = _reference_greedy(cfg, params, r.prompt, r.max_new,
                                 cache_len)
        assert r.tokens == want, (r.rid, r.tokens, want)


def test_chunk_grid_clamps_to_cache_len():
    assert serve_mod._chunk_grid(48, 128, 80) == [(0, 80)]
    assert serve_mod._chunk_grid(48, 32, 80) == [(0, 32), (32, 32)]
    assert serve_mod._chunk_grid(70, 32, 80) == [(0, 32), (32, 32),
                                                 (64, 16)]
    assert serve_mod._chunk_grid(16, 8, 64) == [(0, 8), (8, 8)]
    with pytest.raises(ValueError):
        serve_mod._chunk_grid(100, 32, 80)
    # a chunk overflowing a full cache is a loud trace-time error, not a
    # silent prompt-row clobber
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    cache = M.init_cache(cfg, 1, 12, dtype=jnp.float32)
    with pytest.raises(ValueError, match="overflows"):
        M.prefill_step(cfg, params, cache,
                       {"tokens": jnp.zeros((1, 16), jnp.int32)}, 0)


def test_engine_ring_arch_chunked_prefill_matches():
    """Sliding-window arch through the engine, now on the CHUNKED prefill
    path (true_len-masked ring writes make right-padded admission chunks
    safe): mixed-length requests must match per-request sequential greedy
    decode.  chunk > window covers the ring-wrap write; prompts shorter
    than the padded grid cover the masked-write rows."""
    cfg = dataclasses.replace(_cfg(), block_cycle=("attn_local",),
                              sliding_window=8)
    params = M.init_params(cfg, jax.random.key(0))
    trace = serve_mod.gen_trace(4, vocab=cfg.vocab_size,
                                prompt_range=(3, 12), gen_range=(2, 5),
                                arrival_rate=0.0, seed=4)
    rec = serve_mod.run_engine(cfg, params, trace, n_slots=2,
                               cache_len=20, chunk=16, sample=False,
                               seed=0)
    assert rec["chunked_prefill"]
    for r in trace:
        want = _reference_greedy(cfg, params, r.prompt, r.max_new, 20)
        assert r.tokens == want, (r.rid, r.tokens, want)


def test_engine_fallback_loop_prefill():
    """Recurrent-cache arch: the engine falls back to token-by-token
    prefill and still matches sequential greedy decode."""
    cfg = get_config("xlstm-1.3b").reduced()
    params = M.init_params(cfg, jax.random.key(0))
    trace = serve_mod.gen_trace(3, vocab=cfg.vocab_size,
                                prompt_range=(3, 6), gen_range=(2, 4),
                                arrival_rate=0.0, seed=5)
    rec = serve_mod.run_engine(cfg, params, trace, n_slots=2,
                               cache_len=16, chunk=8, sample=False, seed=0)
    assert not rec["chunked_prefill"]
    for r in trace:
        want = _reference_greedy(cfg, params, r.prompt, r.max_new, 16)
        assert r.tokens == want, (r.rid, r.tokens, want)


def test_lockstep_ring_wave_matches_sequential():
    """Regression: a lockstep wave mixing short and long prompts on a
    sliding-window arch must match per-request sequential greedy — the old
    standalone wave prefill re-fed short rows' last tokens past their true
    length, wrapping the ring and clobbering rows kpos attributed to real
    positions."""
    cfg = dataclasses.replace(_cfg(), block_cycle=("attn_local",),
                              sliding_window=8)
    params = M.init_params(cfg, jax.random.key(0))
    # one wave of 2: plen 4 next to plen 20 (> window), the aliasing case
    trace = [serve_mod.Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                               max_new=4, arrival=0.0),
             serve_mod.Request(rid=1,
                               prompt=np.arange(20, dtype=np.int32) % 7,
                               max_new=3, arrival=0.0)]
    rec = serve_mod.run_lockstep(cfg, params, trace, n_slots=2,
                                 cache_len=26, chunk=8, sample=False,
                                 seed=0)
    assert rec["requests"] == 2
    for r in trace:
        want = _reference_greedy(cfg, params, r.prompt, r.max_new, 26)
        assert r.tokens == want, (r.rid, r.tokens, want)


def test_lockstep_runner_smoke():
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    trace = serve_mod.gen_trace(4, vocab=cfg.vocab_size,
                                prompt_range=(4, 12), gen_range=(2, 4),
                                arrival_rate=0.0, seed=1)
    rec = serve_mod.run_lockstep(cfg, params, trace, n_slots=2,
                                 cache_len=20, chunk=8, sample=True,
                                 seed=0)
    assert rec["requests"] == 4
    assert rec["generated_tokens"] == sum(r.max_new for r in trace)
    # satellite: sample_tokens is the FIRST REQUEST's first generated
    # tokens, not the first decode step across the batch
    assert rec["sample_tokens"] == trace[0].tokens[:4]
    assert rec["warmup_s"] > 0


@pytest.mark.slow
@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
def test_engine_decode_cp_smoke():
    """Serve-engine smoke on the 2-dev host mesh: mixed-length requests
    with the seq-sharded cache layout must resolve pallas_cp and match the
    unruled sequential reference."""
    from repro import compat
    from repro.distributed import ctx, sharding
    from repro.kernels import dispatch

    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    trace = serve_mod.gen_trace(4, vocab=cfg.vocab_size,
                                prompt_range=(4, 16), gen_range=(2, 5),
                                arrival_rate=0.0, seed=2)
    cache_len = 256                     # 128-aligned per-shard slices
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    rules = sharding.decode_rules(cfg, mesh, batch_size=2)
    with compat.set_mesh(mesh), ctx.use_mesh(mesh), \
            ctx.sharding_rules(rules):
        dispatch.clear_decision_log()
        rec = serve_mod.run_engine(cfg, params, trace, n_slots=2,
                                   cache_len=cache_len, chunk=8,
                                   sample=False, seed=0)
        d = dispatch.last_decision("decode_attention")
        assert d is not None and d.backend == "pallas_cp", d
    assert rec["requests"] == 4
    for r in trace:
        want = _reference_greedy(cfg, params, r.prompt, r.max_new,
                                 cache_len)
        assert r.tokens == want, (r.rid, r.tokens, want)
