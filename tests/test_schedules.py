"""LR schedules: paper's linear anneal + LogUniform sampling, MiniCPM WSD."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.optim import schedules


def test_linear_anneal_endpoints():
    np.testing.assert_allclose(
        float(schedules.linear_anneal(1e-2, jnp.asarray(0.0), 100.0)),
        1e-2, rtol=1e-6)
    np.testing.assert_allclose(
        float(schedules.linear_anneal(1e-2, jnp.asarray(100.0), 100.0)),
        0.0, atol=1e-9)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_log_uniform_in_paper_range(seed):
    lr = float(schedules.log_uniform(jax.random.key(seed)))
    assert 1e-4 <= lr <= 1e-2


def test_log_uniform_is_log_uniform():
    lrs = schedules.log_uniform(jax.random.key(0), shape=(20_000,))
    logs = np.log(np.asarray(lrs))
    # roughly uniform in log space: thirds have similar counts
    lo, hi = np.log(1e-4), np.log(1e-2)
    edges = np.linspace(lo, hi, 4)
    counts = np.histogram(logs, edges)[0]
    assert counts.min() > 0.8 * counts.max()


def test_wsd_phases():
    lr0, total = 1e-3, 1000.0
    warm = float(schedules.wsd(lr0, jnp.asarray(5.0), total))
    stable = float(schedules.wsd(lr0, jnp.asarray(500.0), total))
    decay = float(schedules.wsd(lr0, jnp.asarray(990.0), total))
    assert warm < stable
    assert abs(stable - lr0) < 1e-9
    assert decay < stable
