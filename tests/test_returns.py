"""n-step return tests: unit + hypothesis properties against the O(T^2)
oracle (paper Alg. 2/3 forward-view recursion)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core.returns import (gae_advantages, n_step_returns,
                                n_step_returns_ref)


def test_matches_hand_computed():
    r = jnp.array([1.0, 0.0, 2.0])
    d = jnp.array([0.9, 0.9, 0.9])
    boot = jnp.array(10.0)
    # R2 = 2 + .9*10 = 11; R1 = 0 + .9*11 = 9.9; R0 = 1 + .9*9.9 = 9.91
    out = n_step_returns(r, d, boot)
    np.testing.assert_allclose(out, [9.91, 9.9, 11.0], rtol=1e-6)


def test_terminal_cuts_bootstrap():
    r = jnp.array([0.0, 1.0])
    d = jnp.array([0.9, 0.0])    # step 1 terminal
    out = n_step_returns(r, d, jnp.array(100.0))
    np.testing.assert_allclose(out, [0.9, 1.0], rtol=1e-6)


@settings(max_examples=50, deadline=None)
@given(
    t=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
    gamma=st.floats(0.0, 1.0),
)
def test_matches_oracle(t, seed, gamma):
    rng = np.random.RandomState(seed)
    r = jnp.asarray(rng.randn(t).astype(np.float32))
    done = jnp.asarray(rng.rand(t) < 0.3)
    d = gamma * (1.0 - done.astype(jnp.float32))
    boot = jnp.asarray(rng.randn())
    fast = n_step_returns(r, d, boot)
    slow = n_step_returns_ref(r, d, boot)
    np.testing.assert_allclose(fast, slow, rtol=1e-5, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(t=st.integers(1, 10), seed=st.integers(0, 2**31 - 1))
def test_recursion_identity(t, seed):
    """returns[i] == r[i] + d[i] * returns[i+1] — the defining recursion."""
    rng = np.random.RandomState(seed)
    r = jnp.asarray(rng.randn(t).astype(np.float32))
    d = jnp.asarray((0.9 * (rng.rand(t) > 0.2)).astype(np.float32))
    boot = jnp.asarray(rng.randn())
    rets = n_step_returns(r, d, boot)
    nxt = jnp.concatenate([rets[1:], boot[None]])
    np.testing.assert_allclose(rets, r + d * nxt, rtol=1e-5, atol=1e-5)


def test_gae_lambda1_equals_nstep_advantage():
    """GAE(lambda=1) == n-step returns - values."""
    rng = np.random.RandomState(0)
    t = 8
    r = jnp.asarray(rng.randn(t).astype(np.float32))
    d = jnp.full((t,), 0.95)
    v = jnp.asarray(rng.randn(t).astype(np.float32))
    boot = jnp.asarray(rng.randn())
    adv, rets = gae_advantages(r, d, v, boot, lam=1.0)
    expect = n_step_returns(r, d, boot) - v
    np.testing.assert_allclose(adv, expect, rtol=1e-4, atol=1e-5)


def test_batched_shapes():
    r = jnp.zeros((5, 7))
    d = jnp.ones((5, 7))
    boot = jnp.zeros((7,))
    assert n_step_returns(r, d, boot).shape == (5, 7)
