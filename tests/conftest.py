"""Shared pytest plumbing.

The full suite runs hundreds of XLA:CPU compilations in one process;
letting the jit/compile caches accumulate across all modules eventually
segfaults inside ``backend_compile`` (reproducible on the pristine seed
tree too — it is a jaxlib compile-state accumulation issue, not a test
bug).  Dropping the caches at module boundaries keeps per-process
compile state bounded; each module pays its own (re)traces, which it
would also pay when run alone.
"""
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
