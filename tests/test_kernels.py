"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle.

``backend="pallas"`` pins the dispatch layer to the bare kernels — on this
CPU suite auto dispatch would (correctly) resolve to jnp, which is covered
separately in test_dispatch_mesh.py."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch, ref

KEY = jax.random.key(42)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,hq,hkv,d,window,causal",
    [
        (2, 256, 4, 1, 64, None, True),
        (1, 512, 8, 2, 64, None, True),
        (2, 512, 4, 4, 128, 128, True),
        (1, 256, 2, 2, 64, None, False),
        (1, 1024, 8, 8, 64, 256, True),
    ])
def test_flash_attention_sweep(b, s, hq, hkv, d, window, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    out = dispatch.flash_attention(q, k, v, causal=causal, window=window,
                              backend="pallas")
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,hq,hkv,d,window,causal",
    [
        (2, 256, 4, 1, 64, None, True),     # GQA g=4
        (1, 512, 8, 2, 64, None, True),     # GQA g=4, 512 blocks
        (2, 256, 4, 4, 128, 128, True),     # sliding window, MHA
        (1, 256, 2, 2, 64, None, False),    # bidirectional
        (1, 512, 4, 2, 64, 256, True),      # GQA + window
    ])
def test_flash_attention_grad_sweep(b, s, hq, hkv, d, window, causal, dtype):
    """jax.grad through the Pallas kernel (fused bwd) vs the blockwise-jnp
    custom-vjp oracle, on dq, dk and dv."""
    from repro.models.flash_jnp import flash_attention_jnp
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, s, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    do = jax.random.normal(ks[3], (b, s, hq, d), dtype)

    def loss_pl(q, k, v):
        o = dispatch.flash_attention(q, k, v, causal=causal, window=window,
                                backend="pallas")
        return jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32))

    def loss_ref(q, k, v):
        o = flash_attention_jnp(q, k, v, causal, window, 128)
        return jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32))

    g_pl = jax.grad(loss_pl, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-2
    for got, want, name in zip(g_pl, g_ref, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=tol, rtol=tol, err_msg=name)


def test_flash_attention_grad_matches_sdpa():
    """End-to-end AD through the kernel vs the naive softmax reference."""
    b, s, hq, hkv, d = 1, 256, 4, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    g_pl = jax.grad(lambda q, k, v: jnp.sum(
        dispatch.flash_attention(q, k, v, causal=True, backend="pallas") ** 2),
        argnums=(0, 1, 2))(q, k, v)
    g_rf = jax.grad(lambda q, k, v: jnp.sum(
        ref.flash_attention_ref(q, k, v, causal=True) ** 2),
        argnums=(0, 1, 2))(q, k, v)
    for got, want, name in zip(g_pl, g_rf, ("dq", "dk", "dv")):
        np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4,
                                   err_msg=name)


def test_flash_fwd_save_residuals_lse():
    """The saved lse matches logsumexp of the masked scaled scores."""
    from repro.kernels.flash_attention import flash_attention_fwd
    b, s, hq, hkv, d = 1, 256, 2, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    o, lse = flash_attention_fwd(q, k, v, causal=True, block_q=128,
                                 block_k=128, save_residuals=True)
    logits = jnp.einsum("bshd,bthd->bhst", q, k) * d ** -0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    want = jax.scipy.special.logsumexp(logits, axis=-1)     # (B,Hq,S)
    np.testing.assert_allclose(lse, want, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,length,hq,hkv,d,frac",
    [
        (2, 512, 4, 1, 64, 0.5),
        (1, 1024, 8, 2, 128, 0.9),
        (2, 256, 4, 4, 64, 0.1),
        (1, 2048, 16, 2, 64, 1.0),
    ])
def test_decode_attention_sweep(b, length, hq, hkv, d, frac, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    kc = jax.random.normal(ks[1], (b, length, hkv, d), dtype)
    vc = jax.random.normal(ks[2], (b, length, hkv, d), dtype)
    pos = jnp.array(int(frac * (length - 1)), jnp.int32)
    kpos = jnp.where(jnp.arange(length) <= pos, jnp.arange(length), -1)
    out = dispatch.decode_attention(q, kc, vc, kpos, pos, backend="pallas")
    want = ref.decode_attention_ref(q, kc, vc, kpos, pos)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_decode_attention_ring_cache():
    """Ring-buffer (sliding window) cache: slots hold rotated positions."""
    b, length, h, d = 1, 256, 4, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d))
    kc = jax.random.normal(ks[1], (b, length, h, d))
    vc = jax.random.normal(ks[2], (b, length, h, d))
    pos = jnp.array(1000, jnp.int32)   # far beyond cache_len
    idx = jnp.arange(length)
    cand = pos - (pos % length) + idx
    kpos = jnp.where(cand > pos, cand - length, cand)
    out = dispatch.decode_attention(q, kc, vc, kpos, pos, backend="pallas")
    want = ref.decode_attention_ref(q, kc, vc, kpos, pos)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("shape", [(64,), (1000,), (128, 128), (7, 321),
                                   (3, 5, 7)])
@pytest.mark.parametrize("lr", [1e-4, 1e-2])
def test_rmsprop_kernel_sweep(shape, lr):
    ks = jax.random.split(KEY, 2)
    g = jnp.abs(jax.random.normal(ks[0], shape))
    dg = jax.random.normal(ks[1], shape)
    new_g, upd = dispatch.rmsprop_update(g, dg, lr=lr)
    ng_ref, upd_ref = ref.rmsprop_update_ref(g, dg, lr=lr)
    np.testing.assert_allclose(new_g, ng_ref, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(upd, upd_ref, rtol=1e-5, atol=1e-9)


def test_flash_jnp_blockwise_matches_kernel():
    """The three implementations (naive, blockwise-jnp, Pallas) agree."""
    from repro.models.flash_jnp import flash_attention_jnp
    ks = jax.random.split(KEY, 3)
    b, s, hq, hkv, d = 1, 512, 4, 2, 64
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    o_ref = ref.flash_attention_ref(q, k, v, causal=True)
    o_jnp = flash_attention_jnp(q, k, v, True, None, 128)
    o_pl = dispatch.flash_attention(q, k, v, causal=True, backend="pallas")
    np.testing.assert_allclose(o_jnp, o_ref, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(o_pl, o_ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("shape", [(8, 128), (2, 16, 256), (64, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel_sweep(shape, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], shape, dtype)
    scale = 1.0 + 0.1 * jax.random.normal(ks[1], (shape[-1],))
    out = dispatch.rmsnorm(x, scale, backend="pallas")
    want = ref.rmsnorm_ref(x, scale)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_bwd_skips_fully_masked_tiles():
    """Small blocks + small window => whole score tiles fully masked in the
    bwd grids; the predicated kernels must still match the jnp oracle."""
    from repro.kernels.flash_attention import masked_tile_fraction
    from repro.kernels.flash_attention_bwd import flash_attention_bwd
    from repro.kernels.flash_attention import flash_attention_fwd
    from repro.models.flash_jnp import flash_attention_jnp
    b, s, hq, hkv, d, win = 1, 512, 4, 2, 64, 128
    assert masked_tile_fraction(s, 128, 128, True, win) > 0.4
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    do = jax.random.normal(ks[3], (b, s, hq, d))
    o, lse = flash_attention_fwd(q, k, v, causal=True, window=win,
                                 block_q=128, block_k=128,
                                 save_residuals=True)
    dq, dk, dv = flash_attention_bwd(q, k, v, o, lse, do, causal=True,
                                     window=win, block_q=128, block_k=128)
    g_ref = jax.grad(lambda q, k, v: jnp.sum(
        flash_attention_jnp(q, k, v, True, win, 128) * do),
        argnums=(0, 1, 2))(q, k, v)
    for got, want, name in zip((dq, dk, dv), g_ref, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-2, rtol=2e-2, err_msg=name)


def test_ops_shim_is_gone_and_lint_passes():
    """kernels.ops served one deprecation cycle and is deleted; the tree
    must not import it (enforced by the repro-audit ``no-ops-import``
    pass — run through the ``python -m tools.audit`` runner here so the
    lint is also a tier-1 test)."""
    import importlib
    import subprocess
    import sys
    with pytest.raises(ModuleNotFoundError):
        importlib.import_module("repro.kernels.ops")  # lint: allow-ops-ref
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run(
        [sys.executable, "-m", "tools.audit", "--strict",
         "--only", "no-ops-import"],
        cwd=root, capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr


@pytest.mark.parametrize("shape", [(64, 256), (2, 16, 128)])
def test_rmsnorm_vjp_kernel_matches_ad(shape):
    """The fused one-pass dx/dscale backward vs AD through the reference."""
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], shape)
    scale = 1.0 + 0.1 * jax.random.normal(ks[1], (shape[-1],))
    dy = jax.random.normal(ks[2], shape)

    def loss(fn):
        return lambda x, s: jnp.sum(fn(x, s).astype(jnp.float32) * dy)

    g_pl = jax.grad(loss(lambda x, s: dispatch.rmsnorm(
        x, s, backend="pallas")), argnums=(0, 1))(x, scale)
    g_rf = jax.grad(loss(lambda x, s: ref.rmsnorm_ref(x, s)),
                    argnums=(0, 1))(x, scale)
    for got, want, name in zip(g_pl, g_rf, ("dx", "dscale")):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-4, err_msg=name)
