"""Optimizer tests: paper Eq. 8-9 math, fused-kernel equivalence, and
hypothesis invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.optim import optimizers as opt_mod


def _tree():
    return {"a": jnp.array([1.0, -2.0, 3.0]),
            "b": {"w": jnp.ones((4, 5)) * 0.5}}


def test_shared_rmsprop_formula():
    opt = opt_mod.shared_rmsprop(alpha=0.9, eps=0.1)
    params = _tree()
    state = opt.init(params)
    grads = jax.tree.map(lambda p: 2.0 * jnp.ones_like(p), params)
    updates, state = opt.update(grads, state, 0.01)
    g_expect = 0.1 * 4.0  # alpha*0 + (1-alpha)*g^2
    np.testing.assert_allclose(state["g"]["a"], g_expect, rtol=1e-6)
    np.testing.assert_allclose(
        updates["a"], 0.01 * 2.0 / np.sqrt(g_expect + 0.1), rtol=1e-6)


def test_fused_matches_unfused():
    params = {"w": jnp.asarray(np.random.RandomState(0)
                               .randn(64, 40).astype(np.float32))}
    grads = {"w": jnp.asarray(np.random.RandomState(1)
                              .randn(64, 40).astype(np.float32))}
    o1 = opt_mod.shared_rmsprop()
    o2 = opt_mod.shared_rmsprop(fused=True)
    s1, s2 = o1.init(params), o2.init(params)
    u1, s1 = o1.update(grads, s1, 1e-3)
    u2, s2 = o2.update(grads, s2, 1e-3)
    np.testing.assert_allclose(u1["w"], u2["w"], rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(s1["g"]["w"], s2["g"]["w"], rtol=1e-5)


def test_momentum_sgd():
    opt = opt_mod.momentum_sgd(alpha=0.5)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    g = {"w": jnp.ones(3)}
    u1, state = opt.update(g, state, 1.0)
    np.testing.assert_allclose(u1["w"], 0.5)          # (1-a)*g
    u2, state = opt.update(g, state, 1.0)
    np.testing.assert_allclose(u2["w"], 0.75)         # a*m + (1-a)*g


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(1, 5))
def test_g_stays_nonnegative_and_update_sign(seed, steps):
    """Invariants: the second-moment accumulator is nonnegative; updates
    have the sign of the gradient (descent direction)."""
    rng = np.random.RandomState(seed)
    opt = opt_mod.shared_rmsprop()
    params = {"w": jnp.zeros(16)}
    state = opt.init(params)
    for _ in range(steps):
        g = {"w": jnp.asarray(rng.randn(16).astype(np.float32))}
        updates, state = opt.update(g, state, 1e-2)
        assert bool(jnp.all(state["g"]["w"] >= 0))
        assert bool(jnp.all(jnp.sign(updates["w"]) == jnp.sign(g["w"])))


def test_apply_updates_subtracts():
    params = {"w": jnp.ones(3)}
    out = opt_mod.apply_updates(params, {"w": jnp.full((3,), 0.25)})
    np.testing.assert_allclose(out["w"], 0.75)
