"""Context-parallel (flash-decoding) decode vs the dense decode path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs import get_config
from repro.distributed import ctx, sharding
from repro.models import model as M

MESH = jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ["qwen2-72b", "stablelm-1.6b",
                                  "llama4-scout-17b-a16e"])
def test_decode_cp_matches_dense(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0,
                                cfg.vocab_size)
    c1 = M.init_cache(cfg, b, s, dtype=jnp.float32)
    c2 = M.init_cache(cfg, b, s, dtype=jnp.float32)
    rules = sharding.decode_rules(cfg, MESH, batch_size=b)
    for t in range(s):
        tb = {"tokens": tokens[:, t:t + 1]}
        o1, c1 = M.decode_step(cfg, params, c1, tb, jnp.asarray(t))
        with compat.set_mesh(MESH), ctx.sharding_rules(rules):
            o2, c2 = M.decode_step(cfg, params, c2, tb, jnp.asarray(t))
        np.testing.assert_allclose(np.asarray(o1["logits"]),
                                   np.asarray(o2["logits"]),
                                   atol=2e-4, rtol=2e-4)


def test_decode_cp_ring_cache():
    """Sliding-window ring cache under context-parallel decode."""
    import dataclasses
    cfg = get_config("stablelm-1.6b").reduced()
    cfg = dataclasses.replace(cfg, block_cycle=("attn_local",),
                              sliding_window=8)
    params = M.init_params(cfg, jax.random.key(0))
    b, s = 1, 24
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0,
                                cfg.vocab_size)
    full = M.forward(cfg, params, {"tokens": tokens})["logits"]
    cache = M.init_cache(cfg, b, s, dtype=jnp.float32)
    rules = sharding.decode_rules(cfg, MESH, batch_size=b)
    outs = []
    with compat.set_mesh(MESH), ctx.sharding_rules(rules):
        for t in range(s):
            out, cache = M.decode_step(cfg, params, cache,
                                       {"tokens": tokens[:, t:t + 1]},
                                       jnp.asarray(t))
            outs.append(out["logits"][:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-3, rtol=2e-3)
