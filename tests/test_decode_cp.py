"""Context-parallel (flash-decoding) decode vs the dense decode path.

Since the unification (PR 3) there is one decode entry point:
``attend_decode`` writes the cache on the owning seq shard when the
``decode_cp`` rules apply and routes the attention through
``dispatch.decode_attention``, whose ``pallas_cp`` arm does the partials
kernel + psum combine (jnp fallback for misaligned smoke shapes — what the
(1, 1)-mesh cases here exercise).  The multi-device cases need
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` (CI's host-mesh
matrix leg).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs import get_config
from repro.distributed import ctx, sharding
from repro.kernels import dispatch
from repro.models import model as M

MESH = jax.make_mesh((1, 1), ("data", "model"))
MULTI = len(jax.devices()) >= 2


@pytest.mark.parametrize("arch", ["qwen2-72b", "stablelm-1.6b",
                                  "llama4-scout-17b-a16e"])
def test_decode_cp_matches_dense(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0,
                                cfg.vocab_size)
    c1 = M.init_cache(cfg, b, s, dtype=jnp.float32)
    c2 = M.init_cache(cfg, b, s, dtype=jnp.float32)
    rules = sharding.decode_rules(cfg, MESH, batch_size=b)
    for t in range(s):
        tb = {"tokens": tokens[:, t:t + 1]}
        o1, c1 = M.decode_step(cfg, params, c1, tb, jnp.asarray(t))
        with compat.set_mesh(MESH), ctx.sharding_rules(rules):
            o2, c2 = M.decode_step(cfg, params, c2, tb, jnp.asarray(t))
        np.testing.assert_allclose(np.asarray(o1["logits"]),
                                   np.asarray(o2["logits"]),
                                   atol=2e-4, rtol=2e-4)


@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
def test_decode_cp_multidevice_resolves_pallas_cp():
    """Full decode_step on a real 2-shard seq-sharded cache: the dispatch
    summary must show pallas_cp (no 'context-parallel rules own the cache'
    fallback), and logits must match the unruled dense path."""
    cfg = get_config("stablelm-1.6b").reduced()
    params = M.init_params(cfg, jax.random.key(0))
    b, cache_len, steps = 2, 256, 4
    tokens = jax.random.randint(jax.random.key(1), (b, steps), 0,
                                cfg.vocab_size)
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    c1 = M.init_cache(cfg, b, cache_len, dtype=jnp.float32)
    c2 = M.init_cache(cfg, b, cache_len, dtype=jnp.float32)
    rules = sharding.decode_rules(cfg, mesh, batch_size=b)
    assert rules["decode_cp"]["n_shards"] == 2
    for t in range(steps):
        tb = {"tokens": tokens[:, t:t + 1]}
        o1, c1 = M.decode_step(cfg, params, c1, tb, jnp.asarray(t))
        with compat.set_mesh(mesh), ctx.sharding_rules(rules):
            dispatch.clear_decision_log()
            o2, c2 = M.decode_step(cfg, params, c2, tb, jnp.asarray(t))
            d = dispatch.last_decision("decode_attention")
            assert d is not None and d.backend == "pallas_cp", d
            assert not any("context-parallel rules own the cache" in
                           r["reason"] and r["backend"] == "jnp"
                           for r in dispatch.decision_summary())
        np.testing.assert_allclose(np.asarray(o1["logits"]),
                                   np.asarray(o2["logits"]),
                                   atol=2e-4, rtol=2e-4)


def test_decode_cp_ring_cache():
    """Sliding-window ring cache under context-parallel decode."""
    import dataclasses
    cfg = get_config("stablelm-1.6b").reduced()
    cfg = dataclasses.replace(cfg, block_cycle=("attn_local",),
                              sliding_window=8)
    params = M.init_params(cfg, jax.random.key(0))
    b, s = 1, 24
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0,
                                cfg.vocab_size)
    full = M.forward(cfg, params, {"tokens": tokens})["logits"]
    cache = M.init_cache(cfg, b, s, dtype=jnp.float32)
    rules = sharding.decode_rules(cfg, MESH, batch_size=b)
    outs = []
    with compat.set_mesh(MESH), ctx.sharding_rules(rules):
        for t in range(s):
            out, cache = M.decode_step(cfg, params, cache,
                                       {"tokens": tokens[:, t:t + 1]},
                                       jnp.asarray(t))
            outs.append(out["logits"][:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-3, rtol=2e-3)
