"""T3 delayed-sync (bounded-staleness pod-scale asynchrony) tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import delayed_sync
from repro.data.pipeline import TokenPipeline
from repro.models import model as M
from repro.optim import optimizers as opt_mod


def test_merge_every_semantics():
    tree = jnp.stack([jnp.ones(3), 3 * jnp.ones(3)])
    merged = delayed_sync.merge_every(jnp.asarray(2), 2, tree)
    np.testing.assert_allclose(merged, 2.0)     # step 2 % 2 == 0 -> merge
    kept = delayed_sync.merge_every(jnp.asarray(3), 2, tree)
    np.testing.assert_allclose(kept, tree)


def test_groups_converge_at_merge_points():
    cfg = get_config("stablelm-1.6b").reduced()
    n_groups, h = 2, 3
    params = M.init_params(cfg, jax.random.key(0))
    params_g = delayed_sync.replicate(params, n_groups)
    opt = opt_mod.shared_rmsprop()
    opt_state_g = delayed_sync.replicate(opt.init(params), n_groups)
    step = jax.jit(delayed_sync.make_delayed_train_step(
        cfg, opt, n_groups=n_groups, merge_interval=h, lr=1e-3))
    pipe = TokenPipeline(vocab=cfg.vocab_size, seq_len=32, global_batch=2)

    def group_spread(tree):
        return max(float(jnp.max(jnp.abs(leaf[0] - leaf[1])))
                   for leaf in jax.tree.leaves(tree))

    for i in range(h):
        batch = jax.vmap(lambda k: pipe.batch(k, i))(
            jax.random.split(jax.random.key(i), n_groups))
        params_g, opt_state_g, m = step(params_g, opt_state_g, batch,
                                        jnp.asarray(i))
        spread = group_spread(params_g)
        if i < h - 1:
            assert spread > 0.0       # groups drift between merges
        else:
            assert spread == 0.0      # merge point: identical again
