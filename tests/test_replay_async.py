"""Beyond-paper: replay mixed into the async framework (paper Conclusions)."""
import jax
import jax.numpy as jnp

from repro.core import agents, replay_async
from repro.envs import make
from repro.envs.api import flatten_obs
from repro.models import atari as nets


def test_replay_async_runs_and_fills_buffers():
    env = flatten_obs(make("catch"))
    algo = agents.ALGORITHMS["n_step_q"]()
    params = nets.init_mlp_agent_params(jax.random.key(0),
                                        env.obs_shape[0], env.n_actions,
                                        hidden=32)
    cfg = replay_async.ReplayAsyncConfig(n_workers=4, t_max=5,
                                         buffer_size=64, replay_batch=8,
                                         warmup=16)
    init_state, round_fn = replay_async.make_replay_runner(
        algo, env, params, cfg)
    st = init_state(jax.random.key(1))
    for _ in range(8):
        st, m = round_fn(st)
    assert int(st["filled"][0]) == 40
    assert bool(jnp.isfinite(m["loss"]))


def test_gae_a3c_option():
    env = flatten_obs(make("catch"))
    from repro.core.rollout import init_worker, rollout_segment
    for lam in (0.0, 0.95):
        algo = agents.ALGORITHMS["a3c"](gae_lambda=lam)
        params = nets.init_mlp_agent_params(jax.random.key(0),
                                            env.obs_shape[0],
                                            env.n_actions, hidden=16)
        w = init_worker(env, jax.random.key(2))
        _, traj = rollout_segment(
            lambda o, n, k: algo.act(params, o, n, k, 0.1), env, w, 5)
        loss, _ = algo.segment_loss(params, None, traj)
        assert bool(jnp.isfinite(loss))
