"""Algorithm-level tests: the four async methods (paper §4.1-4.4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import agents
from repro.envs import make
from repro.envs.api import flatten_obs
from repro.core.rollout import init_worker, rollout_segment
from repro.models import atari as nets

ENV = flatten_obs(make("catch"))
KEY = jax.random.key(0)


def _traj(algo, params, t_max=6):
    w = init_worker(ENV, KEY)
    def act(obs, ns, key):
        return algo.act(params, obs, ns, key, 0.3)
    _, traj = rollout_segment(act, ENV, w, t_max)
    return traj


@pytest.mark.parametrize("name", list(agents.ALGORITHMS))
def test_loss_finite_and_grads_flow(name):
    algo = agents.ALGORITHMS[name]()
    params = nets.init_mlp_agent_params(KEY, ENV.obs_shape[0],
                                        ENV.n_actions, hidden=32)
    traj = _traj(algo, params)
    loss, metrics = algo.segment_loss(params, params, traj)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: algo.segment_loss(p, params, traj)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gnorm > 0


def test_one_step_q_target_hand_computed():
    """y = r + gamma * max_a Q_target(s', a) on a fabricated trajectory."""
    algo = agents.ALGORITHMS["one_step_q"](gamma=0.5)
    params = nets.init_mlp_agent_params(KEY, 4, 2, hidden=8)
    obs = jnp.zeros((3, 4))
    traj = {"obs": obs, "actions": jnp.array([0, 1]),
            "rewards": jnp.array([1.0, 2.0]),
            "dones": jnp.array([False, True])}
    feats, _ = nets.trunk(params, obs, None)
    q = nets.q_heads(params, feats)
    y0 = 1.0 + 0.5 * float(jnp.max(q[1]))
    y1 = 2.0  # terminal
    qa = jnp.array([q[0, 0], q[1, 1]])
    expect = float(jnp.mean((jnp.array([y0, y1]) - qa) ** 2))
    loss, _ = algo.segment_loss(params, params, traj)
    np.testing.assert_allclose(float(loss), expect, rtol=1e-5)


def test_a3c_policy_gradient_direction():
    """Positive-advantage actions get more probable after one SGD step."""
    algo = agents.ALGORITHMS["a3c"](gamma=0.9, beta=0.0)
    params = nets.init_mlp_agent_params(KEY, 4, 3, hidden=8)
    obs = jnp.ones((4, 4))
    traj = {"obs": obs, "actions": jnp.array([2, 2, 2]),
            "rewards": jnp.array([5.0, 5.0, 5.0]),
            "dones": jnp.array([False, False, True])}
    grads = jax.grad(lambda p: algo.segment_loss(p, p, traj)[0])(params)
    lr = 1e-2
    new_params = jax.tree.map(lambda p, g: p - lr * g, params, grads)

    def prob_a2(p):
        feats, _ = nets.trunk(p, obs[:1], None)
        return float(jax.nn.softmax(
            nets.actor_critic_heads(p, feats)["logits"])[0, 2])

    assert prob_a2(new_params) > prob_a2(params)


def test_continuous_a3c_loss():
    algo = agents.ALGORITHMS["a3c"](continuous=True)
    env = make("pointmass")
    params = nets.init_mlp_agent_params(KEY, env.obs_shape[0],
                                        env.n_actions, hidden=16,
                                        continuous=True)
    w = init_worker(env, KEY)
    def act(obs, ns, key):
        return algo.act(params, obs, ns, key, 0.0)
    _, traj = rollout_segment(act, env, w, 5)
    loss, m = algo.segment_loss(params, None, traj)
    assert bool(jnp.isfinite(loss))


def test_lstm_agent_rollout_and_loss():
    algo = agents.ALGORITHMS["a3c"]()
    params = nets.init_mlp_agent_params(KEY, ENV.obs_shape[0],
                                        ENV.n_actions, hidden=16, lstm=True,
                                        lstm_size=8)
    ns0 = nets.init_lstm_state(1, 8)
    w = init_worker(ENV, KEY, net_state0=ns0)
    def act(obs, ns, key):
        return algo.act(params, obs, ns, key, 0.0)
    _, traj = rollout_segment(act, ENV, w, 5)
    assert "net_state" in traj
    loss, _ = algo.segment_loss(params, None, traj)
    assert bool(jnp.isfinite(loss))
