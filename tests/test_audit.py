"""The repro-audit suite is itself a tier-1 surface: the clean tree must
pass ``--strict``, and every pass family must flag its known-bad fixture
(a checker that cannot fail is not checking anything)."""
import importlib.util
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(ROOT, "tools", "audit", "fixtures")
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)       # makes `tools.audit` importable

from tools.audit import run_audit                      # noqa: E402
from tools.audit import alloc_model, ast_passes, contracts, \
    kernel_check                                       # noqa: E402
from tools.audit.framework import summary_line         # noqa: E402


def _load_fixture(name):
    path = os.path.join(FIXTURES, name)
    spec = importlib.util.spec_from_file_location(
        "audit_fixture_" + os.path.basename(name)[:-3], path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# clean tree
# ---------------------------------------------------------------------------

def test_strict_audit_clean_on_tree(tmp_path):
    """The committed tree passes every audit pass; AUDIT.json carries the
    allocator coverage counters the acceptance contract pins."""
    report = run_audit(ROOT, strict=True)
    bad = [v for p in report["passes"] for v in p["violations"]]
    assert not bad, "\n".join(f"{v['path']}:{v['line']}: {v['message']}"
                              for v in bad)
    assert report["summary"]["passes_failed"] == 0
    # all four families ran
    assert {p["family"] for p in report["passes"]} == \
        {"ast", "contract", "kernel", "allocator"}
    # the interleaving check actually explored state space and reached
    # both the COW-fork and recycled-page-reuse paths
    am = report["allocator_model"]
    assert am["states_explored"] >= alloc_model.STATE_FLOOR
    assert am["cow_forks"] > 0
    assert am["recycle_reuse"] > 0
    assert am["reserved_allocs"] > 0 and am["preempts"] > 0
    assert am["spec_allocs"] > 0 and am["rewinds"] > 0 \
        and am["spec_commits"] > 0
    # the kernel checker exercised multi-block grids
    kstats = next(p["stats"] for p in report["passes"]
                  if p["name"] == "kernel-check")
    assert kstats["pallas_calls"] >= 10
    assert kstats["grid_points_checked"] > 100
    line = summary_line(report)
    assert line.startswith("audit,ok,") and "violations=0" in line
    # report round-trips through json
    json.loads(json.dumps(report))


def test_cli_runner_strict_exit_code(tmp_path):
    """``python -m tools.audit --strict`` (the CI entry) exits 0 on the
    clean tree and writes the AUDIT.json artifact where asked."""
    out = tmp_path / "AUDIT.json"
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "tools.audit", "--strict", "--only", "ast",
         "--only", "contract", "--only", "allocator", "--json", str(out)],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    report = json.loads(out.read_text())
    assert report["summary"]["violations"] == 0
    assert report["allocator_model"]["cow_forks"] > 0


def test_subset_run_never_clobbers_root_artifact(tmp_path):
    """A ``--only`` subset run without ``--json`` must not overwrite the
    committed <repo>/AUDIT.json — a 1-pass report in the full-suite slot
    misrepresents coverage (the artifact CI uploads and consumers diff)."""
    root_artifact = os.path.join(ROOT, "AUDIT.json")
    before = open(root_artifact, "rb").read()
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "tools.audit", "--strict",
         "--only", "no-ops-import"],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "report:" not in proc.stdout       # no report file claimed
    assert open(root_artifact, "rb").read() == before
    # an explicit --json still writes the subset report where asked
    out = tmp_path / "subset.json"
    proc = subprocess.run(
        [sys.executable, "-m", "tools.audit", "--strict",
         "--only", "no-ops-import", "--json", str(out)],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert json.loads(out.read_text())["summary"]["passes_total"] == 1


# ---------------------------------------------------------------------------
# AST passes vs fixtures
# ---------------------------------------------------------------------------

AST_CASES = [
    ("no-ops-import", "bad_ast/ops_import.py", 4),
    ("kernel-import-containment", "bad_ast/kernel_import.py", 3),
    ("no-step-key-rebuild", "bad_ast/step_key.py", 2),
    ("no-default-backend", "bad_ast/repro/kernels/default_backend.py", 1),
    ("fallback-reason", "bad_ast/repro/kernels/bare_fallback.py", 2),
]


@pytest.mark.parametrize("pass_name,fixture,n_min",
                         [pytest.param(*c, id=c[0]) for c in AST_CASES])
def test_ast_pass_flags_fixture(pass_name, fixture, n_min):
    p = next(p for p in ast_passes.PASSES if p.name == pass_name)
    res = ast_passes.run_pass(p, ROOT,
                              files=[os.path.join(FIXTURES, fixture)])
    assert len(res.violations) >= n_min, \
        f"{pass_name} missed its fixture: {[v.format() for v in res.violations]}"
    assert all(v.pass_name == pass_name for v in res.violations)


def test_step_key_pass_spares_setup_code():
    """Keys built OUTSIDE step functions are legitimate — the fixture's
    ``warmup`` must not be flagged."""
    p = next(p for p in ast_passes.PASSES
             if p.name == "no-step-key-rebuild")
    res = ast_passes.run_pass(
        p, ROOT, files=[os.path.join(FIXTURES, "bad_ast/step_key.py")])
    assert not any("warmup" in v.message for v in res.violations)


def test_ops_import_allow_escape(tmp_path):
    """The ``lint: allow-ops-ref`` escape suppresses a flagged line —
    tests asserting the import FAILS rely on it."""
    f = tmp_path / "escape.py"
    f.write_text("import importlib\n"
                 "importlib.import_module('repro.kernels' + '.ops')"
                 "  # lint: allow-ops-ref\n")
    p = next(p for p in ast_passes.PASSES if p.name == "no-ops-import")
    res = ast_passes.run_pass(p, ROOT, files=[str(f)])
    assert res.ok, [v.format() for v in res.violations]


# ---------------------------------------------------------------------------
# contract passes
# ---------------------------------------------------------------------------

def test_decision_rows_flags_silent_resolver():
    res = contracts.check_decision_rows(
        ROOT, dispatch_src=os.path.join(FIXTURES, "bad_dispatch.py"))
    silent = [v for v in res.violations if "without a _decide" in v.message]
    assert silent, [v.format() for v in res.violations]
    assert all(v.pass_name == "resolver-decision-rows"
               for v in res.violations)


def test_registry_covers_every_backend_entry():
    """Every public dispatch entry taking backend= is registered in
    KERNEL_OPS — the reverse-direction contract that keeps new arms from
    escaping the audit."""
    res = contracts.check_registry_oracles(ROOT)
    assert res.ok, [v.format() for v in res.violations]
    assert res.stats["ops"] >= 7


def test_cache_leaf_sharding_contract():
    """Every cache leaf (f32/int8 x contiguous/paged, scale leaves
    included) hits an explicit cache_shardings rule, rank-matched to its
    payload."""
    res = contracts.check_cache_leaf_sharding(ROOT)
    assert res.ok, [v.format() for v in res.violations]
    assert res.stats["leaves_checked"] >= 16


# ---------------------------------------------------------------------------
# kernel checker vs fixture
# ---------------------------------------------------------------------------

def test_kernel_checker_flags_bad_kernel():
    import jax
    bad = _load_fixture("bad_kernel.py")
    with kernel_check.PallasCapture() as cap:
        cap.case = "bad_kernel"
        jax.eval_shape(bad.run)
    assert len(cap.records) == 1
    v = kernel_check.check_record(cap.records[0])
    msgs = " | ".join(x.message for x in v)
    assert "out of bounds" in msgs, msgs
    assert "write race" in msgs, msgs
    assert "exceeds budget" in msgs, msgs


def test_kernel_checker_budget_is_configurable():
    """A tighter budget flags even the healthy decode kernel — proves the
    VMEM accounting is live, not vacuously passing."""
    results = kernel_check.run_kernel_checks(ROOT, vmem_budget=1024)
    assert any("exceeds budget" in v.message
               for r in results for v in r.violations)


# ---------------------------------------------------------------------------
# allocator interleaving vs fixture
# ---------------------------------------------------------------------------

def test_alloc_model_flags_missing_version_bump():
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro.launch.serve import AllocatorModel
    bad = _load_fixture("bad_alloc.py")
    violations, stats = alloc_model.explore(
        AllocatorModel(n_pages=4,
                       allocator_cls=bad.NoVersionBumpAllocator))
    assert any("version" in v.message for v in violations), \
        [v.format() for v in violations]
    assert stats["states_explored"] > 1


def test_alloc_replay_flags_refcount_underflow():
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro.launch.serve import PageAllocator
    bad = _load_fixture("bad_alloc.py")
    v = alloc_model.replay_trace(PageAllocator(4), bad.UNDERFLOW_TRACE)
    assert any("negative" in x.message for x in v), \
        [x.format() for x in v]


def test_alloc_replay_flags_rollback_leak():
    """A verify round that pre-allocates two speculative pages but only
    rewinds one leaks the other's refcount — the replay harness must
    report the unresolved hold when the trace ends."""
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro.launch.serve import PageAllocator
    bad = _load_fixture("bad_alloc.py")
    v = alloc_model.replay_trace(PageAllocator(4),
                                 bad.LEAKY_ROLLBACK_TRACE)
    assert any("never rewound or committed" in x.message for x in v), \
        [x.format() for x in v]
    # the balanced round is clean: both pages resolved
    ok = alloc_model.replay_trace(
        PageAllocator(4), (("spec_alloc",), ("spec_alloc",),
                           ("rewind", 2), ("commit", 1)))
    assert not ok, [x.format() for x in ok]


def test_alloc_model_flags_phantom_reservation():
    """An allocator whose ``reserve`` never checks capacity breaks the
    "reserved allocs cannot fail" contract — the explorer must reach an
    overbooked state and flag it (and nothing else: this fixture's
    version/refcount discipline is correct)."""
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro.launch.serve import AllocatorModel
    bad = _load_fixture("bad_alloc.py")
    violations, stats = alloc_model.explore(
        AllocatorModel(n_pages=4,
                       allocator_cls=bad.PhantomReserveAllocator))
    assert any("reserved" in v.message and "exceeds free" in v.message
               for v in violations), [v.format() for v in violations]
    assert not any("version" in v.message for v in violations)


def test_alloc_model_real_allocator_is_clean():
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro.launch.serve import AllocatorModel
    violations, stats = alloc_model.explore(AllocatorModel(n_pages=4))
    assert not violations, [v.format() for v in violations]
    assert stats["cow_forks"] > 0 and stats["recycle_reuse"] > 0
    # the robustness ops are part of the modeled vocabulary, and the
    # state count clears the anti-shrink floor the strict run enforces
    assert stats["reserve_ops"] > 0
    assert stats["reserved_allocs"] > 0
    assert stats["preempts"] > 0
    # the speculative family (verify pre-alloc, rejected-draft rewind,
    # accepted-draft commit) is modeled and reached
    assert stats["spec_allocs"] > 0
    assert stats["rewinds"] > 0
    assert stats["spec_commits"] > 0
    assert stats["states_explored"] >= alloc_model.STATE_FLOOR


# ---------------------------------------------------------------------------
# regression pins for violations fixed in this change
# ---------------------------------------------------------------------------

def test_default_interpret_follows_lowering_target(monkeypatch):
    """Kernel modules used to key interpret-mode off the HOST backend
    (``jax.default_backend() == "cpu"``); they now follow the lowering
    target, so a CPU host lowering for a TPU mesh compiles Mosaic instead
    of silently interpreting."""
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro.kernels import _interpret
    assert _interpret.default_interpret() is True      # CPU dev box
    monkeypatch.setattr(_interpret.ctx, "current_platform", lambda: "tpu")
    assert _interpret.default_interpret() is False
    monkeypatch.setattr(_interpret.ctx, "current_platform",
                        lambda: "gpu")
    assert _interpret.default_interpret() is True      # TPU-only kernels


def test_no_kernel_module_reads_default_backend():
    """The concrete violations this audit surfaced (5 sites keying
    interpret off the host platform) stay fixed."""
    p = next(p for p in ast_passes.PASSES
             if p.name == "no-default-backend")
    res = ast_passes.run_pass(p, ROOT)
    assert res.ok, [v.format() for v in res.violations]
