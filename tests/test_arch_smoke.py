"""Per-architecture smoke tests (spec deliverable f): reduced variant of each
assigned family — forward + one train step on CPU, asserting output shapes
and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALIASES, get_config
from repro.core import llm_a3c
from repro.models import model as M
from repro.optim import optimizers as opt_mod

ARCHS = list(ALIASES)


def _batch(cfg, b, s, key):
    if cfg.family == "vlm":
        batch = {"embeds": 0.02 * jax.random.normal(key, (b, s, cfg.d_model)),
                 "positions": jnp.broadcast_to(
                     jnp.arange(s)[None, None], (3, b, s)).astype(jnp.int32),
                 "actions": jax.random.randint(key, (b, s), 0,
                                               cfg.vocab_size)}
    else:
        batch = {"tokens": jax.random.randint(key, (b, s), 0,
                                              cfg.vocab_size)}
    if cfg.is_encdec:
        batch["enc_frames"] = 0.02 * jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model))
    batch["rewards"] = jax.random.bernoulli(key, 0.3, (b, s)) \
        .astype(jnp.float32)
    batch["discounts"] = jnp.full((b, s), 0.99)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    key = jax.random.key(0)
    params = M.init_params(cfg, key)
    b, s = 2, 32
    batch = _batch(cfg, b, s, key)
    out = M.forward(cfg, params, batch)
    assert out["logits"].shape == (b, s, cfg.vocab_size)
    assert out["value"].shape == (b, s)
    assert bool(jnp.all(jnp.isfinite(out["logits"])))
    assert bool(jnp.all(jnp.isfinite(out["value"])))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.key(1)
    params = M.init_params(cfg, key)
    opt = opt_mod.shared_rmsprop()
    opt_state = opt.init(params)
    train_step = jax.jit(llm_a3c.make_train_step(cfg, opt))
    batch = _batch(cfg, 2, 32, key)
    params2, opt_state2, metrics = train_step(params, opt_state, batch,
                                              jnp.asarray(0))
    assert bool(jnp.isfinite(metrics["loss"]))
    # parameters actually moved
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(a - b))),
                     params, params2))
    assert moved > 0.0
    # no NaNs anywhere in the updated tree
    for leaf in jax.tree.leaves(params2):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.key(2)
    params = M.init_params(cfg, key)
    b = 2
    cache = M.init_cache(cfg, b, 64, dtype=jnp.float32)
    serve = llm_a3c.make_serve_step(cfg)
    batch = ({"embeds": jnp.zeros((b, 1, cfg.d_model)),
              "positions": jnp.zeros((3, b, 1), jnp.int32)}
             if cfg.family == "vlm" else
             {"tokens": jnp.zeros((b, 1), jnp.int32)})
    tok, value, cache = serve(params, cache, batch, jnp.asarray(0),
                              jax.random.key(0))
    assert tok.shape == (b,)
    assert bool(jnp.all((tok >= 0) & (tok < cfg.vocab_size)))
