"""Append-mode flash attention: kernel parity, dispatch resolution, and
multi-chunk prefill equivalence.

The append kernel decouples the q and kv grid dimensions (chunk queries at
absolute positions ``pos0 + i`` over the cache prefix plus the chunk), so
every prefill chunk — not just the first — runs the fused path.  The jnp
oracle in ``ref.flash_attention_append_ref`` is the allclose target, and
is itself pinned against the masked-sdpa construction the old
``attend_prefill`` prefix branch used.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed import ctx
from repro.kernels import dispatch, ref
from repro.models import model as M

KEY = jax.random.key(11)


def _qkv(b, c, sk, hq, hkv, d, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, c, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, sk, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, sk, hkv, d), dtype)
    return q, k, v


def _linear_kpos(sk, pos0, c):
    idx = jnp.arange(sk)
    return jnp.where(idx < pos0 + c, idx, -1)


def _ring_kpos(length, pos0):
    """Rotated ring prefix: slot s holds the largest position ≡ s (mod
    length) written before pos0 (-1 if none)."""
    idx = jnp.arange(length)
    pos = pos0 - 1
    cand = pos - (pos % length) + idx
    cand = jnp.where(cand > pos, cand - length, cand)
    return jnp.where(cand >= 0, cand, -1)


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,c,pos0,hq,hkv,d,window",
    [
        (1, 128, 0, 4, 4, 64, None),       # chunk 0 == square causal
        (2, 128, 256, 4, 1, 64, None),     # GQA g=4, later chunk
        (1, 256, 256, 8, 2, 64, None),     # GQA g=4, 256-wide chunk
        (1, 128, 384, 4, 4, 64, 128),      # window: prefix tiles skipped
        (1, 128, 1920, 4, 2, 64, None),    # deep prefix (final 2048 chunk)
    ])
def test_append_kernel_matches_oracle(b, c, pos0, hq, hkv, d, window,
                                      dtype):
    sk = pos0 + c
    q, k, v = _qkv(b, c, sk, hq, hkv, d, dtype)
    kpos = _linear_kpos(sk, pos0, c)
    out = dispatch.flash_attention_append(q, k, v, kpos, pos0=pos0,
                                          window=window, kpos_linear=True,
                                          backend="pallas")
    want = ref.flash_attention_append_ref(q, k, v, kpos, pos0=pos0,
                                          window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_append_kernel_ring_prefix():
    """Rotated (ring) key layout: kpos carries the rotation, no tile skip
    (kpos_linear=False), and a per-batch-row kpos exercises the (B, Sk)
    layout."""
    b, c, pos0, hq, hkv, d, window = 2, 128, 1024, 4, 2, 64, 256
    ring_len = 256
    sk = ring_len + c
    q, k, v = _qkv(b, c, sk, hq, hkv, d)
    kpos = jnp.concatenate([_ring_kpos(ring_len, pos0),
                            pos0 + jnp.arange(c)])
    kpos = jnp.broadcast_to(kpos, (b, sk))
    out = dispatch.flash_attention_append(q, k, v, kpos, pos0=pos0,
                                          window=window,
                                          kpos_linear=False,
                                          backend="pallas")
    want = ref.flash_attention_append_ref(q, k, v, kpos, pos0=pos0,
                                          window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_append_oracle_matches_masked_sdpa():
    """The oracle reproduces the masked-sdpa construction the old
    ``attend_prefill`` prefix branch used (concat + repeat_kv + where)."""
    from repro.models import attention as attn
    b, c, pos0, hq, hkv, d = 1, 64, 96, 4, 2, 64
    sk = pos0 + c
    q, k, v = _qkv(b, c, sk, hq, hkv, d)
    kpos = jnp.arange(sk)
    got = ref.flash_attention_append_ref(q, k, v, kpos, pos0=pos0)
    qpos = pos0 + jnp.arange(c)
    mask = (kpos[None, :] >= 0) & (kpos[None, :] <= qpos[:, None])
    n_rep = hq // hkv
    want = attn.sdpa(q, attn._repeat_kv(k, n_rep),
                     attn._repeat_kv(v, n_rep), mask[None, None])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# dispatch resolution
# ---------------------------------------------------------------------------

def test_append_dispatch_fallback_reasons():
    b, c, pos0, hq, hkv, d = 1, 128, 128, 4, 2, 64
    sk = pos0 + c
    q, k, v = _qkv(b, c, sk, hq, hkv, d)
    kpos = jnp.arange(sk)

    # auto on a bare CPU host: jnp with the platform reason
    dispatch.clear_decision_log()
    dispatch.flash_attention_append(q, k, v, kpos, pos0=pos0)
    dec = dispatch.last_decision("flash_append")
    assert dec.backend in ("jnp", "pallas")   # pallas iff a TPU host
    if dec.backend == "jnp":
        assert "platform" in dec.reason

    # misaligned chunk: logged fallback even under explicit pallas
    q2, k2, v2 = _qkv(b, 96, pos0 + 96, hq, hkv, d)
    dispatch.clear_decision_log()
    out = dispatch.flash_attention_append(q2, k2, v2,
                                          jnp.arange(pos0 + 96),
                                          pos0=pos0, backend="pallas")
    dec = dispatch.last_decision("flash_append")
    assert dec.backend == "jnp" and "not MXU-aligned" in dec.reason
    want = ref.flash_attention_append_ref(q2, k2, v2,
                                          jnp.arange(pos0 + 96),
                                          pos0=pos0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)

    # rules without a dispatch mesh: jnp with the install-a-mesh reason
    with ctx.sharding_rules({"residual": None}):
        dispatch.clear_decision_log()
        dispatch.flash_attention_append(q, k, v, kpos, pos0=pos0)
        dec = dispatch.last_decision("flash_append")
        assert dec.backend == "jnp" and "without a dispatch mesh" \
            in dec.reason

    # broken GQA grouping is a config error, not a fallback
    with pytest.raises(ValueError, match="GQA"):
        dispatch.flash_attention_append(q[:, :, :3], k, v, kpos,
                                        pos0=pos0)


def test_append_dispatch_shard_map_1dev_mesh():
    """Explicit shard_map honors even a 1-device mesh (bench idiom) and
    matches the oracle."""
    b, c, pos0, hq, hkv, d = 2, 128, 128, 4, 2, 64
    sk = pos0 + c
    q, k, v = _qkv(b, c, sk, hq, hkv, d)
    kpos = _linear_kpos(sk, pos0, c)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with ctx.use_mesh(mesh):
        out = dispatch.flash_attention_append(
            q, k, v, kpos, pos0=pos0, kpos_linear=True,
            backend="pallas_shard_map")
    want = ref.flash_attention_append_ref(q, k, v, kpos, pos0=pos0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
def test_append_dispatch_auto_mesh_2dev():
    """Auto dispatch under a 2-device mesh resolves the shard_map'd append
    arm (heads over 'model') and matches the oracle — the arm the serve
    engine's admission prefill rides under a mesh."""
    b, c, pos0, hq, hkv, d = 1, 128, 256, 4, 2, 64
    sk = pos0 + c
    q, k, v = _qkv(b, c, sk, hq, hkv, d)
    kpos = _linear_kpos(sk, pos0, c)
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    with ctx.use_mesh(mesh):
        dispatch.clear_decision_log()
        out = dispatch.flash_attention_append(q, k, v, kpos, pos0=pos0,
                                              kpos_linear=True)
        dec = dispatch.last_decision("flash_append")
        assert dec.backend == "pallas_shard_map", dec
    want = ref.flash_attention_append_ref(q, k, v, kpos, pos0=pos0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# model-level multi-chunk prefill
# ---------------------------------------------------------------------------

def _prefill_chain(cfg, params, tokens, cache_len, chunk, true_len=None):
    b, s = tokens.shape
    cache = M.init_cache(cfg, b, cache_len, dtype=jnp.float32)
    outs = []
    for p0 in range(0, s, chunk):
        o, cache = M.prefill_step(cfg, params, cache,
                                  {"tokens": tokens[:, p0:p0 + chunk]},
                                  p0, true_len)
        outs.append(o["logits"])
    return jnp.concatenate(outs, axis=1), cache


def test_prefill_chunks_match_forward_gqa():
    """Multi-chunk prefill == teacher-forced forward on a GQA variant
    (q heads grouped 4:1 over kv heads) with a ragged final chunk."""
    cfg = dataclasses.replace(get_config("stablelm-1.6b").reduced(),
                              n_kv_heads=1)
    params = M.init_params(cfg, jax.random.key(0))
    b, s = 2, 20            # chunks of 8: ragged final chunk of 4
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0,
                                cfg.vocab_size)
    full = M.forward(cfg, params, {"tokens": tokens})["logits"]
    got, _ = _prefill_chain(cfg, params, tokens, 24, 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               atol=2e-3, rtol=2e-3)


def test_prefill_ring_true_len_masks_padding():
    """Ring-cache writes stop at each row's true_len: a short row padded
    to the grid must decode exactly like the unpadded prompt (the
    aliasing case that used to gate rings out of engine prefill)."""
    cfg = dataclasses.replace(get_config("stablelm-1.6b").reduced(),
                              block_cycle=("attn_local",),
                              sliding_window=8)
    params = M.init_params(cfg, jax.random.key(0))
    cache_len = 26
    long_p, short_p = 20, 4     # padded grid driven by the long row
    tokens = jax.random.randint(jax.random.key(2), (2, long_p), 0,
                                cfg.vocab_size)
    true_len = jnp.asarray([long_p, short_p], jnp.int32)
    _, cache = _prefill_chain(cfg, params, tokens, cache_len, 8,
                              true_len=true_len)

    # reference: the short prompt alone, exact-length chunks
    _, ref_cache = _prefill_chain(cfg, params, tokens[1:2, :short_p],
                                  cache_len, 4)
    # per-slot decode over the padded 2-row cache: row 1 must behave as if
    # it had never seen the padding
    got, _ = M.decode_step(cfg, params, cache,
                           {"tokens": jnp.zeros((2, 1), jnp.int32)},
                           jnp.asarray([long_p, short_p]))
    want, _ = M.decode_step(cfg, params, ref_cache,
                            {"tokens": jnp.zeros((1, 1), jnp.int32)},
                            jnp.asarray(short_p))
    np.testing.assert_allclose(np.asarray(got["logits"][1:2]),
                               np.asarray(want["logits"]),
                               atol=2e-3, rtol=2e-3)
