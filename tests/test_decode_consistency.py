"""Strong correctness: teacher-forced forward logits must equal step-by-step
decode logits at every position, for every cache kind (KV, ring, SSM,
mLSTM/sLSTM state, shared-attn, cross-attn)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.models import encdec

ARCHS = ["stablelm-1.6b", "qwen2-72b", "zamba2-1.2b", "xlstm-1.3b",
         "granite-moe-1b-a400m", "llama4-scout-17b-a16e"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_vs_decode(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.key(0)
    params = M.init_params(cfg, key)
    b, s = 1, 16
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0,
                                cfg.vocab_size)
    full = M.forward(cfg, params, {"tokens": tokens})["logits"]

    cache = M.init_cache(cfg, b, s, dtype=jnp.float32)
    outs = []
    for t in range(s):
        out, cache = M.decode_step(cfg, params, cache,
                                   {"tokens": tokens[:, t:t + 1]},
                                   jnp.asarray(t, jnp.int32))
        outs.append(out["logits"][:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-3, rtol=2e-3)


def test_whisper_prefill_vs_decode():
    cfg = get_config("whisper-base").reduced()
    key = jax.random.key(0)
    params = M.init_params(cfg, key)
    b, s = 1, 12
    frames = 0.05 * jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model))
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0,
                                cfg.vocab_size)
    full = M.forward(cfg, params,
                     {"tokens": tokens, "enc_frames": frames})["logits"]
    cache = M.init_cache(cfg, b, s, dtype=jnp.float32)
    cache = encdec.prefill_cross(cfg, params, cache, frames)
    outs = []
    for t in range(s):
        out, cache = M.decode_step(cfg, params, cache,
                                   {"tokens": tokens[:, t:t + 1]},
                                   jnp.asarray(t, jnp.int32))
        outs.append(out["logits"][:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-3, rtol=2e-3)


def test_sliding_window_ring_cache_matches_full():
    """attn_local with ring cache == full-cache attention restricted to the
    window."""
    import dataclasses
    cfg = get_config("stablelm-1.6b").reduced()
    cfg = dataclasses.replace(cfg, block_cycle=("attn_local",),
                              sliding_window=8)
    params = M.init_params(cfg, jax.random.key(0))
    b, s = 1, 24
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0,
                                cfg.vocab_size)
    full = M.forward(cfg, params, {"tokens": tokens})["logits"]
    cache = M.init_cache(cfg, b, s, dtype=jnp.float32)  # ring len = window
    outs = []
    for t in range(s):
        out, cache = M.decode_step(cfg, params, cache,
                                   {"tokens": tokens[:, t:t + 1]},
                                   jnp.asarray(t, jnp.int32))
        outs.append(out["logits"][:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-3, rtol=2e-3)
