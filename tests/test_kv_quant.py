"""int8 KV-cache quantization: the quant/dequant primitives, dispatch-arm
parity against the explicit-dequant oracles (contiguous, paged, mesh),
the garbage-row safety properties, the engine-level quality sweep
(teacher-forced greedy match + logit MSE across linear / ring / GQA
archs), and the capacity model's int8 column.
"""
import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed import ctx
from repro.kernels import dispatch, kv_quant, ref
from repro.launch import serve as serve_mod
from repro.launch import traffic
from repro.models import model as M

KEY = jax.random.key(11)
MULTI = len(jax.devices()) >= 2
PS = 128


def _rand_kv(b=2, s=256, hq=4, hkv=2, d=64):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    return q, k, v


def _paged_from_contiguous(x, *, ps=PS, perm_seed=0):
    """Scatter (B, S, H, D) rows into a page pool (page 0 = garbage
    sink) under a permuted assignment; returns (pool, page_table)."""
    b, s, h, d = x.shape
    m = s // ps
    rng = np.random.default_rng(perm_seed)
    pages = 1 + rng.permutation(b * m)
    pt = pages.reshape(b, m).astype(np.int32)
    pool = np.zeros((b * m + 2, ps, h, d), x.dtype)
    for bi in range(b):
        for mi in range(m):
            pool[pt[bi, mi]] = np.asarray(x[bi, mi * ps:(mi + 1) * ps])
    return jnp.asarray(pool), jnp.asarray(pt)


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def test_resolve_kv_dtype():
    assert kv_quant.resolve_kv_dtype("f32") == jnp.float32
    assert kv_quant.resolve_kv_dtype("bf16") == jnp.bfloat16
    assert kv_quant.resolve_kv_dtype("int8") == jnp.int8
    assert kv_quant.resolve_kv_dtype(jnp.int8) == jnp.dtype(jnp.int8)
    with pytest.raises(ValueError):
        kv_quant.resolve_kv_dtype("fp8")
    assert kv_quant.is_quantized(jnp.int8)
    assert not kv_quant.is_quantized(jnp.bfloat16)
    assert kv_quant.dtype_name(jnp.float32) == "f32"
    assert kv_quant.dtype_name(jnp.int8) == "int8"


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(KEY, (4, 32, 3, 64))
    q, s = kv_quant.quantize(x)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert s.shape == x.shape[:-1] + (1,)
    dq = kv_quant.dequantize(q, s)
    # round-to-nearest: per-row error <= half a quantization step
    err = jnp.abs(dq - x)
    assert float(jnp.max(err - 0.5 * s)) <= 1e-6


def test_quantize_zero_row_safe():
    """All-zero rows (unwritten cache, garbage sink init) quantize with
    scale 0 and dequantize to exact zeros — no div-by-zero, no NaN."""
    x = jnp.zeros((2, 4, 2, 64))
    q, s = kv_quant.quantize(x)
    assert float(jnp.max(jnp.abs(s))) == 0.0
    dq = kv_quant.dequantize(q, s)
    assert float(jnp.max(jnp.abs(dq))) == 0.0


# ---------------------------------------------------------------------------
# dispatch arms vs the explicit-dequant oracles
# ---------------------------------------------------------------------------

def test_decode_quant_dispatch_parity():
    q, k, v = _rand_kv()
    k8, ks = kv_quant.quantize(k)
    v8, vs = kv_quant.quantize(v)
    kpos = jnp.broadcast_to(jnp.arange(k.shape[1]), k.shape[:2])
    pos = jnp.asarray([200, 131])
    want = ref.decode_attention_quant_ref(q, k8, v8, ks, vs, kpos, pos)
    for backend in ("auto", "pallas", "jnp"):
        dispatch.clear_decision_log()
        got = dispatch.decode_attention(q, k8, v8, kpos, pos,
                                        k_scale=ks, v_scale=vs,
                                        backend=backend)
        assert float(jnp.max(jnp.abs(got - want))) <= 1e-5, backend
        d = dispatch.last_decision("decode_attention")
        assert "int8 kv" in d.reason, (backend, d)


def test_append_quant_dispatch_parity():
    b, c, pos0 = 2, 128, 128
    q, k, v = _rand_kv(b=b, s=pos0 + c)
    q = jax.random.normal(KEY, (b, c, 4, 64))
    k8, ks = kv_quant.quantize(k)
    v8, vs = kv_quant.quantize(v)
    kpos = jnp.arange(pos0 + c)
    want = ref.flash_attention_append_quant_ref(q, k8, v8, ks, vs, kpos,
                                                pos0=pos0)
    for backend in ("auto", "pallas", "jnp"):
        dispatch.clear_decision_log()
        got = dispatch.flash_attention_append(
            q, k8, v8, kpos, pos0=pos0, kpos_linear=True,
            k_scale=ks, v_scale=vs, backend=backend)
        assert float(jnp.max(jnp.abs(got - want))) <= 1e-5, backend
        d = dispatch.last_decision("flash_append")
        assert "int8 kv" in d.reason, (backend, d)


def test_decode_paged_quant_delegates_with_scales():
    q, k, v = _rand_kv()
    k8, ks = kv_quant.quantize(k)
    v8, vs = kv_quant.quantize(v)
    kp, pt = _paged_from_contiguous(k8)
    vp, _ = _paged_from_contiguous(v8)
    kps, _ = _paged_from_contiguous(ks)
    vps, _ = _paged_from_contiguous(vs)
    pos = jnp.asarray([200, 131])
    kpos = jnp.broadcast_to(jnp.arange(k.shape[1]), k.shape[:2])
    want = ref.decode_attention_quant_ref(q, k8, v8, ks, vs, kpos, pos)
    dispatch.clear_decision_log()
    got = dispatch.decode_attention_paged(q, kp, vp, pt, pos,
                                          length=k.shape[1],
                                          k_scale=kps, v_scale=vps)
    assert float(jnp.max(jnp.abs(got - want))) <= 1e-5
    d = dispatch.last_decision("decode_paged")
    assert "scale pool gathered together" in d.reason
    # misaligned page size falls back to the paged quant oracle
    dispatch.clear_decision_log()
    got64 = dispatch.decode_attention_paged(
        q, kp[:, :64], vp[:, :64], pt, pos,
        k_scale=kps[:, :64], v_scale=vps[:, :64])
    d = dispatch.last_decision("decode_paged")
    assert d.backend == "jnp" and "int8 kv dequantized" in d.reason
    assert got64.shape == got.shape


@pytest.mark.skipif(not MULTI, reason="needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
def test_decode_quant_shard_map_and_cp():
    q, k, v = _rand_kv()
    k8, ks = kv_quant.quantize(k)
    v8, vs = kv_quant.quantize(v)
    kpos = jnp.broadcast_to(jnp.arange(k.shape[1]), k.shape[:2])
    pos = jnp.asarray([200, 131])
    want = ref.decode_attention_quant_ref(q, k8, v8, ks, vs, kpos, pos)
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    with ctx.use_mesh(mesh):
        dispatch.clear_decision_log()
        got = dispatch.decode_attention(q, k8, v8, kpos, pos,
                                        k_scale=ks, v_scale=vs,
                                        backend="pallas_shard_map")
        assert float(jnp.max(jnp.abs(got - want))) <= 1e-5
        d = dispatch.last_decision("decode_attention")
        assert d.backend == "pallas_shard_map"
        assert "dequant-in-kernel" in d.reason
    cp_rules = {"decode_cp": {"mesh": mesh, "seq_axes": ("model",),
                              "dp_axes": ("data",), "n_shards": 2}}
    with ctx.sharding_rules(cp_rules):
        dispatch.clear_decision_log()
        got = dispatch.decode_attention(q, k8, v8, kpos, pos,
                                        k_scale=ks, v_scale=vs)
        assert float(jnp.max(jnp.abs(got - want))) <= 1e-5
        d = dispatch.last_decision("decode_attention")
        assert d.backend == "pallas_cp"
        assert "dequant-in-kernel" in d.reason


def test_garbage_rows_never_poison_output():
    """Rows beyond kpos validity — the paged garbage sink, unwritten
    slots — may hold arbitrary int8 bytes and arbitrary scales (incl. the
    zero-init); attention output must not depend on them."""
    q, k, v = _rand_kv()
    k8, ks = kv_quant.quantize(k)
    v8, vs = kv_quant.quantize(v)
    pos = jnp.asarray([150, 99])
    kpos = jnp.where(jnp.arange(k.shape[1])[None] <= pos[:, None],
                     jnp.arange(k.shape[1])[None], -1)
    live = jnp.arange(k.shape[1])[None, :, None, None] <= \
        pos[:, None, None, None]
    junk8 = jnp.where(live, k8, jnp.asarray(127, jnp.int8))
    junks = jnp.where(live[..., :1, :], ks, 1e6)
    base = dispatch.decode_attention(q, k8, v8, kpos, pos,
                                     k_scale=ks, v_scale=vs)
    poisoned = dispatch.decode_attention(
        q, junk8, jnp.where(live, v8, jnp.asarray(-128, jnp.int8)),
        kpos, pos, k_scale=junks,
        v_scale=jnp.where(live[..., :1, :], vs, 0.0))
    assert float(jnp.max(jnp.abs(base - poisoned))) <= 1e-6


# ---------------------------------------------------------------------------
# model-level quality sweep: linear / ring / GQA archs
# ---------------------------------------------------------------------------

def _teacher_forced(cfg, params, toks, kv_dtype, T):
    """Feed a fixed token stream through the decode loop and return
    (per-step argmax, per-step full logits) under the given cache."""
    step = jax.jit(lambda p, c, b_, pos: M.decode_step(cfg, p, c, b_, pos))
    cache = M.init_cache(cfg, 1, T + 8, dtype=jnp.float32,
                         kv_dtype=kv_dtype)
    arg, logs = [], []
    for i in range(T):
        out, cache = step(params, cache, {"tokens": toks[:, i:i + 1]},
                          jnp.asarray(i))
        lg = np.asarray(out["logits"][:, -1], np.float32)
        arg.append(int(lg.argmax()))
        logs.append(lg)
    return np.array(arg), np.stack(logs)


@pytest.mark.parametrize("arch", ["linear", "ring", "gqa"])
def test_quality_sweep_int8_vs_f32(arch):
    """The acceptance sweep across the three attention layouts: tiny
    logit MSE (vs the logit variance) and teacher-forced greedy match
    >= 0.99 on decisive steps for an int8 cache against the f32 cache.

    Random-init params produce near-uniform logits whose top-2 margin is
    routinely smaller than ANY ~1% perturbation (bf16 rounding included),
    so raw greedy match is an unstable metric here: a step only counts
    against the 0.99 bar when the f32 decision itself is decisive — top-2
    margin above tau = 4x the measured int8 logit-perturbation RMS.  tau
    is asserted to stay tiny relative to the logit scale so the tolerance
    cannot hide real degradation, and raw match must still be >= 0.95."""
    if arch == "linear":
        cfg = get_config("stablelm-1.6b").reduced()
    elif arch == "ring":
        cfg = dataclasses.replace(
            get_config("stablelm-1.6b").reduced(),
            block_cycle=("attn", "attn_local"), sliding_window=8)
    else:
        cfg = get_config("qwen2-72b").reduced()   # Hq=4, Hkv=1
        assert cfg.n_heads > cfg.n_kv_heads
    params = M.init_params(cfg, jax.random.key(0))
    T = 64
    toks = jax.random.randint(jax.random.key(3), (1, T), 0,
                              cfg.vocab_size)
    a_f32, l_f32 = _teacher_forced(cfg, params, toks, None, T)
    a_i8, l_i8 = _teacher_forced(cfg, params, toks, jnp.int8, T)
    lf, li = l_f32.reshape(T, -1), l_i8.reshape(T, -1)
    mse = float(((lf - li) ** 2).mean())
    var = float(lf.var())
    assert mse <= 1e-3 * max(var, 1e-6), (arch, mse, var)

    tau = 4.0 * float(np.sqrt(mse))
    assert tau <= 0.1 * float(lf.std()), (arch, tau)   # tolerance is tiny
    srt = np.sort(lf, axis=-1)
    decisive = (srt[:, -1] - srt[:, -2]) >= tau
    match = (a_f32 == a_i8)
    raw = float(match.mean())
    dec = float(match[decisive].mean()) if decisive.any() else 1.0
    assert decisive.mean() > 0.5, arch      # the metric has teeth
    assert dec >= 0.99, (arch, dec, raw)
    assert raw >= 0.95, (arch, raw)


# ---------------------------------------------------------------------------
# engine + capacity model
# ---------------------------------------------------------------------------

def test_engine_int8_runs_and_reports():
    cfg = get_config("stablelm-1.6b").reduced()
    params = M.init_params(cfg, jax.random.key(0))
    trace = serve_mod.gen_trace(4, vocab=cfg.vocab_size,
                                prompt_range=(16, 48), gen_range=(4, 8),
                                arrival_rate=0.0, seed=0)
    dispatch.clear_decision_log()
    rec = serve_mod.run_engine(cfg, params, trace, n_slots=2,
                               cache_len=128, chunk=64, sample=False,
                               seed=0, prefix_cache=True, kv_dtype="int8")
    assert rec["kv_dtype"] == "int8"
    assert all(len(r.tokens) > 0 for r in trace)
    reasons = " | ".join(d.reason for d in dispatch.decision_log())
    assert "int8" in reasons


def test_engine_no_attention_arch_falls_back(caplog):
    """--kv-dtype int8 on an arch with no attention layers must log a
    fallback and serve with f32 state, not crash."""
    cfg = get_config("zamba2-1.2b").reduced()     # pure mamba2
    assert not any(k in ("attn", "attn_local") for k in cfg.layer_kinds())
    params = M.init_params(cfg, jax.random.key(0))
    with caplog.at_level(logging.WARNING):
        eng = serve_mod.ServeEngine(cfg, params, n_slots=2, cache_len=64,
                                    chunk=32, sample=False, seed=0,
                                    kv_dtype="int8")
    assert eng.kv_dtype_name == "f32"
    assert any("falling back" in r.message for r in caplog.records)


def test_paged_capacity_int8_column():
    cfg = get_config("stablelm-1.6b").reduced()
    kw = dict(n_slots=8, cache_len=1024, page_size=128,
              resident_tokens_per_req=384, shared_tokens=128)
    f32 = traffic.paged_capacity(cfg, kv_dtype="f32", **kw)
    i8 = traffic.paged_capacity(cfg, kv_dtype="int8", **kw)
    # same bf16 contiguous budget, >= 1.9x the slots on int8 pools
    assert i8["budget_bytes"] == f32["budget_bytes"]
    assert i8["slots_paged"] >= 1.9 * f32["slots_paged"]
    assert i8["kv_dtype"] == "int8" and f32["kv_dtype"] == "f32"
    # page bytes match the eval_shape'd real pools (scale pools included)
    for kv in ("f32", "int8"):
        n_pages = 9
        got = traffic.paged_cache_bytes(cfg, 1, 1024, page_size=128,
                                        n_pages=n_pages, kv_dtype=kv)
        base = traffic.paged_cache_bytes(cfg, 1, 1024, page_size=128,
                                         n_pages=1, kv_dtype=kv)
        per_page = traffic.page_pool_bytes(cfg, 1, 128, kv_dtype=kv)
        assert got - base == (n_pages - 1) * per_page


def test_cache_bytes_int8_counts_scales():
    cfg = get_config("stablelm-1.6b").reduced()
    b, s = 2, 256
    f32 = traffic.cache_bytes(cfg, b, s, kv_dtype="f32")
    i8 = traffic.cache_bytes(cfg, b, s, kv_dtype="int8")
    n_attn = sum(1 for k in cfg.layer_kinds()
                 if k in ("attn", "attn_local"))
    d = cfg.head_dim
    # per KV row: 4D -> D + 4 bytes (int8 payload + f32 scale)
    want_delta = n_attn * 2 * b * s * cfg.n_kv_heads * (4 * d - d - 4)
    assert f32 - i8 == want_delta
    assert traffic.decode_bytes_per_token(cfg, b, s, kv_dtype="f32") - \
        traffic.decode_bytes_per_token(cfg, b, s, kv_dtype="int8") == \
        want_delta
