"""Paged KV cache: page-gathered dispatch arms, page allocator / prefix
index bookkeeping, and the engine's shared-prefix reuse.

The load-bearing property is BIT-FOR-BIT equality with the contiguous
layout: the paged arms gather pool pages into a dense view statically
sliced to the logical cache length, so the delegated contiguous kernels
see byte-identical inputs and produce byte-identical outputs (same XLA
reduction trees).  Greedy generations through the engine therefore cannot
drift when the layout flips.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import dispatch, ref
from repro.launch import serve as serve_mod
from repro.launch import traffic
from repro.models import attention as attn
from repro.models import model as M

KEY = jax.random.key(7)
PS = 128


def _paged_from_contiguous(k, v, *, ps=PS, n_extra=1, perm_seed=0):
    """Scatter a contiguous (B, S, Hkv, D) cache into a page pool under a
    permuted page assignment; returns (k_pool, v_pool, page_table)."""
    b, s, hkv, d = k.shape
    assert s % ps == 0
    m = s // ps
    rng = np.random.default_rng(perm_seed)
    pages = 1 + rng.permutation(b * m)            # page 0 = garbage sink
    pt = pages.reshape(b, m).astype(np.int32)
    n_pages = b * m + 1 + n_extra
    kp = np.zeros((n_pages, ps, hkv, d), k.dtype)
    vp = np.zeros((n_pages, ps, hkv, d), v.dtype)
    for bi in range(b):
        for mi in range(m):
            kp[pt[bi, mi]] = np.asarray(k[bi, mi * ps:(mi + 1) * ps])
            vp[pt[bi, mi]] = np.asarray(v[bi, mi * ps:(mi + 1) * ps])
    return jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(pt)


# ---------------------------------------------------------------------------
# dispatch arms
# ---------------------------------------------------------------------------

def test_decode_paged_bitwise_matches_contiguous():
    b, s, hq, hkv, d = 2, 256, 4, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    kp, vp, pt = _paged_from_contiguous(k, v)
    pos = jnp.asarray([200, 131])
    kpos = jnp.broadcast_to(jnp.arange(s), (b, s))

    dispatch.clear_decision_log()
    want = dispatch.decode_attention(q, k, v, kpos, pos)
    got = dispatch.decode_attention_paged(q, kp, vp, pt, pos, length=s)
    assert jnp.array_equal(got, want)
    d_own = dispatch.last_decision("decode_paged")
    d_in = dispatch.last_decision("decode_attention")
    assert d_own is not None and d_in is not None
    assert d_own.backend == d_in.backend      # delegation, not a fork
    # and the pure-jnp oracle agrees numerically
    orc = ref.decode_attention_paged_ref(q, kp, vp, pt, pos, length=s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(orc),
                               atol=2e-5, rtol=2e-5)


def test_decode_paged_unmapped_tail_pages():
    """Rows behind unmapped (-1) table entries are invisible: equality
    with a contiguous call whose kpos masks the same rows."""
    b, s, hq, hkv, d = 2, 256, 4, 2, 64
    ks = jax.random.split(jax.random.fold_in(KEY, 1), 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    kp, vp, pt = _paged_from_contiguous(k, v)
    pt = pt.at[:, 1].set(-1)                      # second page unmapped
    pos = jnp.asarray([100, 64])                  # within the first page
    kpos = jnp.where(jnp.arange(s) < PS, jnp.arange(s), -1)
    want = dispatch.decode_attention(
        q, k, v, jnp.broadcast_to(kpos, (b, s)), pos)
    got = dispatch.decode_attention_paged(q, kp, vp, pt, pos, length=s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_append_paged_bitwise_matches_contiguous():
    b, c, hq, hkv, d = 2, 128, 4, 2, 64
    pos0 = 128
    ks = jax.random.split(jax.random.fold_in(KEY, 2), 5)
    q = jax.random.normal(ks[0], (b, c, hq, d))
    k_pre = jax.random.normal(ks[1], (b, pos0, hkv, d))
    v_pre = jax.random.normal(ks[2], (b, pos0, hkv, d))
    k_c = jax.random.normal(ks[3], (b, c, hkv, d))
    v_c = jax.random.normal(ks[4], (b, c, hkv, d))
    kp, vp, pt = _paged_from_contiguous(k_pre, v_pre)

    k_stream = jnp.concatenate([k_pre, k_c], axis=1)
    v_stream = jnp.concatenate([v_pre, v_c], axis=1)
    kpos = jnp.arange(pos0 + c)
    dispatch.clear_decision_log()
    want = dispatch.flash_attention_append(q, k_stream, v_stream, kpos,
                                           pos0=pos0, kpos_linear=True)
    got = dispatch.flash_attention_append_paged(q, kp, vp, pt, k_c, v_c,
                                                pos0=pos0)
    assert jnp.array_equal(got, want)
    d_own = dispatch.last_decision("append_paged")
    assert d_own is not None
    orc = ref.flash_attention_append_paged_ref(q, kp, vp, pt, k_c, v_c,
                                               pos0=pos0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(orc),
                               atol=2e-5, rtol=2e-5)


def test_append_paged_first_chunk_ignores_pool():
    """pos0 == 0: the key stream is the chunk alone, whatever garbage the
    pool holds."""
    b, c, hq, hkv, d = 2, 128, 4, 2, 64
    ks = jax.random.split(jax.random.fold_in(KEY, 3), 3)
    q = jax.random.normal(ks[0], (b, c, hq, d))
    k_c = jax.random.normal(ks[1], (b, c, hkv, d))
    v_c = jax.random.normal(ks[2], (b, c, hkv, d))
    kp = jax.random.normal(jax.random.fold_in(KEY, 4), (3, PS, hkv, d))
    pt = jnp.full((b, 2), -1, jnp.int32)
    want = dispatch.flash_attention_append(q, k_c, v_c, jnp.arange(c),
                                           pos0=0, kpos_linear=True)
    got = dispatch.flash_attention_append_paged(q, kp, kp, pt, k_c, v_c,
                                                pos0=0)
    assert jnp.array_equal(got, want)


def test_paged_misalignment_falls_back_to_jnp():
    """Non-128-multiple page_size routes to the jnp oracle with a logged
    reason, never a kernel arm."""
    b, hq, hkv, d, ps = 2, 4, 2, 64, 64
    ks = jax.random.split(jax.random.fold_in(KEY, 5), 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    kp = jax.random.normal(ks[1], (5, ps, hkv, d))
    vp = jax.random.normal(ks[2], (5, ps, hkv, d))
    pt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    pos = jnp.asarray([100, 60])
    dispatch.clear_decision_log()
    got = dispatch.decode_attention_paged(q, kp, vp, pt, pos)
    dec = dispatch.last_decision("decode_paged")
    assert dec is not None and dec.backend == "jnp"
    assert "128" in dec.reason
    orc = ref.decode_attention_paged_ref(q, kp, vp, pt, pos)
    assert jnp.array_equal(got, orc)


# ---------------------------------------------------------------------------
# model layer
# ---------------------------------------------------------------------------

def _map_tables(cache, n_slots, max_pages):
    """Give every layer's page table the identity mapping (slot b owns
    pages [1 + b*M, 1 + (b+1)*M) of its layer's pool)."""
    pt = np.arange(1, n_slots * max_pages + 1,
                   dtype=np.int32).reshape(n_slots, max_pages)

    def fix(path, leaf):
        if getattr(path[-1], "key", None) == "pt":
            # leaves are layer-stacked: (L, n_slots, max_pages); every
            # layer indexes its own pool, so the same ids per layer work
            return jnp.broadcast_to(jnp.asarray(pt), leaf.shape)
        return leaf
    return jax.tree_util.tree_map_with_path(fix, cache)


def test_model_paged_cache_bitwise_matches_contiguous():
    """Chunked prefill + per-slot decode through init_cache(paged=...)
    produce byte-identical logits to the contiguous layout."""
    cfg = get_config("stablelm-1.6b").reduced()
    params = M.init_params(cfg, jax.random.key(0))
    b, cache_len, chunk = 2, 256, 128
    layout = attn.PagedLayout(page_size=PS, n_pages=2 * (cache_len // PS) + 1)
    tokens = jax.random.randint(jax.random.key(1), (b, cache_len), 0,
                                cfg.vocab_size)

    cont = M.init_cache(cfg, b, cache_len, dtype=jnp.float32)
    paged = _map_tables(
        M.init_cache(cfg, b, cache_len, dtype=jnp.float32, paged=layout),
        b, cache_len // PS)
    for p0 in range(0, cache_len, chunk):
        oc, cont = M.prefill_step(cfg, params, cont,
                                  {"tokens": tokens[:, p0:p0 + chunk]}, p0)
        op, paged = M.prefill_step(cfg, params, paged,
                                   {"tokens": tokens[:, p0:p0 + chunk]}, p0)
        assert jnp.array_equal(oc["logits"], op["logits"]), p0
    # cache_len == prompt here, so decode from a shorter prefill instead
    cont2 = M.init_cache(cfg, b, cache_len, dtype=jnp.float32)
    paged2 = _map_tables(
        M.init_cache(cfg, b, cache_len, dtype=jnp.float32, paged=layout),
        b, cache_len // PS)
    _, cont2 = M.prefill_step(cfg, params, cont2,
                              {"tokens": tokens[:, :chunk]}, 0)
    _, paged2 = M.prefill_step(cfg, params, paged2,
                               {"tokens": tokens[:, :chunk]}, 0)
    nxt = tokens[:, chunk:chunk + 1]
    dc, _ = M.decode_step(cfg, params, cont2, {"tokens": nxt},
                          jnp.asarray(chunk))
    dp, _ = M.decode_step(cfg, params, paged2, {"tokens": nxt},
                          jnp.asarray(chunk))
    assert jnp.array_equal(dc["logits"], dp["logits"])


def test_init_paged_cache_requires_whole_pages():
    with pytest.raises(ValueError):
        attn.init_paged_kv_cache(2, 200, 2, 64, page_size=128, n_pages=5)


# ---------------------------------------------------------------------------
# allocator + prefix index
# ---------------------------------------------------------------------------

def test_page_allocator_refcount_and_versions():
    a = serve_mod.PageAllocator(4)                # pages 1..3 usable
    p1, p2, p3 = a.alloc(), a.alloc(), a.alloc()
    assert sorted((p1, p2, p3)) == [1, 2, 3]
    assert a.used_pages == 3
    with pytest.raises(RuntimeError):
        a.alloc()                                 # exhausted (0 reserved)
    a.incref(p1)
    v0 = int(a.version[p1])
    a.decref(p1)
    assert a.ref[p1] == 1 and int(a.version[p1]) == v0
    a.decref(p1)                                  # ref -> 0: recycled
    assert int(a.version[p1]) == v0 + 1
    assert a.alloc() == p1                        # back on the free list


def test_prefix_index_chain_and_staleness():
    a = serve_mod.PageAllocator(8)
    idx = serve_mod.PrefixIndex(4)
    prompt = np.arange(10, dtype=np.int32)        # 2 full blocks + tail 2
    pages = [a.alloc(), a.alloc(), a.alloc()]
    idx.register(prompt, pages, a)
    hits = idx.lookup(prompt, a)
    assert [p for p, _ in hits] == pages
    assert sum(n for _, n in hits) == 10          # partial tail matches too
    # an extended prompt shares only the full blocks
    longer = np.concatenate([prompt[:8], np.asarray([9, 9, 9], np.int32)])
    hits = idx.lookup(longer, a)
    assert [p for p, _ in hits] == pages[:2]
    # a diverging second block stops the chain after block 0
    div = prompt.copy()
    div[5] = 99
    assert [p for p, _ in idx.lookup(div, a)] == pages[:1]
    # recycling a page invalidates (version bump), entry pruned lazily
    a.decref(pages[1])
    assert a.ref[pages[1]] == 0
    assert [p for p, _ in idx.lookup(prompt, a)] == pages[:1]


# ---------------------------------------------------------------------------
# engine: reset reuse, shared-prefix parity, COW
# ---------------------------------------------------------------------------

def _cfg():
    return get_config("stablelm-1.6b").reduced()


def _drive(eng, trace):
    """Minimal admission/decode loop (all arrivals at t=0)."""
    qi, done = 0, []
    while qi < len(trace) or any(r is not None for r in eng.req_of):
        pairs = []
        for j in range(eng.n_slots):
            if qi >= len(trace) or eng.req_of[j] is not None:
                continue
            pairs.append((trace[qi], j))
            qi += 1
        done.extend(eng.admit(pairs, 0.0))
        if any(r is not None for r in eng.req_of):
            done.extend(eng.decode_step_all())
    return {r.rid: list(r.tokens) for r in trace}


def _copy_trace(trace):
    return [serve_mod.Request(rid=r.rid, prompt=np.asarray(r.prompt).copy(),
                              max_new=r.max_new, arrival=r.arrival)
            for r in trace]


def _shared_trace(vocab, *, n=8, shared_len=192, seed=0):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, shared_len).astype(np.int32)
    dup_tail = rng.integers(0, vocab, 9).astype(np.int32)
    out = []
    for rid in range(n):
        tail = dup_tail if rid in (1, 2) else \
            rng.integers(0, vocab, 1 + (rid % 3) * 7).astype(np.int32)
        out.append(serve_mod.Request(
            rid=rid, prompt=np.concatenate([shared, tail]),
            max_new=2 + (rid % 3) * 5, arrival=0.0))
    return out


def test_engine_reset_reproduces_fresh_engine():
    """reset() + the same trace again == a fresh engine, bit for bit —
    recycled pool pages and a cleared prefix index leak nothing."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    trace = _shared_trace(cfg.vocab_size, n=5)
    kw = dict(n_slots=2, cache_len=256, chunk=64, sample=False, seed=0)
    eng = serve_mod.ServeEngine(cfg, params, **kw)
    assert eng.paged
    first = _drive(eng, _copy_trace(trace))
    eng.reset()
    second = _drive(eng, _copy_trace(trace))
    fresh = _drive(serve_mod.ServeEngine(cfg, params, **kw),
                   _copy_trace(trace))
    assert first == second == fresh


def test_engine_shared_prefix_matches_no_sharing():
    """Shared-long-prefix trace: identical greedy tokens with the prefix
    cache on and off, with dedup > 1, skipped prefill chunks, and COW
    exercised by the duplicate prompts' divergent decode writes."""
    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    t_on = _shared_trace(cfg.vocab_size)
    t_off = _copy_trace(t_on)
    kw = dict(n_slots=4, cache_len=256, chunk=64, sample=False, seed=0)
    rec_on = serve_mod.run_engine(cfg, params, t_on, **kw)
    rec_off = serve_mod.run_engine(cfg, params, t_off, prefix_cache=False,
                                   **kw)
    assert rec_on["paged"] and rec_off["paged"]
    assert {r.rid: r.tokens for r in t_on} == \
           {r.rid: r.tokens for r in t_off}
    assert rec_on["dedup_ratio"] > 1.0
    assert rec_on["cow_events"] > 0
    assert rec_on["prefill_chunks_skipped"] > 0
    assert rec_off["dedup_ratio"] == 1.0
    assert rec_off["prefill_chunks_skipped"] == 0
    assert rec_on["pages_alloced"] < rec_off["pages_alloced"]


def test_engine_shared_prefix_ring_archs():
    """Mixed attn/ring arch: paged covers the global-attention layers,
    ring layers stay contiguous and chunk skipping stays off — tokens
    must still match the no-sharing engine.  A pure-ring arch has no
    paged layers at all and the engine must say so."""
    cfg = dataclasses.replace(_cfg(), block_cycle=("attn", "attn_local"),
                              sliding_window=8)
    params = M.init_params(cfg, jax.random.key(0))
    t_on = _shared_trace(cfg.vocab_size, n=5)
    t_off = _copy_trace(t_on)
    kw = dict(n_slots=2, cache_len=256, chunk=64, sample=False, seed=0)
    rec_on = serve_mod.run_engine(cfg, params, t_on, **kw)
    rec_off = serve_mod.run_engine(cfg, params, t_off, prefix_cache=False,
                                   **kw)
    assert rec_on["paged"]
    assert rec_on["prefill_chunks_skipped"] == 0     # ring needs chunks
    assert rec_on["dedup_ratio"] > 1.0               # sharing still on
    assert {r.rid: r.tokens for r in t_on} == \
           {r.rid: r.tokens for r in t_off}

    pure = dataclasses.replace(_cfg(), block_cycle=("attn_local",),
                               sliding_window=8)
    params_p = M.init_params(pure, jax.random.key(0))
    t_pure = _shared_trace(pure.vocab_size, n=3)
    rec = serve_mod.run_engine(pure, params_p, t_pure, **kw)
    assert not rec["paged"]                          # nothing to page


# ---------------------------------------------------------------------------
# analysis
# ---------------------------------------------------------------------------

def test_paged_capacity_model():
    cfg = _cfg()
    cap = traffic.paged_capacity(cfg, n_slots=8, cache_len=1024,
                                 page_size=128,
                                 resident_tokens_per_req=256,
                                 shared_tokens=128)
    assert cap["slot_ratio"] >= 4.0
    assert cap["dedup_ratio_model"] > 1.5
    # the paged budget actually fits: pages + per-slot overhead <= budget
    spend = (cap["shared_pages"] + cap["slots_paged"]
             * cap["unique_pages_per_req"]) * cap["page_bytes"] \
        + cap["slots_paged"] * cap["per_slot_overhead_bytes"]
    assert spend <= cap["budget_bytes"]
    # pool bytes match the eval_shape'd real cache
    n_pages = 9
    got = traffic.paged_cache_bytes(cfg, 1, 1024, page_size=128,
                                    n_pages=n_pages)
    pool = traffic.page_pool_bytes(cfg, n_pages, 128)
    assert got > pool and (got - pool) == cap["per_slot_overhead_bytes"]


# ---------------------------------------------------------------------------
# 2-dev host mesh
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
def test_engine_paged_two_device_mesh():
    """Paged engine under the (batch, heads) mesh: greedy tokens must
    match the single-device no-mesh run, and the paged dispatch arms must
    appear in the decision log."""
    from repro import compat
    from repro.distributed import ctx, sharding

    cfg = _cfg()
    params = M.init_params(cfg, jax.random.key(0))
    trace = _shared_trace(cfg.vocab_size, n=4)
    kw = dict(n_slots=2, cache_len=256, chunk=64, sample=False, seed=0)
    ref_trace = _copy_trace(trace)
    base = serve_mod.run_engine(cfg, params, ref_trace, **kw)
    assert base["paged"]
    want = {r.rid: r.tokens for r in ref_trace}

    mesh = jax.make_mesh((1, 2), ("data", "model"))
    rules = sharding.decode_rules(cfg, mesh, batch_size=2)
    mesh_trace = _copy_trace(trace)
    with compat.set_mesh(mesh), ctx.use_mesh(mesh), \
            ctx.sharding_rules(rules):
        dispatch.clear_decision_log()
        rec = serve_mod.run_engine(cfg, params, mesh_trace, **kw)
        ops = {d.op for d in dispatch.decision_log()}
    assert rec["paged"]
    assert "decode_paged" in ops and "append_paged" in ops
    assert {r.rid: r.tokens for r in mesh_trace} == want
