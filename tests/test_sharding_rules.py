"""Sharding-rule unit tests using an abstract 16x16 mesh (no devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import abstract_mesh
from repro.configs import get_config
from repro.distributed import sharding
from repro.launch import specs as specs_mod

MESH = abstract_mesh((16, 16), ("data", "model"))
MESH3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _spec_of(shard):
    return tuple(shard.spec)


def test_param_rules_dense():
    cfg = get_config("qwen2-72b")
    p_specs = specs_mod.params_specs(cfg)
    shards = sharding.param_shardings(cfg, MESH, p_specs)
    flat = {"/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path): s
            for path, s in jax.tree_util.tree_flatten_with_path(shards)[0]}
    # scanned layers: leading stack dim unsharded, (F, M) layout for wq
    wq = next(v for k, v in flat.items() if k.endswith("attn/wq/w"))
    assert _spec_of(wq) == (None, "data", "model")
    wo = next(v for k, v in flat.items() if k.endswith("attn/wo/w"))
    assert _spec_of(wo) == (None, "model", "data")
    emb = next(v for k, v in flat.items() if "embed/table" in k)
    assert _spec_of(emb) == ("model", "data")


def test_odd_vocab_drops_model_axis():
    cfg = get_config("minicpm-2b")    # vocab 122753 (odd)
    p_specs = specs_mod.params_specs(cfg)
    shards = sharding.param_shardings(cfg, MESH, p_specs)
    flat = {"/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path): s
            for path, s in jax.tree_util.tree_flatten_with_path(shards)[0]}
    emb = next(v for k, v in flat.items() if "embed/table" in k)
    spec = _spec_of(emb)
    assert spec[0] is None            # vocab axis dropped (not divisible)


def test_batch_shardings_multi_pod():
    tree = {"tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32),
            "positions": jax.ShapeDtypeStruct((3, 256, 128), jnp.int32)}
    shards = sharding.batch_shardings(MESH3, tree, batch_size=256)
    assert tuple(shards["tokens"].spec)[0] == ("pod", "data")
    assert tuple(shards["positions"].spec) == (None, ("pod", "data"), None)


def test_cache_shardings_batch1_context_parallel():
    cfg = get_config("qwen2-72b")
    cache = jax.eval_shape(
        lambda: __import__("repro.models.model", fromlist=["init_cache"])
        .init_cache(cfg, 1, 4096, dtype=jnp.bfloat16))
    shards = sharding.cache_shardings(cfg, MESH, cache, batch_size=1)
    k_shard = jax.tree_util.tree_flatten_with_path(shards)[0]
    kv = [s for path, s in k_shard
          if str(path[-1].key) in ("k", "v")][0]
    spec = tuple(kv.spec)
    # batch=1: seq dim takes data+model (full-mesh context parallelism)
    assert spec[2] == ("data", "model")


def test_cache_shardings_paged_pool():
    """Paged pool leaves: batch-sharded serving puts Hkv on 'model' (same
    dim the gathered dense view shards); batch=1 context parallelism puts
    the PAGE dim on the seq axes (whole 128-row pages per shard); page
    tables replicate (they are gather/scatter indices)."""
    from repro.models import attention as attn
    from repro.models import model as M

    cfg = get_config("qwen2-72b")
    layout = attn.PagedLayout(page_size=128, n_pages=256)
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, 16, 4096, dtype=jnp.bfloat16,
                             paged=layout))
    flat = {str(path[-1].key): s for path, s in
            jax.tree_util.tree_flatten_with_path(
                sharding.cache_shardings(cfg, MESH, cache,
                                         batch_size=256))[0]}
    off = 1 if len(cfg.layer_kinds()) > 1 else 0
    kp = tuple(flat["kp"].spec)
    assert kp[off + 2] is None or kp[off + 2] == "model"
    assert kp[off + 0] is None                     # pages whole, batch path
    assert tuple(flat["pt"].spec) == ()            # replicated indices

    # batch=1: the page dim takes the seq axes (256 pages % 256 mesh == 0)
    flat1 = {str(path[-1].key): s for path, s in
             jax.tree_util.tree_flatten_with_path(
                 sharding.cache_shardings(cfg, MESH, cache,
                                          batch_size=1))[0]}
    kp1 = tuple(flat1["kp"].spec)
    assert kp1[off + 0] == ("data", "model")
    assert tuple(flat1["pt"].spec) == ()


def test_cache_shardings_quant_scale_leaves():
    """int8 cache: the f32 scale leaves (ks/vs contiguous, kps/vps paged)
    are rank-matched to their payload and must take the payload's spec on
    every leading dim, trailing singleton unsharded — the property that
    lets COW copies, admission scatters, and the engine's bdim scan treat
    payload and scale identically."""
    from repro.models import attention as attn
    from repro.models import model as M

    cfg = get_config("qwen2-72b")
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, 16, 4096, dtype=jnp.bfloat16,
                             kv_dtype=jnp.int8))
    flat = {str(path[-1].key): s for path, s in
            jax.tree_util.tree_flatten_with_path(
                sharding.cache_shardings(cfg, MESH, cache,
                                         batch_size=256))[0]}
    for pay, sc in (("k", "ks"), ("v", "vs")):
        pspec, sspec = tuple(flat[pay].spec), tuple(flat[sc].spec)
        assert sspec[:-1] == pspec[:-1], (pay, pspec, sspec)
        assert sspec[-1] is None

    layout = attn.PagedLayout(page_size=128, n_pages=256)
    paged = jax.eval_shape(
        lambda: M.init_cache(cfg, 16, 4096, dtype=jnp.bfloat16,
                             paged=layout, kv_dtype=jnp.int8))
    off = 1 if len(cfg.layer_kinds()) > 1 else 0
    for bsz, page_axes in ((256, None), (1, ("data", "model"))):
        flatp = {str(path[-1].key): s for path, s in
                 jax.tree_util.tree_flatten_with_path(
                     sharding.cache_shardings(cfg, MESH, paged,
                                              batch_size=bsz))[0]}
        for pay, sc in (("kp", "kps"), ("vp", "vps")):
            pspec = tuple(flatp[pay].spec)
            sspec = tuple(flatp[sc].spec)
            assert sspec[:-1] == pspec[:-1], (bsz, pay, pspec, sspec)
            assert sspec[-1] is None
            assert pspec[off + 0] == page_axes  # CP pages follow payload


def test_activation_rules_gqa_fallback():
    cfg = get_config("qwen2-72b")     # kv=8 < model=16
    rules = sharding.activation_rules(MESH, batch_size=256, cfg=cfg)
    assert tuple(rules["attn_q"].spec)[2] == "model"
    # non-divisible kv heads: sequence-sharded pin (perf iter #8)
    assert tuple(rules["attn_kv"].spec)[1] == "model"


def test_activation_rules_odd_heads_seq_sharded():
    cfg = get_config("minicpm-2b")    # 36 heads, 16-way model axis
    rules = sharding.activation_rules(MESH, batch_size=256, cfg=cfg)
    assert tuple(rules["attn_q"].spec)[1] == "model"
    assert tuple(rules["attn_q"].spec)[2] is None
