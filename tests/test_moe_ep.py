"""Expert-parallel MoE (shard_map all-to-all) vs the dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.models import moe as moe_mod
from repro.models import moe_ep

MESH = jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("top_k,cf", [(1, 1.25), (2, 1.25), (2, 4.0)])
def test_ep_matches_dense_oracle(top_k, cf):
    p = moe_mod.init_moe(jax.random.key(0), 16, 32, 4)
    x = jax.random.normal(jax.random.key(1), (2, 8, 16))
    y_d, lb_d = moe_mod.moe_apply(p, x, top_k=top_k, capacity_factor=cf)
    y_e, lb_e = moe_ep.moe_apply_ep(p, x, top_k=top_k, capacity_factor=cf,
                                    act="silu", mesh=MESH,
                                    dp_axes=("data",))
    np.testing.assert_allclose(y_d, y_e, atol=1e-6)
    np.testing.assert_allclose(lb_d, lb_e, atol=1e-6)


def test_ep_gradients_match():
    p = moe_mod.init_moe(jax.random.key(0), 16, 32, 4)
    x = jax.random.normal(jax.random.key(1), (2, 8, 16))

    g1 = jax.grad(lambda p: moe_mod.moe_apply(
        p, x, top_k=2, capacity_factor=1.25)[0].sum())(p)
    g2 = jax.grad(lambda p: moe_ep.moe_apply_ep(
        p, x, top_k=2, capacity_factor=1.25, act="silu", mesh=MESH,
        dp_axes=("data",))[0].sum())(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(a, b, atol=1e-6)


def test_ep_activated_by_rules_in_train_step():
    """The model dispatches to EP when the sharding context provides it."""
    from repro.configs import get_config
    from repro.core import llm_a3c
    from repro.distributed import ctx, sharding
    from repro.models import model as M

    cfg = get_config("granite-moe-1b-a400m").reduced()
    params = M.init_params(cfg, jax.random.key(0))
    b, s = 2, 16
    batch = {"tokens": jax.random.randint(jax.random.key(1), (b, s), 0,
                                          cfg.vocab_size),
             "rewards": jnp.zeros((b, s)),
             "discounts": jnp.full((b, s), 0.99)}
    plain, _ = llm_a3c.a3c_token_loss(cfg, params, batch)
    rules = sharding.activation_rules(MESH, batch_size=b, cfg=cfg)
    assert "moe_ep" in rules
    with compat.set_mesh(MESH), ctx.sharding_rules(rules):
        ep, _ = llm_a3c.a3c_token_loss(cfg, params, batch)
    np.testing.assert_allclose(float(plain), float(ep), rtol=1e-5)
