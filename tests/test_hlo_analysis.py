"""Why the weighted HLO walk exists: XLA's cost_analysis counts While (scan)
bodies once.  These tests pin that fact and validate the weighted parser."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.launch import hlo_analysis as H


def _scan_model(n):
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y
    return f


def test_xla_cost_analysis_undercounts_scan():
    f = _scan_model(8)
    x = jnp.zeros((4, 128))
    w = jnp.zeros((8, 128, 128))
    c_scan = jax.jit(f).lower(x, w).compile()
    flops_scan = compat.cost_analysis(c_scan).get("flops", 0)

    def unrolled(x, w):
        for i in range(8):
            x = jnp.tanh(x @ w[i])
        return x
    c_unr = jax.jit(unrolled).lower(x, w).compile()
    flops_unr = compat.cost_analysis(c_unr).get("flops", 0)
    # the documented defect: scan counted once vs 8x
    assert flops_unr > 6 * flops_scan


def test_weighted_walk_recovers_trip_count():
    f = _scan_model(8)
    x = jnp.zeros((4, 128))
    w = jnp.zeros((8, 128, 128))
    c = jax.jit(f).lower(x, w).compile()
    tot = H.weighted_totals(c.as_text())
    expect = 8 * 2 * 4 * 128 * 128     # 8 iterations x 2MNK
    assert abs(tot["flops"] - expect) / expect < 0.05, tot["flops"]


def test_shape_parsing():
    assert H._type_bytes("bf16[16,4096,512]{2,1,0}") == 16 * 4096 * 512 * 2
    assert H._type_bytes("(f32[8,8], f32[4])") == 8 * 8 * 4 + 16
    assert H._shape_dims("f32[3,5]{1,0}") == [3, 5]


def test_operand_name_extraction():
    ops = H._operands("(%copy.1, %all-gather.1), channel_id=1")
    assert ops == ["copy.1", "all-gather.1"]
