"""Tier T3: bounded-staleness delayed synchronization (pod-scale asynchrony).

The paper's Hogwild! threads tolerate unbounded word-level staleness on one
machine.  At pod scale the TPU-native analogue is *local update / periodic
merge*: G replica groups (the ``pod`` mesh axis, or simulated on CPU) each
apply their own updates for H rounds, then parameters (and, in the paper's
Shared-RMSProp spirit, the second-moment accumulators g) are averaged.

This satisfies Tsitsiklis (1994)'s "outdated information is eventually
discarded" condition with an explicit bound (staleness <= H·t_max steps),
which is *stronger* than what Hogwild! guarantees.  On the production mesh
the merge is one all-reduce over the ``pod`` axis every H steps — amortized
collective cost 1/H of full synchronous data parallelism.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def replicate(tree, n_groups: int):
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape), tree)


def merge(tree_grouped):
    """ψ-average across the group axis (axis 0)."""
    return jax.tree.map(lambda a: jnp.mean(a, 0), tree_grouped)


def merge_every(step: jnp.ndarray, h: int, tree_grouped):
    """Return group-averaged params every h-th step, else unchanged."""
    do = (step % h) == 0
    merged = merge(tree_grouped)
    n = jax.tree.leaves(tree_grouped)[0].shape[0]
    broad = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), merged)
    return jax.tree.map(lambda g, b: jnp.where(do, b, g), tree_grouped,
                        broad)


def make_delayed_train_step(cfg, opt, *, n_groups: int, merge_interval: int,
                            gamma: float = 0.99, beta: float = 0.01,
                            lr: float = 7e-4,
                            merge_opt_state: bool = True):
    """Grouped train step: params/opt_state carry a leading group axis; each
    group consumes its own batch shard and updates locally; groups merge
    every ``merge_interval`` steps.

    On the production mesh the group axis is sharded over ``pod`` so the
    per-group update is pod-local and the merge lowers to a cross-pod
    all-reduce — the Gorila-vs-A3C spectrum made explicit.

    ``merge_opt_state`` mirrors the paper's Shared RMSProp: True shares the
    second-moment statistics across groups at merge points (the robust
    variant, Fig. 8), False keeps them forever-local (per-thread RMSProp).
    """
    from repro.core.llm_a3c import a3c_token_loss
    from repro.optim import optimizers as opt_mod

    def local_update(params, opt_state, batch):
        grads, metrics = jax.grad(
            lambda p: a3c_token_loss(cfg, p, batch, gamma=gamma,
                                     beta=beta),
            has_aux=True)(params)
        updates, opt_state = opt.update(grads, opt_state, lr)
        return opt_mod.apply_updates(params, updates), opt_state, metrics

    def train_step(params_g, opt_state_g, batch_g, step):
        params_g, opt_state_g, metrics = jax.vmap(local_update)(
            params_g, opt_state_g, batch_g)
        params_g = merge_every(step + 1, merge_interval, params_g)
        if merge_opt_state:
            opt_state_g = merge_every(step + 1, merge_interval, opt_state_g)
        return params_g, opt_state_g, jax.tree.map(jnp.mean, metrics)

    return train_step
