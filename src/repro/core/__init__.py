from repro.core.agents import ALGORITHMS  # noqa: F401
from repro.core.async_runner import RunnerConfig, make_runner  # noqa: F401
from repro.core.returns import n_step_returns, gae_advantages  # noqa: F401
