"""Asynchronous actor-learner runner — the paper's core mechanism, adapted.

Tier T1 ("hogwild"): K workers roll out in parallel from the same parameter
snapshot, then their gradients are applied SEQUENTIALLY to the shared
parameters — worker k's gradient lands on parameters that k-1 other updates
have already moved.  This is the standard bounded-staleness model of
Hogwild!: gradient staleness ∈ [0, K-1], exactly the effect the lock-free
threads produce (modulo word-level tearing, which has no SPMD analogue).

Tier T2 ("sync"): same rollouts, one averaged update (A2C — the synchronous
limit of A3C; used as ablation).

Shared vs per-worker optimizer statistics (paper §4.5 / Fig. 8): with
``shared_stats=True`` one RMSProp accumulator g is threaded through the
sequential scan (the paper's Shared RMSProp); otherwise each worker owns a g
(stacked state, vmap-applied), reproducing the per-thread variant.

Target networks for the value-based methods are swapped every
``target_interval`` global frames (paper's I_target).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import exploration
from repro.core.agents import Algorithm
from repro.core.rollout import init_worker, rollout_segment
from repro.envs.api import Env
from repro.optim import optimizers as opt_mod
from repro.optim import schedules


@dataclasses.dataclass(frozen=True)
class RunnerConfig:
    n_workers: int = 16
    t_max: int = 5
    lr0: float = 7e-4
    total_frames: int = 200_000
    target_interval: int = 2_000
    anneal_frames: int = 50_000
    mode: str = "hogwild"          # hogwild (T1) | sync (T2)
    optimizer: str = "shared_rmsprop"
    shared_stats: bool = True
    max_grad_norm: float = 40.0
    lr_schedule: str = "linear"


def _clip_grads(grads, max_norm):
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-8))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def make_runner(algo: Algorithm, env: Env, net_params, cfg: RunnerConfig,
                *, net_state0=None):
    """Returns (state0, round_fn) where round_fn is jit-compiled and advances
    all workers by one t_max segment + applies their updates."""
    opt = opt_mod.OPTIMIZERS[cfg.optimizer]()
    sched = schedules.SCHEDULES[cfg.lr_schedule]

    def init_state(key):
        k_w, k_eps, k_rng = jax.random.split(key, 3)
        workers = jax.vmap(lambda k: init_worker(
            env, k, net_state0))(jax.random.split(k_w, cfg.n_workers))
        if cfg.shared_stats:
            opt_state = opt.init(net_params)
        else:
            opt_state = jax.vmap(lambda _: opt.init(net_params))(
                jnp.arange(cfg.n_workers))
        return {
            "params": net_params,
            "target_params": net_params,
            "opt_state": opt_state,
            "workers": workers,
            "eps_final": exploration.sample_eps_final(k_eps, cfg.n_workers),
            "frames": jnp.zeros((), jnp.int32),
            "last_target_sync": jnp.zeros((), jnp.int32),
            "rng": k_rng,
        }

    def worker_segment(params, target_params, worker, eps_final, frames):
        eps = exploration.eps_at(eps_final, frames, cfg.anneal_frames)

        def act_fn(obs, net_state, key):
            return algo.act(params, obs, net_state, key, eps)

        new_worker, traj = rollout_segment(act_fn, env, worker, cfg.t_max)

        def loss_fn(p):
            loss, metrics = algo.segment_loss(p, target_params, traj)
            return loss, metrics

        grads, metrics = jax.grad(loss_fn, has_aux=True)(params)
        grads, gnorm = _clip_grads(grads, cfg.max_grad_norm)
        metrics["grad_norm"] = gnorm
        metrics["ep_ret"] = new_worker["last_ep_ret"]
        return grads, new_worker, metrics

    def round_fn(state):
        params = state["params"]
        lr = sched(cfg.lr0, state["frames"].astype(jnp.float32),
                   float(cfg.total_frames))
        grads, workers, metrics = jax.vmap(
            worker_segment, in_axes=(None, None, 0, 0, None))(
                params, state["target_params"], state["workers"],
                state["eps_final"], state["frames"])

        if cfg.mode == "sync":
            g_mean = jax.tree.map(lambda g: jnp.mean(g, 0), grads)
            opt_state = state["opt_state"]
            if not cfg.shared_stats:
                opt_state = jax.tree.map(lambda s: s[0], opt_state)
            updates, opt_state = opt.update(g_mean, opt_state, lr)
            params = opt_mod.apply_updates(params, updates)
            if not cfg.shared_stats:
                opt_state = jax.tree.map(
                    lambda s: jnp.broadcast_to(s, (cfg.n_workers,) + s.shape),
                    opt_state)
        elif cfg.mode == "hogwild":
            if cfg.shared_stats:
                def apply_one(carry, g_w):
                    p, ost = carry
                    updates, ost = opt.update(g_w, ost, lr)
                    return (opt_mod.apply_updates(p, updates), ost), None

                (params, opt_state), _ = jax.lax.scan(
                    apply_one, (params, state["opt_state"]), grads)
            else:
                def apply_one(p, inp):
                    g_w, ost_w = inp
                    updates, ost_w = opt.update(g_w, ost_w, lr)
                    return opt_mod.apply_updates(p, updates), ost_w

                params, opt_state = jax.lax.scan(
                    apply_one, params, (grads, state["opt_state"]))
        else:
            raise ValueError(cfg.mode)

        frames = state["frames"] + cfg.n_workers * cfg.t_max
        # target network swap every target_interval frames
        do_swap = (frames - state["last_target_sync"]) >= cfg.target_interval
        target = jax.tree.map(
            lambda t, p: jnp.where(do_swap, p, t),
            state["target_params"], params) if algo.needs_target \
            else state["target_params"]
        new_state = dict(state, params=params, opt_state=opt_state,
                         workers=workers, frames=frames,
                         target_params=target,
                         last_target_sync=jnp.where(
                             do_swap, frames, state["last_target_sync"]))
        mean_metrics = {k: jnp.mean(v) for k, v in metrics.items()}
        return new_state, mean_metrics

    return init_state, jax.jit(round_fn)


def evaluate(algo: Algorithm, env: Env, params, key, *, n_episodes: int = 8,
             max_steps: int = 1000, net_state0=None) -> jnp.ndarray:
    """Greedy/near-greedy evaluation: mean undiscounted episode return."""

    def one_episode(k):
        k_env, k_steps = jax.random.split(k)
        env_state, obs = env.reset(k_env)

        def step(carry, k_t):
            env_state, obs, net_state, ret, done_seen = carry
            k_a, k_e = jax.random.split(k_t)
            action, net_state = algo.act(params, obs, net_state, k_a,
                                         jnp.asarray(0.01))
            env_state, obs, reward, done = env.step(env_state, action, k_e)
            ret = ret + reward * (1.0 - done_seen)
            done_seen = jnp.maximum(done_seen, done.astype(jnp.float32))
            return (env_state, obs, net_state, ret, done_seen), None

        init = (env_state, obs, net_state0, jnp.zeros(()), jnp.zeros(()))
        carry, _ = jax.lax.scan(step, init,
                                jax.random.split(k_steps, max_steps))
        return carry[3]

    rets = jax.vmap(one_episode)(jax.random.split(key, n_episodes))
    return jnp.mean(rets)
