"""Rollout collection: one t_max-step segment per actor-learner (paper Alg.
2/3 inner loop), as a ``lax.scan`` so it vmaps across workers.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.envs.api import Env


def init_worker(env: Env, key, net_state0=None) -> Dict[str, Any]:
    k_env, k_rng = jax.random.split(key)
    env_state, obs = env.reset(k_env)
    w = {
        "env_state": env_state,
        "obs": obs,
        "rng": k_rng,
        "frame": jnp.zeros((), jnp.int32),
        "ep_ret": jnp.zeros(()),
        "last_ep_ret": jnp.zeros(()),
    }
    if net_state0 is not None:
        w["net_state"] = net_state0
    return w


def rollout_segment(act_fn: Callable, env: Env, worker: Dict[str, Any],
                    t_max: int):
    """act_fn(obs, net_state, key) -> (action, net_state).

    Returns (new_worker, traj) with traj["obs"] of length T+1 (bootstrap
    state included) and traj["net_state"] = the segment-start LSTM state.
    """
    has_net_state = "net_state" in worker
    net_state0 = worker.get("net_state")

    def step(c, _):
        rng, k_act, k_env = jax.random.split(c["rng"], 3)
        action, net_state = act_fn(c["obs"], c.get("net_state"), k_act)
        env_state, obs, reward, done = env.step(c["env_state"], action,
                                                k_env)
        ep_ret = c["ep_ret"] + reward
        c2 = dict(c, env_state=env_state, obs=obs, rng=rng,
                  frame=c["frame"] + 1,
                  ep_ret=jnp.where(done, 0.0, ep_ret),
                  last_ep_ret=jnp.where(done, ep_ret, c["last_ep_ret"]))
        if has_net_state:
            # recurrent agents: reset LSTM state at episode boundaries
            c2["net_state"] = jax.tree.map(
                lambda a: jnp.where(done, jnp.zeros_like(a), a), net_state)
        return c2, (c["obs"], action, reward, done)

    final, (obs_seq, actions, rewards, dones) = jax.lax.scan(
        step, worker, None, length=t_max)
    traj = {
        "obs": jnp.concatenate([obs_seq, final["obs"][None]], axis=0),
        "actions": actions,
        "rewards": rewards,
        "dones": dones,
    }
    if has_net_state:
        traj["net_state"] = net_state0
    return final, traj
