"""A3C at LLM scale: the paper's Alg. 3 loss applied to a token-level MDP.

State s_t = token prefix, action a_t = tokens[t+1], policy = LM head softmax,
critic = value head.  The n-step return recursion runs over the sequence
axis — every position gets the "longest possible" forward-view return exactly
as in the paper, with the final position's value as bootstrap.

This is the ``train_step`` that the multi-pod dry-run lowers for every
assigned architecture: the actor-learner groups live on the ``data`` mesh
axis, tensor parallelism on ``model``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.returns import n_step_returns
from repro.models import model as M
from repro.models.config import ModelConfig


def a3c_token_loss(cfg: ModelConfig, params, batch: Dict[str, Any], *,
                   gamma: float = 0.99, beta: float = 0.01,
                   value_coef: float = 0.5):
    """batch: tokens (B,S) [or embeds/enc_frames per family], rewards (B,S),
    discounts (B,S) = gamma * (1 - done).  Position t's reward is for the
    transition prefix[:t] --tokens[t+1]--> prefix[:t+1]."""
    out = M.forward(cfg, params, batch)
    logits = out["logits"].astype(jnp.float32)        # (B, S, V)
    values = out["value"]                             # (B, S)

    if "actions" in batch:
        actions = batch["actions"]                    # (B, S) explicit
    else:
        actions = jnp.roll(batch["tokens"], -1, axis=1)
    rewards = batch["rewards"]
    discounts = batch["discounts"]

    # returns over the sequence axis (time-major for the scan)
    bootstrap = jax.lax.stop_gradient(values[:, -1])
    rets = n_step_returns(jnp.moveaxis(rewards, 1, 0),
                          jnp.moveaxis(discounts, 1, 0),
                          bootstrap)
    rets = jnp.moveaxis(rets, 0, 1)                   # (B, S)

    valid = jnp.ones_like(rewards).at[:, -1].set(0.0)  # last pos: no action
    nvalid = jnp.maximum(valid.sum(), 1.0)
    adv = jax.lax.stop_gradient(rets - values)

    logp_all = jax.nn.log_softmax(logits)
    logp_a = jnp.take_along_axis(logp_all, actions[..., None], -1)[..., 0]
    entropy = -jnp.sum(jnp.exp(logp_all) * logp_all, -1)

    pol_loss = -jnp.sum(logp_a * adv * valid) / nvalid
    v_loss = value_coef * jnp.sum((rets - values) ** 2 * valid) / nvalid
    ent_loss = -beta * jnp.sum(entropy * valid) / nvalid
    aux = cfg.aux_loss_weight * out.get("aux_loss", 0.0)
    loss = pol_loss + v_loss + ent_loss + aux
    metrics = {"loss": loss, "pol": pol_loss, "value": v_loss,
               "entropy": -ent_loss / max(beta, 1e-9), "aux": aux,
               "mean_return": jnp.sum(rets * valid) / nvalid}
    return loss, metrics


def make_train_step(cfg: ModelConfig, opt, *, gamma: float = 0.99,
                    beta: float = 0.01, lr0: float = 7e-4,
                    total_steps: int = 100_000):
    """Synchronous (T2) data-parallel train step — the A2C limit of A3C.
    Under pjit the cross-group gradient reduction is the all-reduce the
    compiler inserts for the data axis."""
    from repro.optim import optimizers as opt_mod
    from repro.optim import schedules

    def train_step(params, opt_state, batch, step):
        lr = schedules.linear_anneal(lr0, step.astype(jnp.float32),
                                     float(total_steps))
        grads, metrics = jax.grad(
            lambda p: a3c_token_loss(cfg, p, batch, gamma=gamma,
                                     beta=beta),
            has_aux=True)(params)
        updates, opt_state = opt.update(grads, opt_state, lr)
        params = opt_mod.apply_updates(params, updates)
        return params, opt_state, metrics

    return train_step


def _stream_keys(key, sids, pos, b):
    """One PRNG key per batch row, derived from (stream id, logical
    position) — NOT from the engine step count, so a speculative run
    that commits 3 tokens in one step and a plain decode that takes 3
    steps draw identical streams for identical tokens."""
    sids = jnp.broadcast_to(jnp.asarray(sids), (b,)).astype(jnp.uint32)
    pos = jnp.broadcast_to(jnp.asarray(pos), (b,)).astype(jnp.uint32)
    keys = jax.vmap(jax.random.fold_in, (None, 0))(key, sids)
    return jax.vmap(jax.random.fold_in)(keys, pos)


def sample_slot_tokens(logits, key, *, sample: bool = True, sids=None,
                       pos=None):
    """Per-slot sampling: logits (B, V), one threaded PRNG key.

    With ``sids``/``pos`` (the serve engine's path) each row draws from
    the ``fold_in(fold_in(key, sids[j]), pos[j])`` stream — keyed by the
    request's identity and the *logical position of the sampled token*,
    so streams are invariant to batching, slot assignment, preemption
    and speculation (a token is the same draw no matter how many verify
    tokens committed alongside it).  Legacy callers omit both and get
    the per-slot-index fold (caller folds the step index into ``key``).
    """
    if not sample:
        return jnp.argmax(logits, axis=-1)
    b = logits.shape[0]
    if sids is None:
        keys = jax.vmap(jax.random.fold_in, (None, 0))(key, jnp.arange(b))
    else:
        keys = _stream_keys(key, sids, pos, b)
    return jax.vmap(jax.random.categorical)(keys, logits)


def make_serve_step(cfg: ModelConfig, *, sample: bool = True):
    """One-token decode step for the actor/serving path (decode shapes).
    Returns (token (B,), value (B,), cache).

    ``pos`` is a lockstep scalar or per-slot (B,) (continuous batching);
    ``key`` is a *threaded* jax PRNG key.  Engine callers pass ``sids``
    (per-slot stream ids, e.g. request ids): the generated token at
    logical position pos + 1 then draws from the (sid, pos + 1) stream —
    invariant to speculation and scheduling.  Legacy callers omit
    ``sids`` and fold the step index into ``key`` themselves."""

    def serve_step(params, cache, batch, pos, key, sids=None):
        out, cache = M.decode_step(cfg, params, cache, batch, pos)
        logits = out["logits"][:, -1].astype(jnp.float32)
        if sids is None:
            token = sample_slot_tokens(logits, key, sample=sample)
        else:
            token = sample_slot_tokens(logits, key, sample=sample,
                                       sids=sids, pos=pos + 1)
        value = out["value"][:, -1] if "value" in out else \
            jnp.zeros(logits.shape[0])
        return token, value, cache

    return serve_step


def make_verify_step(cfg: ModelConfig, shift: int, *, sample: bool = True):
    """Fused speculative round for the serve engine: ONE jitted call
    scores a (B, K) batch of per-slot draft chunks (row j's current
    token + drafts at positions pos[j] + i), decides acceptance, and
    commits exactly the accepted rows' KV — a single launch per round
    (a separate host-decided commit launch doubled per-round dispatch
    overhead, which is most of what speculation amortises).

    Returns ``verify_step(params, cache, batch, pos, key, sids, k_eff,
    remaining) -> (targets (B, K) int32, n_acc (B,) int32, cache)``:
    ``targets[j, i]`` is the token the target model emits after
    consuming position pos[j] + i — greedy argmax, or a draw from the
    (sid, pos + i + 1) stream, the *same* derivation ``make_serve_step``
    uses, so accepted sampled tokens are bit-identical to
    non-speculative decode.  The accept rule is the longest draft
    prefix matching the targets (within row j's effective k) plus the
    bonus target token, clamped to ``remaining[j]`` (the request's
    unused generation budget; 0 marks an idle row, which accepts and
    commits nothing).  ``shift`` is the engine's logical cache length
    (static re-basing bound).

    Verify itself writes nothing — ``M.verify_step`` returns the chunk
    K/V as ``pendings`` and ``M.commit_step`` scatters rows i <
    n_acc[j] in the same launch, so KV rollback on rejection stays a
    no-op by construction, and every output the host reads is forced
    together (no partially-dispatched cache state outlives the
    round)."""

    def verify_step(params, cache, batch, pos, key, sids, k_eff,
                    remaining):
        out, pendings = M.verify_step(cfg, params, cache, batch, pos,
                                      shift)
        logits = out["logits"].astype(jnp.float32)      # (B, K, V)
        b, kq, _ = logits.shape
        if not sample:
            targets = jnp.argmax(logits, axis=-1)
        else:
            tpos = pos[:, None] + 1 + jnp.arange(kq)[None]   # (B, K)
            skeys = jax.vmap(jax.random.fold_in, (None, 0))(
                key, jnp.broadcast_to(jnp.asarray(sids), (b,))
                .astype(jnp.uint32))
            pkeys = jax.vmap(jax.vmap(jax.random.fold_in, (None, 0)))(
                skeys, tpos.astype(jnp.uint32))
            targets = jax.vmap(jax.vmap(jax.random.categorical))(pkeys,
                                                                 logits)
        targets = targets.astype(jnp.int32)
        # accept: a_j = leading run of draft/target matches inside row
        # j's effective k (same rule as the old host loop: position i
        # counts iff i < k_eff - 1 and every draft up to i matched)
        match = batch["tokens"][:, 1:] == targets[:, :-1]    # (B, K-1)
        in_k = jnp.arange(kq - 1)[None, :] < (k_eff[:, None] - 1)
        run = jnp.cumprod((match & in_k).astype(jnp.int32), axis=1)
        n_acc = jnp.minimum(run.sum(axis=1) + 1,
                            remaining).astype(jnp.int32)
        cache = M.commit_step(cfg, cache, pendings, pos, n_acc)
        return targets, n_acc, cache

    return verify_step


def make_prefill_step(cfg: ModelConfig):
    """Chunked flash prefill for the serve engine: one jitted call runs a
    whole (B, C) prompt chunk through the flash forward path and writes the
    KV caches in blocks — replacing C single-token ``serve_step`` launches.
    Returns None when the architecture's caches can't be block-written
    (SSM / xLSTM / enc-dec); callers fall back to the decode loop.

    The returned fn is ``prefill_step(params, cache, batch, pos0,
    true_len) -> (logits (B, C, V), cache)`` with ``pos0`` static (one
    trace per chunk offset).

    Ring (sliding-window) architectures chunk-prefill too: ``true_len``
    (B,) carries each row's real prompt length and the ring cache write
    masks rows past it, so right-padded admission chunks can no longer
    alias ring rows that the decode-side kpos attributes to real earlier
    positions (the gate PR 4 had to place here).  Exact-chunk callers may
    leave ``true_len`` None."""
    if not M.supports_chunked_prefill(cfg):
        return None

    @functools.partial(jax.jit, static_argnames=("pos0",))
    def prefill_step(params, cache, batch, pos0=0, true_len=None):
        out, cache = M.prefill_step(cfg, params, cache, batch, pos0,
                                    true_len)
        return out["logits"].astype(jnp.float32), cache

    return prefill_step
