"""A3C at LLM scale: the paper's Alg. 3 loss applied to a token-level MDP.

State s_t = token prefix, action a_t = tokens[t+1], policy = LM head softmax,
critic = value head.  The n-step return recursion runs over the sequence
axis — every position gets the "longest possible" forward-view return exactly
as in the paper, with the final position's value as bootstrap.

This is the ``train_step`` that the multi-pod dry-run lowers for every
assigned architecture: the actor-learner groups live on the ``data`` mesh
axis, tensor parallelism on ``model``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.returns import n_step_returns
from repro.models import model as M
from repro.models.config import ModelConfig


def a3c_token_loss(cfg: ModelConfig, params, batch: Dict[str, Any], *,
                   gamma: float = 0.99, beta: float = 0.01,
                   value_coef: float = 0.5):
    """batch: tokens (B,S) [or embeds/enc_frames per family], rewards (B,S),
    discounts (B,S) = gamma * (1 - done).  Position t's reward is for the
    transition prefix[:t] --tokens[t+1]--> prefix[:t+1]."""
    out = M.forward(cfg, params, batch)
    logits = out["logits"].astype(jnp.float32)        # (B, S, V)
    values = out["value"]                             # (B, S)

    if "actions" in batch:
        actions = batch["actions"]                    # (B, S) explicit
    else:
        actions = jnp.roll(batch["tokens"], -1, axis=1)
    rewards = batch["rewards"]
    discounts = batch["discounts"]

    # returns over the sequence axis (time-major for the scan)
    bootstrap = jax.lax.stop_gradient(values[:, -1])
    rets = n_step_returns(jnp.moveaxis(rewards, 1, 0),
                          jnp.moveaxis(discounts, 1, 0),
                          bootstrap)
    rets = jnp.moveaxis(rets, 0, 1)                   # (B, S)

    valid = jnp.ones_like(rewards).at[:, -1].set(0.0)  # last pos: no action
    nvalid = jnp.maximum(valid.sum(), 1.0)
    adv = jax.lax.stop_gradient(rets - values)

    logp_all = jax.nn.log_softmax(logits)
    logp_a = jnp.take_along_axis(logp_all, actions[..., None], -1)[..., 0]
    entropy = -jnp.sum(jnp.exp(logp_all) * logp_all, -1)

    pol_loss = -jnp.sum(logp_a * adv * valid) / nvalid
    v_loss = value_coef * jnp.sum((rets - values) ** 2 * valid) / nvalid
    ent_loss = -beta * jnp.sum(entropy * valid) / nvalid
    aux = cfg.aux_loss_weight * out.get("aux_loss", 0.0)
    loss = pol_loss + v_loss + ent_loss + aux
    metrics = {"loss": loss, "pol": pol_loss, "value": v_loss,
               "entropy": -ent_loss / max(beta, 1e-9), "aux": aux,
               "mean_return": jnp.sum(rets * valid) / nvalid}
    return loss, metrics


def make_train_step(cfg: ModelConfig, opt, *, gamma: float = 0.99,
                    beta: float = 0.01, lr0: float = 7e-4,
                    total_steps: int = 100_000):
    """Synchronous (T2) data-parallel train step — the A2C limit of A3C.
    Under pjit the cross-group gradient reduction is the all-reduce the
    compiler inserts for the data axis."""
    from repro.optim import optimizers as opt_mod
    from repro.optim import schedules

    def train_step(params, opt_state, batch, step):
        lr = schedules.linear_anneal(lr0, step.astype(jnp.float32),
                                     float(total_steps))
        grads, metrics = jax.grad(
            lambda p: a3c_token_loss(cfg, p, batch, gamma=gamma,
                                     beta=beta),
            has_aux=True)(params)
        updates, opt_state = opt.update(grads, opt_state, lr)
        params = opt_mod.apply_updates(params, updates)
        return params, opt_state, metrics

    return train_step


def sample_slot_tokens(logits, key, *, sample: bool = True):
    """Per-slot sampling: logits (B, V), one threaded PRNG key.  Each batch
    slot draws from its own ``fold_in(key, slot)`` stream, so concurrent
    requests never share a sampling stream (and the caller folds the step
    index into ``key``, so streams never repeat across steps either)."""
    if not sample:
        return jnp.argmax(logits, axis=-1)
    b = logits.shape[0]
    keys = jax.vmap(jax.random.fold_in, (None, 0))(key, jnp.arange(b))
    return jax.vmap(jax.random.categorical)(keys, logits)


def make_serve_step(cfg: ModelConfig, *, sample: bool = True):
    """One-token decode step for the actor/serving path (decode shapes).
    Returns (token (B,), value (B,), cache).

    ``pos`` is a lockstep scalar or per-slot (B,) (continuous batching);
    ``key`` is a *threaded* jax PRNG key — the caller folds the step index
    in (``jax.random.fold_in(base, step)``) and the step folds the slot
    index per row, replacing the old ``jax.random.key(uint32_seed)``
    rebuild whose streams were correlated across steps and identical
    across slots."""

    def serve_step(params, cache, batch, pos, key):
        out, cache = M.decode_step(cfg, params, cache, batch, pos)
        logits = out["logits"][:, -1].astype(jnp.float32)
        token = sample_slot_tokens(logits, key, sample=sample)
        value = out["value"][:, -1] if "value" in out else \
            jnp.zeros(logits.shape[0])
        return token, value, cache

    return serve_step


def make_prefill_step(cfg: ModelConfig):
    """Chunked flash prefill for the serve engine: one jitted call runs a
    whole (B, C) prompt chunk through the flash forward path and writes the
    KV caches in blocks — replacing C single-token ``serve_step`` launches.
    Returns None when the architecture's caches can't be block-written
    (SSM / xLSTM / enc-dec); callers fall back to the decode loop.

    The returned fn is ``prefill_step(params, cache, batch, pos0,
    true_len) -> (logits (B, C, V), cache)`` with ``pos0`` static (one
    trace per chunk offset).

    Ring (sliding-window) architectures chunk-prefill too: ``true_len``
    (B,) carries each row's real prompt length and the ring cache write
    masks rows past it, so right-padded admission chunks can no longer
    alias ring rows that the decode-side kpos attributes to real earlier
    positions (the gate PR 4 had to place here).  Exact-chunk callers may
    leave ``true_len`` None."""
    if not M.supports_chunked_prefill(cfg):
        return None

    @functools.partial(jax.jit, static_argnames=("pos0",))
    def prefill_step(params, cache, batch, pos0=0, true_len=None):
        out, cache = M.prefill_step(cfg, params, cache, batch, pos0,
                                    true_len)
        return out["logits"].astype(jnp.float32), cache

    return prefill_step
