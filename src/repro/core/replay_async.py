"""Beyond-paper: experience replay INSIDE the asynchronous framework.

The paper's Conclusions: "Incorporating experience replay into the
asynchronous reinforcement learning framework could substantially improve
the data efficiency of these methods by reusing old data."  This module
implements that proposal for the value-based methods: each actor-learner
keeps a small local replay buffer; every update combines the fresh on-policy
segment gradient (the paper's Alg. 1/2) with a gradient on a uniformly
sampled replay minibatch of past transitions (1-step Q targets).

Per-worker local buffers preserve the lock-free structure — no shared
buffer, no cross-worker coordination — so the method remains "asynchronous"
in the paper's sense; the replay fraction ``replay_weight`` interpolates
between pure A3C-style on-policy (0.0) and DQN-like replay-heavy (1.0).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import exploration
from repro.core.agents import Algorithm
from repro.core.rollout import init_worker, rollout_segment
from repro.envs.api import Env
from repro.models import atari as nets
from repro.optim import optimizers as opt_mod
from repro.optim import schedules


@dataclasses.dataclass(frozen=True)
class ReplayAsyncConfig:
    n_workers: int = 8
    t_max: int = 5
    lr0: float = 1e-2
    buffer_size: int = 512          # per worker
    replay_batch: int = 16
    replay_weight: float = 0.5
    warmup: int = 64                # transitions before replay kicks in
    gamma: float = 0.99
    target_interval: int = 2_000
    anneal_frames: int = 20_000
    total_frames: int = 10**9
    max_grad_norm: float = 40.0


def _replay_loss(params, target_params, mb, gamma):
    feats, _ = nets.trunk(params, mb["obs"], None)
    q = nets.q_heads(params, feats)
    feats_t, _ = nets.trunk(target_params, mb["next_obs"], None)
    q_t = jax.lax.stop_gradient(nets.q_heads(target_params, feats_t))
    not_done = 1.0 - mb["dones"].astype(jnp.float32)
    y = mb["rewards"] + gamma * not_done * jnp.max(q_t, -1)
    qa = jnp.take_along_axis(q, mb["actions"][:, None], -1)[:, 0]
    return jnp.mean((y - qa) ** 2)


def make_replay_runner(algo: Algorithm, env: Env, net_params,
                       cfg: ReplayAsyncConfig):
    """Hogwild runner with per-worker replay buffers mixed into updates."""
    opt = opt_mod.shared_rmsprop()
    obs_shape = env.obs_shape

    def init_state(key):
        k_w, k_eps, k_rng = jax.random.split(key, 3)
        workers = jax.vmap(lambda k: init_worker(env, k))(
            jax.random.split(k_w, cfg.n_workers))
        buf = {
            "obs": jnp.zeros((cfg.n_workers, cfg.buffer_size) + obs_shape),
            "next_obs": jnp.zeros((cfg.n_workers, cfg.buffer_size)
                                  + obs_shape),
            "actions": jnp.zeros((cfg.n_workers, cfg.buffer_size),
                                 jnp.int32),
            "rewards": jnp.zeros((cfg.n_workers, cfg.buffer_size)),
            "dones": jnp.zeros((cfg.n_workers, cfg.buffer_size), bool),
        }
        return {
            "params": net_params, "target_params": net_params,
            "opt_state": opt.init(net_params), "workers": workers,
            "buffer": buf,
            "ptr": jnp.zeros((cfg.n_workers,), jnp.int32),
            "filled": jnp.zeros((cfg.n_workers,), jnp.int32),
            "eps_final": exploration.sample_eps_final(k_eps, cfg.n_workers),
            "frames": jnp.zeros((), jnp.int32),
            "last_target_sync": jnp.zeros((), jnp.int32),
            "rng": k_rng,
        }

    def worker_segment(params, target_params, worker, buf_w, ptr, filled,
                       eps_final, frames, key):
        eps = exploration.eps_at(eps_final, frames, cfg.anneal_frames)

        def act_fn(obs, ns, k):
            return algo.act(params, obs, ns, k, eps)

        new_worker, traj = rollout_segment(act_fn, env, worker, cfg.t_max)

        # append the segment's transitions to this worker's ring buffer
        def push(i, carry):
            buf_w, ptr = carry
            slot = ptr % cfg.buffer_size
            buf_w = {
                "obs": buf_w["obs"].at[slot].set(traj["obs"][i]),
                "next_obs": buf_w["next_obs"].at[slot].set(
                    traj["obs"][i + 1]),
                "actions": buf_w["actions"].at[slot].set(
                    traj["actions"][i]),
                "rewards": buf_w["rewards"].at[slot].set(
                    traj["rewards"][i]),
                "dones": buf_w["dones"].at[slot].set(traj["dones"][i]),
            }
            return buf_w, ptr + 1

        buf_w, ptr = jax.lax.fori_loop(0, cfg.t_max, push, (buf_w, ptr))
        filled = jnp.minimum(filled + cfg.t_max, cfg.buffer_size)

        idx = jax.random.randint(key, (cfg.replay_batch,), 0,
                                 jnp.maximum(filled, 1))
        mb = jax.tree.map(lambda a: a[idx], buf_w)
        use_replay = (filled >= cfg.warmup).astype(jnp.float32) \
            * cfg.replay_weight

        def loss_fn(p):
            on_loss, metrics = algo.segment_loss(p, target_params, traj)
            rp_loss = _replay_loss(p, target_params, mb, cfg.gamma)
            return on_loss + use_replay * rp_loss, metrics

        grads, metrics = jax.grad(loss_fn, has_aux=True)(params)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, cfg.max_grad_norm / (gnorm + 1e-8))
        grads = jax.tree.map(lambda g: g * scale, grads)
        metrics["ep_ret"] = new_worker["last_ep_ret"]
        return grads, new_worker, buf_w, ptr, filled, metrics

    @jax.jit
    def round_fn(state):
        rng, k_seg = jax.random.split(state["rng"])
        lr = schedules.linear_anneal(cfg.lr0,
                                     state["frames"].astype(jnp.float32),
                                     float(cfg.total_frames))
        grads, workers, buf, ptr, filled, metrics = jax.vmap(
            worker_segment, in_axes=(None, None, 0, 0, 0, 0, 0, None, 0))(
                state["params"], state["target_params"], state["workers"],
                state["buffer"], state["ptr"], state["filled"],
                state["eps_final"], state["frames"],
                jax.random.split(k_seg, cfg.n_workers))

        def apply_one(carry, g_w):
            p, ost = carry
            updates, ost = opt.update(g_w, ost, lr)
            return (opt_mod.apply_updates(p, updates), ost), None

        (params, opt_state), _ = jax.lax.scan(
            apply_one, (state["params"], state["opt_state"]), grads)
        frames = state["frames"] + cfg.n_workers * cfg.t_max
        # accumulator-based swap (same as async_runner): the old
        # ``frames % target_interval < increment`` test silently skipped
        # swaps whenever one round advanced frames past a whole interval.
        swap = (frames - state["last_target_sync"]) >= cfg.target_interval
        target = jax.tree.map(lambda t, p: jnp.where(swap, p, t),
                              state["target_params"], params)
        return dict(state, params=params, opt_state=opt_state,
                    workers=workers, buffer=buf, ptr=ptr, filled=filled,
                    frames=frames, rng=rng, target_params=target,
                    last_target_sync=jnp.where(
                        swap, frames, state["last_target_sync"])), \
            {k: jnp.mean(v) for k, v in metrics.items()}

    return init_state, round_fn
