"""The four asynchronous algorithms (paper §4.1–4.4) as (act, loss) pairs.

Each algorithm supplies:
  act(params, obs, net_state, key, eps)          -> (action, net_state)
  segment_loss(params, target_params, traj, ...) -> (scalar loss, metrics)

``traj`` is one rollout segment of t_max steps collected by
``repro.core.rollout``: obs (T+1,...) including the bootstrap state, actions
(T,), rewards (T,), dones (T,) and the LSTM state at segment start (so the
loss re-runs the recurrent trunk exactly as the actor saw it — the paper's
forward-view BPTT).

Networks are the paper's own (repro.models.atari); the same losses are reused
at LLM scale by repro.core.llm_a3c.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import exploration
from repro.core.returns import gae_advantages, n_step_returns
from repro.models import atari as nets


@dataclasses.dataclass(frozen=True)
class Algorithm:
    name: str
    act: Callable
    segment_loss: Callable
    needs_target: bool
    policy_based: bool


def _forward(params, obs, net_state):
    feats, net_state = nets.trunk(params, obs, net_state)
    return feats, net_state


def _forward_segment(params, obs_seq, net_state0):
    """Run the trunk over a (T+1, B?, ...) obs sequence, threading LSTM
    state; feedforward nets just vmap."""
    if "lstm" in params:
        def step(st, ob):
            feats, st = nets.trunk(params, ob[None], st)
            return st, feats[0]
        _, feats = jax.lax.scan(step, net_state0, obs_seq)
        return feats
    feats, _ = nets.trunk(params, obs_seq, None)
    return feats


# ---------------------------------------------------------------------------
# A3C (Alg. 3) — discrete and continuous
# ---------------------------------------------------------------------------

def make_a3c(*, gamma: float = 0.99, beta: float = 0.01,
             value_coef: float = 0.5, continuous: bool = False,
             beta_continuous: float = 1e-4,
             gae_lambda: float = 0.0) -> Algorithm:
    """gae_lambda > 0 enables GAE(lambda) advantages (Schulman et al.
    2015b) — the upgrade the paper's Conclusions explicitly propose;
    gae_lambda == 0 is the paper-faithful n-step advantage."""

    def act(params, obs, net_state, key, eps):
        feats, net_state = _forward(params, obs[None], net_state)
        if continuous:
            h = nets.gaussian_heads(params, feats)
            a = h["mu"][0] + jnp.sqrt(h["sigma2"][0]) * \
                jax.random.normal(key, h["mu"][0].shape)
            return a, net_state
        h = nets.actor_critic_heads(params, feats)
        a = jax.random.categorical(key, h["logits"][0])
        return a, net_state

    def segment_loss(params, target_params, traj, **_):
        del target_params
        feats = _forward_segment(params, traj["obs"], traj.get("net_state"))
        discounts = gamma * (1.0 - traj["dones"].astype(jnp.float32))
        if continuous:
            h = nets.gaussian_heads(params, feats)
            values = h["value"]
            bootstrap = jax.lax.stop_gradient(values[-1])
            rets = n_step_returns(traj["rewards"], discounts, bootstrap)
            adv = jax.lax.stop_gradient(rets - values[:-1])
            mu, s2 = h["mu"][:-1], h["sigma2"][:-1]
            logp = -0.5 * (jnp.sum((traj["actions"] - mu) ** 2, -1)
                           / s2
                           + mu.shape[-1] * (jnp.log(2 * jnp.pi * s2)))
            entropy = 0.5 * (jnp.log(2 * jnp.pi * s2) + 1.0)
            pol_loss = -jnp.mean(logp * adv)
            ent_loss = -beta_continuous * jnp.mean(entropy)
        else:
            h = nets.actor_critic_heads(params, feats)
            values = h["value"]
            bootstrap = jax.lax.stop_gradient(values[-1])
            if gae_lambda > 0:
                adv, rets = gae_advantages(
                    traj["rewards"], discounts,
                    jax.lax.stop_gradient(values[:-1]), bootstrap,
                    lam=gae_lambda)
                adv = jax.lax.stop_gradient(adv)
            else:
                rets = n_step_returns(traj["rewards"], discounts, bootstrap)
                adv = jax.lax.stop_gradient(rets - values[:-1])
            logits = h["logits"][:-1]
            logp_all = jax.nn.log_softmax(logits)
            logp = jnp.take_along_axis(
                logp_all, traj["actions"][:, None], axis=-1)[:, 0]
            entropy = -jnp.sum(jnp.exp(logp_all) * logp_all, -1)
            pol_loss = -jnp.mean(logp * adv)
            ent_loss = -beta * jnp.mean(entropy)
        v_loss = value_coef * jnp.mean((rets - values[:-1]) ** 2)
        loss = pol_loss + v_loss + ent_loss
        metrics = {"loss": loss, "pol": pol_loss, "value": v_loss,
                   "entropy": -ent_loss, "mean_return": jnp.mean(rets)}
        return loss, metrics

    return Algorithm("a3c", act, segment_loss, needs_target=False,
                     policy_based=True)


# ---------------------------------------------------------------------------
# value-based: one-step Q (Alg. 1), one-step Sarsa (Eq. 6), n-step Q (Alg. 2)
# ---------------------------------------------------------------------------

def _q_act(params, obs, net_state, key, eps):
    feats, net_state = _forward(params, obs[None], net_state)
    q = nets.q_heads(params, feats)[0]
    return exploration.eps_greedy(key, q, eps), net_state


def make_one_step_q(*, gamma: float = 0.99) -> Algorithm:

    def segment_loss(params, target_params, traj, **_):
        feats = _forward_segment(params, traj["obs"], traj.get("net_state"))
        q = nets.q_heads(params, feats)                      # (T+1, A)
        feats_t = _forward_segment(target_params, traj["obs"],
                                   traj.get("net_state"))
        q_t = jax.lax.stop_gradient(nets.q_heads(target_params, feats_t))
        not_done = 1.0 - traj["dones"].astype(jnp.float32)
        y = traj["rewards"] + gamma * not_done * jnp.max(q_t[1:], -1)
        qa = jnp.take_along_axis(q[:-1], traj["actions"][:, None], -1)[:, 0]
        loss = jnp.mean((y - qa) ** 2)
        return loss, {"loss": loss, "q_mean": jnp.mean(qa)}

    return Algorithm("one_step_q", _q_act, segment_loss, needs_target=True,
                     policy_based=False)


def make_one_step_sarsa(*, gamma: float = 0.99) -> Algorithm:

    def segment_loss(params, target_params, traj, **_):
        feats = _forward_segment(params, traj["obs"], traj.get("net_state"))
        q = nets.q_heads(params, feats)
        feats_t = _forward_segment(target_params, traj["obs"],
                                   traj.get("net_state"))
        q_t = jax.lax.stop_gradient(nets.q_heads(target_params, feats_t))
        not_done = 1.0 - traj["dones"].astype(jnp.float32)
        # Sarsa target needs a' actually taken at s'; within a segment that is
        # actions[i+1], so the last transition has no on-policy a' yet and is
        # excluded (t_max-1 updates per segment — noted in DESIGN.md).
        q_next_a = jnp.take_along_axis(q_t[1:-1], traj["actions"][1:, None],
                                       -1)[:, 0]
        y = traj["rewards"][:-1] + gamma * not_done[:-1] * q_next_a
        qa = jnp.take_along_axis(q[:-2], traj["actions"][:-1, None], -1)[:, 0]
        loss = jnp.mean((y - qa) ** 2)
        return loss, {"loss": loss, "q_mean": jnp.mean(qa)}

    return Algorithm("one_step_sarsa", _q_act, segment_loss,
                     needs_target=True, policy_based=False)


def make_n_step_q(*, gamma: float = 0.99) -> Algorithm:

    def segment_loss(params, target_params, traj, **_):
        feats = _forward_segment(params, traj["obs"], traj.get("net_state"))
        q = nets.q_heads(params, feats)
        feats_t = _forward_segment(target_params, traj["obs"],
                                   traj.get("net_state"))
        q_t = jax.lax.stop_gradient(nets.q_heads(target_params, feats_t))
        discounts = gamma * (1.0 - traj["dones"].astype(jnp.float32))
        bootstrap = jnp.max(q_t[-1], -1)
        rets = n_step_returns(traj["rewards"], discounts, bootstrap)
        qa = jnp.take_along_axis(q[:-1], traj["actions"][:, None], -1)[:, 0]
        loss = jnp.mean((rets - qa) ** 2)
        return loss, {"loss": loss, "q_mean": jnp.mean(qa),
                      "mean_return": jnp.mean(rets)}

    return Algorithm("n_step_q", _q_act, segment_loss, needs_target=True,
                     policy_based=False)


ALGORITHMS = {
    "a3c": make_a3c,
    "one_step_q": make_one_step_q,
    "one_step_sarsa": make_one_step_sarsa,
    "n_step_q": make_n_step_q,
}
