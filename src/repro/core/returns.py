"""Forward-view n-step returns (paper §4.3/4.4) and advantage estimators.

The paper computes, for a rollout segment of up to t_max steps, the "longest
possible n-step return" for every state in the segment:

    R_i = r_i + γ r_{i+1} + ... + γ^{t-i} R_bootstrap        (Alg. 2/3)

implemented as the reverse recursion R <- r_i + γ R seeded with the bootstrap
value (0 at terminal, V(s_t) or max_a Q(s_t,a) otherwise).  ``discounts``
carries γ * (1 - done) per step so episode boundaries inside a segment
truncate the recursion exactly as the pseudocode's terminal check does.

Also provides GAE(λ) (Schulman et al. 2015b) — the paper's Conclusions
explicitly name it as the natural advantage-estimator upgrade; we ship it as
a beyond-paper option.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def n_step_returns(rewards: jnp.ndarray, discounts: jnp.ndarray,
                   bootstrap: jnp.ndarray) -> jnp.ndarray:
    """rewards, discounts: (T, ...); bootstrap: (...)  -> returns (T, ...).

    returns[i] = rewards[i] + discounts[i] * returns[i+1], seeded with
    returns[T] = bootstrap.  Time is axis 0 (scan axis).
    """
    def body(carry, x):
        r, d = x
        carry = r + d * carry
        return carry, carry

    _, rets = jax.lax.scan(body, bootstrap, (rewards, discounts),
                           reverse=True)
    return rets


def n_step_returns_ref(rewards, discounts, bootstrap):
    """O(T^2) python oracle used by property tests."""
    t = rewards.shape[0]
    out = []
    for i in range(t):
        acc = bootstrap
        for j in range(t - 1, i - 1, -1):
            acc = rewards[j] + discounts[j] * acc
        out.append(acc)
    return jnp.stack(out)


def gae_advantages(rewards: jnp.ndarray, discounts: jnp.ndarray,
                   values: jnp.ndarray, bootstrap: jnp.ndarray,
                   *, lam: float = 0.95):
    """Generalized advantage estimation (beyond-paper option).

    values: (T, ...) V(s_i) for the segment; bootstrap: V(s_T).
    Returns (advantages (T, ...), returns = adv + values).
    """
    next_values = jnp.concatenate([values[1:], bootstrap[None]], axis=0)
    deltas = rewards + discounts * next_values - values

    def body(carry, x):
        delta, d = x
        carry = delta + lam * d * carry
        return carry, carry

    _, adv = jax.lax.scan(body, jnp.zeros_like(bootstrap), (deltas, discounts),
                          reverse=True)
    return adv, adv + values
