"""Per-worker exploration policies (paper §4.1, §5.1).

The value-based methods use ε-greedy where each worker's *final* ε is sampled
from {0.1, 0.01, 0.5} with probabilities {0.4, 0.3, 0.3} and ε is annealed
from 1.0 to that value over the first ``anneal_frames`` frames.  Keeping the
per-worker diversity is the paper's stated stabilization mechanism — it is
preserved verbatim here (one ε stream per actor-learner group).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS_FINALS = jnp.array([0.1, 0.01, 0.5])
EPS_PROBS = jnp.array([0.4, 0.3, 0.3])


def sample_eps_final(key, n_workers: int) -> jnp.ndarray:
    idx = jax.random.choice(key, 3, (n_workers,), p=EPS_PROBS)
    return EPS_FINALS[idx]


def eps_at(eps_final: jnp.ndarray, frame: jnp.ndarray,
           anneal_frames: int = 100_000) -> jnp.ndarray:
    frac = jnp.clip(frame / anneal_frames, 0.0, 1.0)
    return 1.0 + frac * (eps_final - 1.0)


def eps_greedy(key, q_values: jnp.ndarray, eps) -> jnp.ndarray:
    """q_values (..., A) -> actions (...,)."""
    k1, k2 = jax.random.split(key)
    greedy = jnp.argmax(q_values, axis=-1)
    rand = jax.random.randint(k1, greedy.shape, 0, q_values.shape[-1])
    explore = jax.random.uniform(k2, greedy.shape) < eps
    return jnp.where(explore, rand, greedy)
