"""DQN with experience replay — the paper's comparison baseline (§3.2).

Uniform replay buffer + target network, per Mnih et al. 2015, so the
"parallel actors replace replay" ablation (Table 1 / Fig. 1 analogue) can be
run: same network, same environment, replay instead of parallel
actor-learners.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import exploration
from repro.envs.api import Env
from repro.models import atari as nets
from repro.optim import optimizers as opt_mod


@dataclasses.dataclass(frozen=True)
class DQNConfig:
    buffer_size: int = 10_000
    batch_size: int = 32
    lr: float = 1e-3
    gamma: float = 0.99
    target_interval: int = 1_000
    train_every: int = 4
    warmup: int = 500
    eps_final: float = 0.05
    anneal_frames: int = 20_000


def make_dqn(env: Env, params, cfg: DQNConfig):
    opt = opt_mod.shared_rmsprop()
    obs_shape = env.obs_shape

    def init_state(key):
        k_env, k_rng = jax.random.split(key)
        env_state, obs = env.reset(k_env)
        buf = {
            "obs": jnp.zeros((cfg.buffer_size,) + obs_shape),
            "next_obs": jnp.zeros((cfg.buffer_size,) + obs_shape),
            "actions": jnp.zeros((cfg.buffer_size,), jnp.int32),
            "rewards": jnp.zeros((cfg.buffer_size,)),
            "dones": jnp.zeros((cfg.buffer_size,), bool),
        }
        return {"params": params, "target_params": params,
                "opt_state": opt.init(params), "buffer": buf,
                "ptr": jnp.zeros((), jnp.int32),
                "filled": jnp.zeros((), jnp.int32),
                "env_state": env_state, "obs": obs,
                "frames": jnp.zeros((), jnp.int32), "rng": k_rng,
                "ep_ret": jnp.zeros(()), "last_ep_ret": jnp.zeros(())}

    def _loss(p, tp, batch):
        feats, _ = nets.trunk(p, batch["obs"], None)
        q = nets.q_heads(p, feats)
        feats_t, _ = nets.trunk(tp, batch["next_obs"], None)
        q_t = jax.lax.stop_gradient(nets.q_heads(tp, feats_t))
        not_done = 1.0 - batch["dones"].astype(jnp.float32)
        y = batch["rewards"] + cfg.gamma * not_done * jnp.max(q_t, -1)
        qa = jnp.take_along_axis(q, batch["actions"][:, None], -1)[:, 0]
        return jnp.mean((y - qa) ** 2)

    @jax.jit
    def step_fn(state):
        rng, k_act, k_env, k_sample = jax.random.split(state["rng"], 4)
        eps = exploration.eps_at(jnp.asarray(cfg.eps_final), state["frames"],
                                 cfg.anneal_frames)
        feats, _ = nets.trunk(state["params"], state["obs"][None], None)
        q = nets.q_heads(state["params"], feats)[0]
        action = exploration.eps_greedy(k_act, q, eps)
        env_state, obs, reward, done = env.step(state["env_state"], action,
                                                k_env)
        ptr = state["ptr"] % cfg.buffer_size
        buf = state["buffer"]
        buf = {
            "obs": buf["obs"].at[ptr].set(state["obs"]),
            "next_obs": buf["next_obs"].at[ptr].set(obs),
            "actions": buf["actions"].at[ptr].set(action),
            "rewards": buf["rewards"].at[ptr].set(reward),
            "dones": buf["dones"].at[ptr].set(done),
        }
        filled = jnp.minimum(state["filled"] + 1, cfg.buffer_size)
        frames = state["frames"] + 1

        def do_train(p, ost):
            idx = jax.random.randint(k_sample, (cfg.batch_size,), 0, filled)
            mb = jax.tree.map(lambda a: a[idx], buf)
            grads = jax.grad(_loss)(p, state["target_params"], mb)
            updates, ost = opt.update(grads, ost, cfg.lr)
            return opt_mod.apply_updates(p, updates), ost

        train = (frames % cfg.train_every == 0) & (frames >= cfg.warmup)
        p2, ost2 = do_train(state["params"], state["opt_state"])
        params_n = jax.tree.map(lambda a, b: jnp.where(train, b, a),
                                state["params"], p2)
        ost_n = jax.tree.map(lambda a, b: jnp.where(train, b, a),
                             state["opt_state"], ost2)
        swap = frames % cfg.target_interval == 0
        target_n = jax.tree.map(lambda t, p: jnp.where(swap, p, t),
                                state["target_params"], params_n)
        ep_ret = state["ep_ret"] + reward
        return dict(state, params=params_n, opt_state=ost_n, buffer=buf,
                    ptr=state["ptr"] + 1, filled=filled, env_state=env_state,
                    obs=obs, frames=frames, rng=rng,
                    target_params=target_n,
                    ep_ret=jnp.where(done, 0.0, ep_ret),
                    last_ep_ret=jnp.where(done, ep_ret,
                                          state["last_ep_ret"]))

    return init_state, step_fn
