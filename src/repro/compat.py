"""Version-tolerant shims over JAX APIs that moved between releases.

The repo targets whatever JAX the image bakes in (currently 0.4.37) but is
written against the newer public names; every drift goes through one helper
here so call sites stay clean and a future JAX bump is a one-file change.

Covered drifts:
  * ``pltpu.CompilerParams``      — named ``TPUCompilerParams`` in <= 0.4.x.
  * ``jax.sharding.set_mesh``     — absent in <= 0.4.x; ``Mesh`` itself is a
    context manager there, and ``AbstractMesh`` needs no entry at all when
    shardings are passed explicitly.
  * ``AbstractMesh(...)``         — 0.4.x takes one tuple of (name, size)
    pairs; newer JAX takes (axis_sizes, axis_names).
"""
from __future__ import annotations

import contextlib
from typing import Sequence, Tuple

import jax
from jax.experimental.pallas import tpu as pltpu

_TPU_COMPILER_PARAMS_CLS = getattr(
    pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams(**kwargs)`` under either name."""
    return _TPU_COMPILER_PARAMS_CLS(**kwargs)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    Prefers ``jax.sharding.set_mesh`` / ``jax.set_mesh`` (newer JAX).  On
    0.4.x a concrete ``Mesh`` is its own context manager; an
    ``AbstractMesh`` has no context to enter — explicit NamedShardings
    carry it — so we no-op.
    """
    setter = getattr(jax.sharding, "set_mesh", None) or \
        getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext(mesh)


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict.

    Depending on jax/XLA version this returns a dict or a one-element list
    of per-module dicts; normalize to the (possibly empty) dict.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def abstract_mesh(axis_sizes: Sequence[int], axis_names: Sequence[str]):
    """``AbstractMesh`` under both constructor signatures."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        pairs: Tuple[Tuple[str, int], ...] = tuple(
            zip(axis_names, axis_sizes))
        return AbstractMesh(pairs)
