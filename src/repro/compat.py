"""Version-tolerant shims over JAX APIs that moved between releases.

The repo targets whatever JAX the image bakes in (currently 0.4.37) but is
written against the newer public names; every drift goes through one helper
here so call sites stay clean and a future JAX bump is a one-file change.

Covered drifts:
  * ``pltpu.CompilerParams``      — named ``TPUCompilerParams`` in <= 0.4.x.
  * ``jax.sharding.set_mesh``     — absent in <= 0.4.x; ``Mesh`` itself is a
    context manager there, and ``AbstractMesh`` needs no entry at all when
    shardings are passed explicitly.
  * ``AbstractMesh(...)``         — 0.4.x takes one tuple of (name, size)
    pairs; newer JAX takes (axis_sizes, axis_names).
  * trace-cache token             — jax has no public "fold this value into
    the jit cache key" hook; ``set_trace_token`` rides the
    ``mesh_context_manager`` config state: it participates in both the
    python trace cache (``config.trace_context()``) and the C++ jit key
    (``include_in_jit_key=True``), and — unlike the xla_metadata slot,
    which JaxprEqnContext managers rewrite mid-trace — it is only ever
    written by ``Mesh.__enter__/__exit__``, so an appended token survives
    a whole trace/lower block.  If the state ever disappears the shim
    degrades to a no-op and the dispatch layer falls back to its
    documented trace-cache caveat.
"""
from __future__ import annotations

import contextlib
from typing import Sequence, Tuple

import jax
from jax.experimental.pallas import tpu as pltpu

_TPU_COMPILER_PARAMS_CLS = getattr(
    pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams(**kwargs)`` under either name."""
    return _TPU_COMPILER_PARAMS_CLS(**kwargs)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    Prefers ``jax.sharding.set_mesh`` / ``jax.set_mesh`` (newer JAX).  On
    0.4.x a concrete ``Mesh`` is its own context manager; an
    ``AbstractMesh`` has no context to enter — explicit NamedShardings
    carry it — so we no-op.

    ``Mesh.__enter__``/``__exit__`` rebuild the trace-token carrier state
    from the mesh stack, which would silently drop a dispatch token
    appended by ``ctx.use_mesh``/``ctx.sharding_rules`` (and with it the
    stale-trace protection), so this wrapper re-asserts the current
    dispatch token after both transitions.
    """
    setter = getattr(jax.sharding, "set_mesh", None) or \
        getattr(jax, "set_mesh", None)
    if setter is not None:
        inner = setter(mesh)
    elif hasattr(mesh, "__enter__"):
        inner = mesh
    else:
        inner = contextlib.nullcontext(mesh)
    if _token_provider is None:
        return inner
    return _reassert_token_around(inner)


@contextlib.contextmanager
def _reassert_token_around(inner):
    with inner as m:
        prev = set_trace_token(_token_provider())
        try:
            yield m
        finally:
            restore_trace_token(prev)
    # the mesh exit rebuilt the carrier from its stack, dropping tokens
    # appended by enclosing ctx managers — re-assert the current state
    set_trace_token(_token_provider())


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict.

    Depending on jax/XLA version this returns a dict or a one-element list
    of per-module dicts; normalize to the (possibly empty) dict.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def _trace_token_state():
    try:
        from jax._src import config as jcfg
        cm = getattr(jcfg, "mesh_context_manager", None)
        if cm is not None and hasattr(cm, "set_local") and \
                hasattr(cm, "get_local"):
            return cm
    except Exception:
        pass
    return None


_NO_TOKEN = object()
_TOKEN_TAG = "repro.dispatch"
_token_provider = None


def register_trace_token_provider(fn) -> None:
    """``fn() -> token | None`` returning the current dispatch state;
    ``set_mesh`` uses it to re-assert the token across Mesh transitions
    (registered by ``repro.distributed.ctx`` at import)."""
    global _token_provider
    _token_provider = fn


def set_trace_token(token):
    """Fold ``token`` (hashable, tagged with ``_TOKEN_TAG``) into jax's jit
    trace-cache key for the current thread.

    Used by ``repro.distributed.ctx`` so that re-lowering one jitted
    callable under a different dispatch mesh / rule set re-resolves kernel
    dispatch instead of replaying the stale trace.  The token is appended
    to the carrier state's previous value (a tuple) with any older
    dispatch token stripped first — idempotent, so re-asserting after a
    Mesh transition cannot stack stale entries.  ``token=None`` means "no
    dispatch state": nothing is appended.  Returns an opaque previous
    value — pass it back to :func:`restore_trace_token` on exit.  Degrades
    to a no-op (returns ``_NO_TOKEN``) if the underlying jax state is
    gone.
    """
    cm = _trace_token_state()
    if cm is None:
        return _NO_TOKEN
    prev = cm.get_local()
    base = prev if isinstance(prev, tuple) else ()
    base = tuple(e for e in base
                 if not (isinstance(e, tuple) and e and e[0] == _TOKEN_TAG))
    cm.set_local(base if token is None else base + (token,))
    return prev


def restore_trace_token(prev) -> None:
    """Restore the value captured by :func:`set_trace_token`."""
    cm = _trace_token_state()
    if cm is not None and prev is not _NO_TOKEN:
        cm.set_local(prev)


def abstract_mesh(axis_sizes: Sequence[int], axis_names: Sequence[str]):
    """``AbstractMesh`` under both constructor signatures."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        pairs: Tuple[Tuple[str, int], ...] = tuple(
            zip(axis_names, axis_sizes))
        return AbstractMesh(pairs)
