"""Synthetic token data pipeline with per-worker seeding.

Produces the batched TokenMDP training inputs (tokens, rewards, discounts)
used by the LLM-scale A3C train path.  Each actor-learner group gets an
independent stream (per-worker seeds — the paper's exploration-diversity
principle applied to data order).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.envs.token_mdp import TokenMDP


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    gamma: float = 0.99
    episode_len: int = 0   # 0 = one episode per sequence

    def batch(self, key, step: int = 0):
        """Sample one training batch.  Sequences are behaviour rollouts of a
        noisy successor policy so rewards are informative but imperfect."""
        k1, k2 = jax.random.split(jax.random.fold_in(key, step))
        first = jax.random.randint(k1, (self.global_batch, 1), 0, self.vocab)
        noise = jax.random.bernoulli(k2, 0.3,
                                     (self.global_batch, self.seq_len))
        rand = jax.random.randint(jax.random.fold_in(k2, 1),
                                  (self.global_batch, self.seq_len), 0,
                                  self.vocab)
        steps = jnp.arange(self.seq_len)[None]
        succ = (first + steps) % self.vocab
        tokens = jnp.where(noise, rand, succ).astype(jnp.int32)

        mdp = TokenMDP(self.vocab, self.seq_len, self.seq_len)
        rewards = mdp.reward_for_sequence(tokens)
        ep = self.episode_len or self.seq_len
        done = ((steps + 1) % ep == 0).astype(jnp.float32)
        done = jnp.broadcast_to(done, rewards.shape)
        discounts = self.gamma * (1.0 - done)
        return {"tokens": tokens, "rewards": rewards, "discounts": discounts}
