"""Grouped-query attention with RoPE / M-RoPE, sliding windows and KV caches.

Three entry points:
  * ``attend_train``   — full-sequence causal (or bidirectional) attention.
  * ``attend_decode``  — one new token against a pre-filled KV cache.
  * ``cross_attend``   — decoder query over encoder memory (Whisper).

The jnp paths here (``sdpa``, masks, the blockwise flash in
``flash_jnp``) are the reference implementations; the Pallas kernels in
``repro.kernels`` implement the same math with explicit VMEM tiling and are
validated against these in tests.  Backend selection — which of the two
families a call lowers through, bare or shard_map'd over a mesh — lives
entirely in ``repro.kernels.dispatch``; the entry points here just forward
``backend`` (default ``"auto"``) to it.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed import ctx
from repro.kernels import dispatch
from repro.models import common as cm

NEG_INF = -1e30


class AttnParams(NamedTuple):
    pass  # attention params are plain dicts; NamedTuple kept for doc purposes


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, *, qkv_bias: bool = False,
                   out_bias: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": cm.init_linear(ks[0], d_model, n_heads * head_dim, bias=qkv_bias),
        "wk": cm.init_linear(ks[1], d_model, n_kv_heads * head_dim, bias=qkv_bias),
        "wv": cm.init_linear(ks[2], d_model, n_kv_heads * head_dim, bias=qkv_bias),
        "wo": cm.init_linear(ks[3], n_heads * head_dim, d_model, bias=out_bias),
    }


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim)


def _repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, Hkv, D) -> (B, S, Hkv * n_rep, D)."""
    if n_rep == 1:
        return x
    b, s, h, d = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, n_rep, d))
    return x.reshape(b, s, h * n_rep, d)


def sdpa(q, k, v, mask, *, scale: Optional[float] = None) -> jnp.ndarray:
    """q (B,Sq,H,D), k/v (B,Sk,H,D), mask broadcastable to (B,H,Sq,Sk)."""
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def causal_mask(sq: int, sk: int, *, window: Optional[int] = None,
                offset: int = 0) -> jnp.ndarray:
    """(1, 1, Sq, Sk) boolean mask.  ``offset`` = absolute position of q row 0
    minus position of k col 0.  ``window`` keeps only the last ``window`` keys
    (sliding-window / chunked-local attention)."""
    qpos = jnp.arange(sq)[:, None] + offset
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window is not None:
        m = m & (kpos > qpos - window)
    return m[None, None]


def attend_train(params: dict, x: jnp.ndarray, cos, sin, cfg,
                 *, window: Optional[int] = None, use_rope: bool = True,
                 bidirectional: bool = False,
                 backend: str = "auto") -> jnp.ndarray:
    """Full-sequence self attention.  x (B, S, d_model)."""
    n_h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(cm.linear(params["wq"], x), n_h, hd)
    k = _split_heads(cm.linear(params["wk"], x), n_kv, hd)
    v = _split_heads(cm.linear(params["wv"], x), n_kv, hd)
    if use_rope:
        rd = getattr(cfg, "rotary_dim", None)
        q = cm.apply_rope(q, cos, sin, rotary_dim=rd)
        k = cm.apply_rope(k, cos, sin, rotary_dim=rd)
    # Megatron-TP: attention is head-local on the model axis; without these
    # constraints GSPMD re-gathers K/V blocks inside the flash scan.
    q = ctx.constrain(q, "attn_q")
    k = ctx.constrain(k, "attn_kv")
    v = ctx.constrain(v, "attn_kv")
    o = dispatch.flash_attention(q, k, v, causal=not bidirectional,
                                 window=window, backend=backend)
    b, s = x.shape[:2]
    return cm.linear(params["wo"], o.reshape(b, s, n_h * hd))


# ---------------------------------------------------------------------------
# KV cache (decode path)
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, cache_len: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> dict:
    """Cache for one attention layer.  ``index`` is the next write slot; for
    ring caches (sliding window) writes wrap modulo ``cache_len``."""
    return {
        "k": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def attend_decode(params: dict, x: jnp.ndarray, cache: dict, pos: jnp.ndarray,
                  cfg, *, window: Optional[int] = None, use_rope: bool = True,
                  backend: str = "auto"):
    """One-token decode.  x (B, 1, d_model); pos () absolute position.

    Returns (out (B, 1, d_model), new_cache).  When ``window`` is set the
    cache is a ring buffer of length == window (sub-linear memory for
    long-context decode); otherwise cache_len == max seq and slot == pos.
    """
    n_h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b = x.shape[0]
    q = _split_heads(cm.linear(params["wq"], x), n_h, hd)
    k = _split_heads(cm.linear(params["wk"], x), n_kv, hd)
    v = _split_heads(cm.linear(params["wv"], x), n_kv, hd)
    if use_rope:
        cos, sin = cm.rope_cos_sin(pos[None, None], hd, cfg.rope_theta)
        rd = getattr(cfg, "rotary_dim", None)
        q = cm.apply_rope(q, cos, sin, rotary_dim=rd)
        k = cm.apply_rope(k, cos, sin, rotary_dim=rd)

    cache_len = cache["k"].shape[1]
    # full cache: slot == pos (pos < cache_len); ring cache: wrap around.
    slot = pos % cache_len
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    new_cache = {"k": ck, "v": cv, "index": pos + 1}

    kpos = _cache_positions(cache_len, pos, window)
    o = dispatch.decode_attention(q[:, 0], ck, cv, kpos, pos,
                                  backend=backend)[:, None]
    return cm.linear(params["wo"], o.reshape(b, 1, n_h * hd)), new_cache


def attend_decode_cp(params: dict, x: jnp.ndarray, cache: dict,
                     pos: jnp.ndarray, cfg, *, window: Optional[int],
                     mesh, seq_axes, dp_axes):
    """Context-parallel decode (flash-decoding pattern, perf iter #5).

    The KV cache's sequence dim is sharded over ``seq_axes``; each device
    computes a partial softmax over its cache slice and the combine is a
    3-tensor psum of (m, l, acc) — O(B*Hq*D) bytes instead of all-gathering
    the multi-GB cache every layer.  The cache write happens on the owning
    shard only (predicated dynamic_update_slice).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n_h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b = x.shape[0]
    q = _split_heads(cm.linear(params["wq"], x), n_h, hd)
    k = _split_heads(cm.linear(params["wk"], x), n_kv, hd)
    v = _split_heads(cm.linear(params["wv"], x), n_kv, hd)
    if True:  # rope (decode positions)
        cos, sin = cm.rope_cos_sin(pos[None, None], hd, cfg.rope_theta)
        rd = getattr(cfg, "rotary_dim", None)
        q = cm.apply_rope(q, cos, sin, rotary_dim=rd)
        k = cm.apply_rope(k, cos, sin, rotary_dim=rd)

    cache_len = cache["k"].shape[1]
    slot = pos % cache_len
    g = n_h // n_kv
    n_seq_shards = 1
    for a in seq_axes:
        n_seq_shards *= mesh.shape[a]
    l_loc = cache_len // n_seq_shards

    bspec = dp_axes if (dp_axes and b % max(
        1, __import__("math").prod(mesh.shape[a] for a in dp_axes)) == 0) \
        else None

    def local_fn(q_, k_, v_, ck, cv):
        # shard coordinate along the (possibly multi-axis) seq sharding
        idx = jnp.zeros((), jnp.int32)
        for a in seq_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        offset = idx * l_loc
        local_slot = slot - offset
        in_range = (local_slot >= 0) & (local_slot < l_loc)
        ls = jnp.clip(local_slot, 0, l_loc - 1)
        ck2 = jax.lax.dynamic_update_slice(
            ck, k_.astype(ck.dtype), (0, ls, 0, 0))
        cv2 = jax.lax.dynamic_update_slice(
            cv, v_.astype(cv.dtype), (0, ls, 0, 0))
        ck = jnp.where(in_range, ck2, ck)
        cv = jnp.where(in_range, cv2, cv)

        # absolute position per local cache slot (ring-aware)
        sidx = offset + jnp.arange(l_loc)
        if window is None:
            kpos = jnp.where(sidx <= pos, sidx, -1)
        else:
            cand = pos - (pos % cache_len) + sidx
            cand = jnp.where(cand > pos, cand - cache_len, cand)
            kpos = jnp.where(cand >= 0, cand, -1)
        valid = (kpos >= 0) & (kpos <= pos)

        # GQA via grouped einsum — never materializes repeated KV
        bl = q_.shape[0]   # local batch inside shard_map
        qg = (q_[:, 0].astype(jnp.float32) * (hd ** -0.5)) \
            .reshape(bl, n_kv, g, hd)
        kk = ck.astype(jnp.float32)
        vv = cv.astype(jnp.float32)
        s_ = jnp.einsum("bkgd,blkd->bkgl", qg, kk)
        s_ = jnp.where(valid[None, None, None, :], s_, -1e30)
        m_loc = s_.max(-1)                                  # (B,Hkv,g)
        p_ = jnp.exp(s_ - m_loc[..., None])
        l_sum = p_.sum(-1)
        acc = jnp.einsum("bkgl,blkd->bkgd", p_, vv)
        # flash-decoding combine across seq shards
        axes = tuple(seq_axes)
        m_max = jax.lax.pmax(m_loc, axes)
        corr = jnp.exp(m_loc - m_max)
        l_tot = jax.lax.psum(l_sum * corr, axes)
        acc_tot = jax.lax.psum(acc * corr[..., None], axes)
        o = (acc_tot / jnp.maximum(l_tot, 1e-30)[..., None]) \
            .reshape(bl, n_h, hd)
        return o.astype(x.dtype), ck, cv

    seq_spec = seq_axes if len(seq_axes) > 1 else seq_axes[0]
    cache_spec = P(bspec, seq_spec, None, None)
    rep_spec = P(bspec, None, None, None)
    o, ck, cv = shard_map(
        local_fn, mesh=mesh,
        in_specs=(rep_spec, rep_spec, rep_spec, cache_spec, cache_spec),
        out_specs=(P(bspec, None, None), cache_spec, cache_spec),
        check_rep=False,
    )(q, k, v, cache["k"], cache["v"])
    new_cache = {"k": ck, "v": cv, "index": pos + 1}
    return cm.linear(params["wo"], o.reshape(b, 1, n_h * hd)), new_cache


def _cache_positions(cache_len: int, pos: jnp.ndarray,
                     window: Optional[int]) -> jnp.ndarray:
    """Absolute position of each cache slot; -1 for not-yet-written slots."""
    idx = jnp.arange(cache_len)
    if window is None:
        return jnp.where(idx <= pos, idx, -1)
    # ring buffer: slot s holds position p iff p % cache_len == s and
    # pos - cache_len < p <= pos.
    cand = pos - (pos % cache_len) + idx
    cand = jnp.where(cand > pos, cand - cache_len, cand)
    return jnp.where(cand >= 0, cand, -1)


# ---------------------------------------------------------------------------
# cross attention (Whisper decoder)
# ---------------------------------------------------------------------------

def cross_attend(params: dict, x: jnp.ndarray, memory_kv: tuple, cfg
                 ) -> jnp.ndarray:
    """x (B, Sq, d); memory_kv = (k, v) each (B, Sm, Hkv, D) precomputed."""
    n_h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b, sq, _ = x.shape
    q = _split_heads(cm.linear(params["wq"], x), n_h, hd)
    k, v = memory_kv
    k = _repeat_kv(k.astype(q.dtype), n_h // n_kv)
    v = _repeat_kv(v.astype(q.dtype), n_h // n_kv)
    o = sdpa(q, k, v, None)
    return cm.linear(params["wo"], o.reshape(b, sq, n_h * hd))


def memory_kv(params: dict, mem: jnp.ndarray, cfg) -> tuple:
    """Precompute cross-attention K/V from encoder output (B, Sm, d)."""
    n_kv, hd = cfg.n_kv_heads, cfg.head_dim
    k = _split_heads(cm.linear(params["wk"], mem), n_kv, hd)
    v = _split_heads(cm.linear(params["wv"], mem), n_kv, hd)
    return (k, v)
