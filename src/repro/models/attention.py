"""Grouped-query attention with RoPE / M-RoPE, sliding windows and KV caches.

Three entry points:
  * ``attend_train``   — full-sequence causal (or bidirectional) attention.
  * ``attend_decode``  — one new token against a pre-filled KV cache.
  * ``cross_attend``   — decoder query over encoder memory (Whisper).

The jnp paths here (``sdpa``, masks, the blockwise flash in
``flash_jnp``) are the reference implementations; the Pallas kernels in
``repro.kernels`` implement the same math with explicit VMEM tiling and are
validated against these in tests.  Backend selection — which of the two
families a call lowers through, bare or shard_map'd over a mesh — lives
entirely in ``repro.kernels.dispatch``; the entry points here just forward
``backend`` (default ``"auto"``) to it.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed import ctx
from repro.kernels import dispatch, kv_quant
from repro.models import common as cm

NEG_INF = -1e30


class AttnParams(NamedTuple):
    pass  # attention params are plain dicts; NamedTuple kept for doc purposes


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, *, qkv_bias: bool = False,
                   out_bias: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": cm.init_linear(ks[0], d_model, n_heads * head_dim, bias=qkv_bias),
        "wk": cm.init_linear(ks[1], d_model, n_kv_heads * head_dim, bias=qkv_bias),
        "wv": cm.init_linear(ks[2], d_model, n_kv_heads * head_dim, bias=qkv_bias),
        "wo": cm.init_linear(ks[3], n_heads * head_dim, d_model, bias=out_bias),
    }


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim)


def _repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, Hkv, D) -> (B, S, Hkv * n_rep, D)."""
    if n_rep == 1:
        return x
    b, s, h, d = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, n_rep, d))
    return x.reshape(b, s, h * n_rep, d)


def sdpa(q, k, v, mask, *, scale: Optional[float] = None) -> jnp.ndarray:
    """q (B,Sq,H,D), k/v (B,Sk,H,D), mask broadcastable to (B,H,Sq,Sk)."""
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def causal_mask(sq: int, sk: int, *, window: Optional[int] = None,
                offset: int = 0) -> jnp.ndarray:
    """(1, 1, Sq, Sk) boolean mask.  ``offset`` = absolute position of q row 0
    minus position of k col 0.  ``window`` keeps only the last ``window`` keys
    (sliding-window / chunked-local attention)."""
    qpos = jnp.arange(sq)[:, None] + offset
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window is not None:
        m = m & (kpos > qpos - window)
    return m[None, None]


def attend_train(params: dict, x: jnp.ndarray, cos, sin, cfg,
                 *, window: Optional[int] = None, use_rope: bool = True,
                 bidirectional: bool = False,
                 backend: str = "auto") -> jnp.ndarray:
    """Full-sequence self attention.  x (B, S, d_model)."""
    n_h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(cm.linear(params["wq"], x), n_h, hd)
    k = _split_heads(cm.linear(params["wk"], x), n_kv, hd)
    v = _split_heads(cm.linear(params["wv"], x), n_kv, hd)
    if use_rope:
        rd = getattr(cfg, "rotary_dim", None)
        q = cm.apply_rope(q, cos, sin, rotary_dim=rd)
        k = cm.apply_rope(k, cos, sin, rotary_dim=rd)
    # Megatron-TP: attention is head-local on the model axis; without these
    # constraints GSPMD re-gathers K/V blocks inside the flash scan.
    q = ctx.constrain(q, "attn_q")
    k = ctx.constrain(k, "attn_kv")
    v = ctx.constrain(v, "attn_kv")
    o = dispatch.flash_attention(q, k, v, causal=not bidirectional,
                                 window=window, backend=backend)
    b, s = x.shape[:2]
    return cm.linear(params["wo"], o.reshape(b, s, n_h * hd))


# ---------------------------------------------------------------------------
# KV cache (decode path)
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, cache_len: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> dict:
    """Cache for one attention layer.  ``index`` is the next write slot; for
    ring caches (sliding window) writes wrap modulo ``cache_len``.

    ``dtype=int8`` makes the cache quantized: ``k``/``v`` store int8 rows
    and per-(row, head) f32 scales ride alongside as ``ks``/``vs``
    (batch, cache_len, Hkv, 1) — rank-matched so sharding specs and
    engine scatters treat them exactly like the payload.  Zero-init
    scales dequantize to zeros; kpos masks unwritten rows anyway."""
    cache = {
        "k": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), dtype),
        "index": jnp.zeros((), jnp.int32),
    }
    if kv_quant.is_quantized(dtype):
        cache["ks"] = jnp.zeros((batch, cache_len, n_kv_heads, 1),
                                jnp.float32)
        cache["vs"] = jnp.zeros((batch, cache_len, n_kv_heads, 1),
                                jnp.float32)
    return cache


class PagedLayout(NamedTuple):
    """Static description of a paged cache: fixed-size pages in a shared
    pool, per-slot page tables.  Page 0 is the reserved garbage sink —
    writes through unmapped table rows land there and reads mask them out
    via kpos — so allocators hand out pages 1..n_pages-1."""
    page_size: int
    n_pages: int


def init_paged_kv_cache(batch: int, cache_len: int, n_kv_heads: int,
                        head_dim: int, *, page_size: int, n_pages: int,
                        dtype=jnp.bfloat16) -> dict:
    """Paged cache for one attention layer: a shared page pool ``kp``/``vp``
    (n_pages, page_size, Hkv, D) plus a per-slot page table ``pt``
    (batch, cache_len // page_size) int32 (-1 = unmapped).  The logical
    per-slot length is exactly ``cache_len``, so ``cache_len`` must divide
    into whole pages — the dense gathered view then has the contiguous
    layout's shapes bit-for-bit."""
    if cache_len % page_size:
        raise ValueError(f"cache_len {cache_len} must be a multiple of "
                         f"page_size {page_size} (whole-page slots)")
    max_pages = cache_len // page_size
    cache = {
        "kp": jnp.zeros((n_pages, page_size, n_kv_heads, head_dim), dtype),
        "vp": jnp.zeros((n_pages, page_size, n_kv_heads, head_dim), dtype),
        "pt": jnp.full((batch, max_pages), -1, jnp.int32),
        "index": jnp.zeros((), jnp.int32),
    }
    if kv_quant.is_quantized(dtype):
        # scale pools ride the page pool: same leading (page, offset) dims,
        # so page COW / refcount / sharding logic applies verbatim
        cache["kps"] = jnp.zeros((n_pages, page_size, n_kv_heads, 1),
                                 jnp.float32)
        cache["vps"] = jnp.zeros((n_pages, page_size, n_kv_heads, 1),
                                 jnp.float32)
    return cache


def _decode_cp_rule(cache_len: int) -> Optional[dict]:
    """The active ``decode_cp`` rule when it actually owns this cache's
    sequence dim (divisible into one slice per shard), else None."""
    cp = (ctx.current_rules() or {}).get("decode_cp")
    if cp is None:
        return None
    n = cp["n_shards"]
    if cache_len % n != 0 or cache_len < n:
        return None
    return cp


def _update_kv_cache_cp(cache: dict, k, v, slot, cp, ks=None, vs=None
                        ) -> tuple:
    """Write each row's new K/V on the owning sequence shard only.

    The cache's sequence dim is sharded over ``cp['seq_axes']``; a plain
    dynamic_update_slice would make GSPMD re-gather the multi-GB cache, so
    the write is a predicated update inside shard_map — each shard updates
    its slice iff the row's slot falls in its range.  ``slot`` is per batch
    row (B,) (continuous batching) or a lockstep scalar.  (The attention
    over the updated cache then routes through ``dispatch.decode_attention``,
    which resolves the matching ``pallas_cp`` combine.)

    Quantized caches pass the already-quantized rows plus their scales
    (``ks``/``vs`` (B, 1, Hkv, 1)); the rank-matched scale leaves take the
    exact same predicated write.  Returns (ck, cv) or (ck, cv, cks, cvs).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import decode_cp_spec

    # same layout spec the dispatch combine uses — the write and the
    # attention must agree on the cache's partitioning
    b = k.shape[0]
    spec = decode_cp_spec(cp, batch=b)
    mesh, seq_axes = spec.mesh, spec.seq_axes
    cache_len = cache["k"].shape[1]
    l_loc = cache_len // cp["n_shards"]
    slot = jnp.broadcast_to(jnp.asarray(slot), (b,))
    if ks is None:
        new_rows = (k, v)
        leaves = (cache["k"], cache["v"])
    else:
        new_rows = (k, v, ks, vs)
        leaves = (cache["k"], cache["v"], cache["ks"], cache["vs"])
    n = len(leaves)

    def write(slot_, *args):
        new, old = args[:n], args[n:]
        # shard coordinate along the (possibly multi-axis) seq sharding
        idx = jnp.zeros((), jnp.int32)
        for a in seq_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        local_slot = slot_ - idx * l_loc               # (B_loc,)
        in_range = (local_slot >= 0) & (local_slot < l_loc)
        ls = jnp.clip(local_slot, 0, l_loc - 1)
        rows = jnp.arange(old[0].shape[0])
        sel = in_range[:, None, None]                  # vs (B_loc, Hkv, D)
        return tuple(
            od.at[rows, ls].set(
                jnp.where(sel, nw[:, 0].astype(od.dtype), od[rows, ls]))
            for nw, od in zip(new, old))

    return shard_map(write, mesh=mesh,
                     in_specs=(P(spec.batch),) + (spec.new_kv,) * n +
                              (spec.kv,) * n,
                     out_specs=(spec.kv,) * n,
                     check_rep=False)(slot, *new_rows, *leaves)


def attend_decode(params: dict, x: jnp.ndarray, cache: dict, pos: jnp.ndarray,
                  cfg, *, window: Optional[int] = None, use_rope: bool = True,
                  backend: str = "auto"):
    """One-token decode.  x (B, 1, d_model); pos — absolute position, either
    a lockstep scalar () or per-slot (B,) (continuous batching: every batch
    row decodes at its own depth; writes, RoPE and the validity mask are all
    per row).

    Returns (out (B, 1, d_model), new_cache).  When ``window`` is set the
    cache is a ring buffer of length == window (sub-linear memory for
    long-context decode); otherwise cache_len == max seq and slot == pos.

    One entry point serves both cache layouts: when the ``decode_cp`` rules
    own the cache's sequence dim, the cache write is a predicated
    shard_map'd update on the owning shard and ``dispatch.decode_attention``
    resolves to the ``pallas_cp`` flash-decoding combine; otherwise the
    write is a plain (per-row) update and dispatch shard_maps over
    (batch, heads) / runs the bare kernel.
    """
    n_h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b = x.shape[0]
    per_slot = jnp.ndim(pos) == 1
    q = _split_heads(cm.linear(params["wq"], x), n_h, hd)
    k = _split_heads(cm.linear(params["wk"], x), n_kv, hd)
    v = _split_heads(cm.linear(params["wv"], x), n_kv, hd)
    if use_rope:
        qpos = pos[:, None] if per_slot else pos[None, None]
        cos, sin = cm.rope_cos_sin(qpos, hd, cfg.rope_theta)
        rd = getattr(cfg, "rotary_dim", None)
        q = cm.apply_rope(q, cos, sin, rotary_dim=rd)
        k = cm.apply_rope(k, cos, sin, rotary_dim=rd)

    if "kp" in cache:
        # paged layout: write through the page table, read through the
        # page-gathered dispatch arm.  Linear caches only — a rotating
        # window has no reusable prefix, so ring layers stay contiguous.
        if window is not None:
            raise ValueError("paged KV caches do not support sliding "
                             "windows; keep ring layers contiguous")
        quant = "kps" in cache
        ps = cache["kp"].shape[1]
        cache_len = cache["pt"].shape[1] * ps
        pt = cache["pt"]
        pidx = pos // ps
        off = pos % ps
        if per_slot:
            page = pt[jnp.arange(b), pidx]             # (B,)
        else:
            page = pt[:, pidx]                         # (B,) scalar col
        # unmapped rows write the page-0 garbage sink; kpos masks them
        page_w = jnp.maximum(page, 0)
        if quant:
            # quantize-on-write: each new row lands as int8 + its own
            # per-(row, head) scale, so no existing page row is rescanned
            k, k_sc = kv_quant.quantize(k)             # (B,1,Hkv,{D,1})
            v, v_sc = kv_quant.quantize(v)
        kp = cache["kp"].at[page_w, off].set(k[:, 0].astype(cache["kp"].dtype))
        vp = cache["vp"].at[page_w, off].set(v[:, 0].astype(cache["vp"].dtype))
        new_cache = {"kp": kp, "vp": vp, "pt": pt,
                     "index": jnp.max(pos) + 1}
        kps = vps = None
        if quant:
            kps = cache["kps"].at[page_w, off].set(k_sc[:, 0])
            vps = cache["vps"].at[page_w, off].set(v_sc[:, 0])
            new_cache["kps"], new_cache["vps"] = kps, vps
        o = dispatch.decode_attention_paged(q[:, 0], kp, vp, pt, pos,
                                            length=cache_len,
                                            k_scale=kps, v_scale=vps,
                                            backend=backend)[:, None]
        return cm.linear(params["wo"], o.reshape(b, 1, n_h * hd)), new_cache

    quant = "ks" in cache
    if quant:
        k, k_sc = kv_quant.quantize(k)                 # (B,1,Hkv,{D,1})
        v, v_sc = kv_quant.quantize(v)
    cache_len = cache["k"].shape[1]
    # full cache: slot == pos (pos < cache_len); ring cache: wrap around.
    slot = pos % cache_len
    cp = _decode_cp_rule(cache_len)
    cks = cvs = None
    if cp is not None:
        if quant:
            ck, cv, cks, cvs = _update_kv_cache_cp(cache, k, v, slot, cp,
                                                   ks=k_sc, vs=v_sc)
        else:
            ck, cv = _update_kv_cache_cp(cache, k, v, slot, cp)
    elif per_slot:
        rows = jnp.arange(b)
        ck = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))
        if quant:
            cks = cache["ks"].at[rows, slot].set(k_sc[:, 0])
            cvs = cache["vs"].at[rows, slot].set(v_sc[:, 0])
    else:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        if quant:
            cks = jax.lax.dynamic_update_slice(
                cache["ks"], k_sc, (0, slot, 0, 0))
            cvs = jax.lax.dynamic_update_slice(
                cache["vs"], v_sc, (0, slot, 0, 0))
    new_cache = {"k": ck, "v": cv, "index": jnp.max(pos) + 1}
    if quant:
        new_cache["ks"], new_cache["vs"] = cks, cvs

    kpos = _cache_positions(cache_len, pos, window)
    o = dispatch.decode_attention(q[:, 0], ck, cv, kpos, pos,
                                  k_scale=cks, v_scale=cvs,
                                  backend=backend)[:, None]
    return cm.linear(params["wo"], o.reshape(b, 1, n_h * hd)), new_cache


def _cache_positions(cache_len: int, pos: jnp.ndarray,
                     window: Optional[int]) -> jnp.ndarray:
    """Absolute position of each cache slot; -1 for not-yet-written slots.
    pos () -> (L,); per-slot pos (B,) -> (B, L)."""
    idx = jnp.arange(cache_len)
    if jnp.ndim(pos) == 1:
        pos = pos[:, None]                             # (B, 1) vs (L,)
    if window is None:
        return jnp.where(idx <= pos, idx, -1)
    # ring buffer: slot s holds position p iff p % cache_len == s and
    # pos - cache_len < p <= pos.
    cand = pos - (pos % cache_len) + idx
    cand = jnp.where(cand > pos, cand - cache_len, cand)
    return jnp.where(cand >= 0, cand, -1)


# ---------------------------------------------------------------------------
# chunked flash prefill
# ---------------------------------------------------------------------------

def attend_prefill(params: dict, x: jnp.ndarray, cache: dict, pos0: int,
                   cfg, *, window: Optional[int] = None,
                   use_rope: bool = True, backend: str = "auto",
                   true_len: Optional[jnp.ndarray] = None):
    """Prefill one prompt chunk.  x (B, C, d_model) covers absolute positions
    [pos0, pos0 + C) — the same positions for every row (prompts are
    right-padded to a common length; ``true_len`` (B,) optionally carries
    each row's real prompt length so ring writes can mask padding, and the
    caller's logit gather / per-slot decode handle the rest).

    Writes the chunk's K/V into cache rows [pos0, pos0 + C) (ring wrap for
    window caches) and returns (out (B, C, d_model), new_cache).  ``pos0``
    is a static python int.  Every chunk — first and later alike — runs
    one ``dispatch.flash_attention_append`` call: the chunk's queries at
    absolute positions [pos0, pos0 + C) attend the key stream
    (cache prefix + the chunk's own K/V) under the kernel's q-offset grid,
    with ring caches passing the same per-row kpos validity the decode
    kernel uses.  There is no masked-sdpa prefix branch; unaligned smoke
    shapes fall back to the jnp append oracle inside dispatch.
    """
    n_h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b, c, _ = x.shape
    q = _split_heads(cm.linear(params["wq"], x), n_h, hd)
    k = _split_heads(cm.linear(params["wk"], x), n_kv, hd)
    v = _split_heads(cm.linear(params["wv"], x), n_kv, hd)
    if use_rope:
        positions = pos0 + jnp.arange(c)[None]         # (1, C)
        cos, sin = cm.rope_cos_sin(positions, hd, cfg.rope_theta)
        rd = getattr(cfg, "rotary_dim", None)
        q = cm.apply_rope(q, cos, sin, rotary_dim=rd)
        k = cm.apply_rope(k, cos, sin, rotary_dim=rd)

    if "kp" in cache:
        if window is not None:
            raise ValueError("paged KV caches do not support sliding "
                             "windows; keep ring layers contiguous")
        quant = "kps" in cache
        ps = cache["kp"].shape[1]
        cache_len = cache["pt"].shape[1] * ps
        if pos0 + c > cache_len:
            raise ValueError(
                f"prefill chunk [{pos0}, {pos0 + c}) overflows the "
                f"{cache_len}-slot paged cache; chunk the prompt to fit")
        pt = cache["pt"]
        positions = pos0 + jnp.arange(c)               # (C,)
        pi = positions // ps
        offs = positions % ps
        pages = pt[:, pi]                              # (B, C)
        end = jnp.full((b,), pos0 + c, jnp.int32) if true_len is None \
            else jnp.minimum(pos0 + c, true_len.astype(jnp.int32))
        # mask BOTH unmapped pages and padding positions >= true_len: a
        # right-padded row must not clobber a shared page another slot's
        # real tokens (or decode output) live in — invalid writes land in
        # the page-0 sink instead
        valid = (positions[None, :] < end[:, None]) & (pages > 0)
        page_w = jnp.where(valid, pages, 0)
        k_sc = v_sc = None
        if quant:
            # quantize once; the same bytes land in the pool AND feed this
            # chunk's attention, so prefill and later decode reads see
            # identical dequantized values
            k, k_sc = kv_quant.quantize(k)             # (B,C,Hkv,{D,1})
            v, v_sc = kv_quant.quantize(v)
        kp = cache["kp"].at[page_w, offs[None, :]].set(
            k.astype(cache["kp"].dtype))
        vp = cache["vp"].at[page_w, offs[None, :]].set(
            v.astype(cache["vp"].dtype))
        new_cache = {"kp": kp, "vp": vp, "pt": pt,
                     "index": jnp.asarray(pos0 + c, jnp.int32)}
        kps = vps = None
        if quant:
            kps = cache["kps"].at[page_w, offs[None, :]].set(k_sc)
            vps = cache["vps"].at[page_w, offs[None, :]].set(v_sc)
            new_cache["kps"], new_cache["vps"] = kps, vps
        # key stream: the PRE-write pool holds the prefix [0, pos0) —
        # the chunk's own K/V ride alongside as dense tensors
        o = dispatch.flash_attention_append_paged(
            q, cache["kp"], cache["vp"], pt, k, v, pos0=pos0,
            k_scale=cache.get("kps"), v_scale=cache.get("vps"),
            ks_chunk=k_sc, vs_chunk=v_sc,
            backend=backend)
        return cm.linear(params["wo"], o.reshape(b, c, n_h * hd)), new_cache

    quant = "ks" in cache
    k_sc = v_sc = None
    if quant:
        # quantize the chunk once: the cache write and this chunk's own
        # key stream use the same int8 bytes + scales, so prefill
        # attention matches what decode later reads back
        k, k_sc = kv_quant.quantize(k)                 # (B,C,Hkv,{D,1})
        v, v_sc = kv_quant.quantize(v)
    cache_len = cache["k"].shape[1]
    cks = cvs = None
    if window is None:
        if pos0 + c > cache_len:
            # a full cache has no wrap semantics: writing past the end
            # would clobber real prompt rows that kpos still reports as
            # valid — loud trace-time failure, the caller must size its
            # chunk grid to the cache (serve._chunk_grid)
            raise ValueError(
                f"prefill chunk [{pos0}, {pos0 + c}) overflows the "
                f"{cache_len}-slot full cache; chunk the prompt to fit")
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, pos0, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, pos0, 0, 0))
        if quant:
            cks = jax.lax.dynamic_update_slice(
                cache["ks"], k_sc, (0, pos0, 0, 0))
            cvs = jax.lax.dynamic_update_slice(
                cache["vs"], v_sc, (0, pos0, 0, 0))
    else:
        # ring cache: slot s must end up holding the LAST written position
        # p ≡ s (mod cache_len) with pos0 <= p < end[row].  Computed as a
        # per-slot gather instead of a scatter, which (a) has no duplicate
        # -index ordering hazard when C > cache_len and (b) takes a
        # per-row ``end`` — rows shorter than the padded chunk grid
        # (true_len) simply stop writing at their real prompt length, so
        # right-padded admission chunks can no longer alias ring rows that
        # kpos attributes to real earlier positions.
        end = jnp.full((b,), pos0 + c, jnp.int32) if true_len is None \
            else jnp.minimum(pos0 + c, true_len.astype(jnp.int32))
        idx = jnp.arange(cache_len)
        last = end[:, None] - 1                              # (B, 1)
        p_cand = last - ((last - idx[None, :]) % cache_len)  # (B, L)
        valid = p_cand >= pos0
        sel = jnp.clip(p_cand - pos0, 0, c - 1)
        gk = jnp.take_along_axis(k.astype(cache["k"].dtype),
                                 sel[:, :, None, None], axis=1)
        gv = jnp.take_along_axis(v.astype(cache["v"].dtype),
                                 sel[:, :, None, None], axis=1)
        ck = jnp.where(valid[:, :, None, None], gk, cache["k"])
        cv = jnp.where(valid[:, :, None, None], gv, cache["v"])
        if quant:
            gks = jnp.take_along_axis(k_sc, sel[:, :, None, None], axis=1)
            gvs = jnp.take_along_axis(v_sc, sel[:, :, None, None], axis=1)
            cks = jnp.where(valid[:, :, None, None], gks, cache["ks"])
            cvs = jnp.where(valid[:, :, None, None], gvs, cache["vs"])
    # strong int32: a weak-typed scalar here would retrace the decode step
    # that consumes this cache
    new_cache = {"k": ck, "v": cv, "index": jnp.asarray(pos0 + c, jnp.int32)}
    if quant:
        new_cache["ks"], new_cache["vs"] = cks, cvs

    # key stream for the append call: the pre-chunk cache prefix (rows a
    # ring write above may have evicted are only positions no chunk query
    # can still see) plus the chunk's own K/V from this projection
    ks_all = vs_all = None
    if pos0 == 0:
        k_all, v_all = k, v
        ks_all, vs_all = k_sc, v_sc
        kpos_all = jnp.arange(c)
        linear = True
    elif window is None:
        cast = (lambda x: x) if quant else (lambda x: x.astype(q.dtype))
        k_all = jnp.concatenate([cast(cache["k"][:, :pos0]), k], axis=1)
        v_all = jnp.concatenate([cast(cache["v"][:, :pos0]), v], axis=1)
        if quant:
            ks_all = jnp.concatenate([cache["ks"][:, :pos0], k_sc], axis=1)
            vs_all = jnp.concatenate([cache["vs"][:, :pos0], v_sc], axis=1)
        kpos_all = jnp.arange(pos0 + c)
        linear = True
    else:
        cast = (lambda x: x) if quant else (lambda x: x.astype(q.dtype))
        k_all = jnp.concatenate([cast(cache["k"]), k], axis=1)
        v_all = jnp.concatenate([cast(cache["v"]), v], axis=1)
        if quant:
            ks_all = jnp.concatenate([cache["ks"], k_sc], axis=1)
            vs_all = jnp.concatenate([cache["vs"], v_sc], axis=1)
        kpos_pre = _cache_positions(cache_len, jnp.asarray(pos0 - 1),
                                    window)
        kpos_all = jnp.concatenate([kpos_pre, pos0 + jnp.arange(c)])
        linear = False
    o = dispatch.flash_attention_append(q, k_all, v_all, kpos_all,
                                        pos0=pos0, window=window,
                                        kpos_linear=linear,
                                        k_scale=ks_all, v_scale=vs_all,
                                        backend=backend)
    return cm.linear(params["wo"], o.reshape(b, c, n_h * hd)), new_cache


# ---------------------------------------------------------------------------
# speculative verify + deferred commit
# ---------------------------------------------------------------------------

def attend_verify(params: dict, x: jnp.ndarray, cache: dict,
                  pos: jnp.ndarray, cfg, *, shift: int,
                  window: Optional[int] = None, use_rope: bool = True,
                  backend: str = "auto"):
    """Score a K-token draft chunk per slot WITHOUT touching the cache.

    x (B, K, d_model) — row j's drafted tokens at absolute positions
    ``pos[j] + i`` (per-slot depths; rows whose real draft is shorter
    than K carry pad tokens — pad keys sit at positions the causal mask
    already hides from every valid query, and pad-query outputs are
    discarded by the caller).  ``shift`` is a static upper bound on
    ``pos`` (the engine's logical cache length) for the dispatch
    re-basing trick.

    Returns (out (B, K, d_model), pending) where ``pending`` holds the
    chunk's K/V rows (already quantized for int8 caches — the exact
    bytes ``commit_kv`` writes) so acceptance can commit 1..K rows
    *after* the host-side accept decision.  Because nothing is written
    here, KV rollback on rejection is a no-op by construction; only the
    page table (engine side) carries speculative state."""
    n_h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b, kq, _ = x.shape
    q = _split_heads(cm.linear(params["wq"], x), n_h, hd)
    k = _split_heads(cm.linear(params["wk"], x), n_kv, hd)
    v = _split_heads(cm.linear(params["wv"], x), n_kv, hd)
    if use_rope:
        positions = pos[:, None] + jnp.arange(kq)[None]  # (B, K) true qpos
        cos, sin = cm.rope_cos_sin(positions, hd, cfg.rope_theta)
        rd = getattr(cfg, "rotary_dim", None)
        q = cm.apply_rope(q, cos, sin, rotary_dim=rd)
        k = cm.apply_rope(k, cos, sin, rotary_dim=rd)

    if "kp" in cache:
        if window is not None:
            raise ValueError("paged KV caches do not support sliding "
                             "windows; keep ring layers contiguous")
        quant = "kps" in cache
        ps = cache["kp"].shape[1]
        cache_len = cache["pt"].shape[1] * ps
        k_sc = v_sc = None
        if quant:
            # quantize once: these bytes feed the verify attention AND are
            # what commit_kv later writes, so verify logits match
            # post-commit decode reads exactly
            k, k_sc = kv_quant.quantize(k)               # (B,K,Hkv,{D,1})
            v, v_sc = kv_quant.quantize(v)
        o = dispatch.flash_attention_verify_paged(
            q, cache["kp"], cache["vp"], cache["pt"], k, v, pos=pos,
            length=cache_len, k_scale=cache.get("kps"),
            v_scale=cache.get("vps"), ks_chunk=k_sc, vs_chunk=v_sc,
            backend=backend)
        pending = {"k": k, "v": v}
        if quant:
            pending["ks"], pending["vs"] = k_sc, v_sc
        return cm.linear(params["wo"], o.reshape(b, kq, n_h * hd)), pending

    quant = "ks" in cache
    k_sc = v_sc = None
    if quant:
        k, k_sc = kv_quant.quantize(k)
        v, v_sc = kv_quant.quantize(v)
    cache_len = cache["k"].shape[1]
    cast = (lambda t: t) if quant else (lambda t: t.astype(q.dtype))
    # key stream: the whole (pre-write) cache + the chunk's own K/V.  The
    # prefix kpos masks everything at or past each row's pos — cache rows
    # there are stale (verify never wrote them) — and the chunk rows carry
    # their true absolute positions.  The decode convention (`pos` = the
    # row currently being processed) means committed rows end at pos - 1.
    k_all = jnp.concatenate([cast(cache["k"]), k], axis=1)
    v_all = jnp.concatenate([cast(cache["v"]), v], axis=1)
    ks_all = vs_all = None
    if quant:
        ks_all = jnp.concatenate([cache["ks"], k_sc], axis=1)
        vs_all = jnp.concatenate([cache["vs"], v_sc], axis=1)
    kpos_pre = _cache_positions(cache_len, pos - 1, window)    # (B, L)
    kpos_all = jnp.concatenate(
        [kpos_pre, pos[:, None] + jnp.arange(kq)[None]], axis=1)
    o = dispatch.flash_attention_verify(q, k_all, v_all, kpos_all,
                                        pos=pos, shift=shift,
                                        window=window, k_scale=ks_all,
                                        v_scale=vs_all, backend=backend)
    pending = {"k": k, "v": v}
    if quant:
        pending["ks"], pending["vs"] = k_sc, v_sc
    return cm.linear(params["wo"], o.reshape(b, kq, n_h * hd)), pending


def commit_kv(cache: dict, pending: dict, pos: jnp.ndarray,
              n_acc: jnp.ndarray, *, window: Optional[int] = None) -> dict:
    """Scatter the accepted prefix of a verify chunk into the cache.

    ``pending`` is ``attend_verify``'s per-layer chunk K/V (B,K,...);
    row j commits rows i < n_acc[j] at positions pos[j] + i (ring wrap
    for window caches, page-table indirection for paged).  Rejected and
    pad rows write nowhere: masked paged writes land in the page-0
    garbage sink, masked contiguous writes rewrite the row's current
    value.  K is small and static, so this unrolls to K scatters."""
    b, kq = pending["k"].shape[0], pending["k"].shape[1]
    rows = jnp.arange(b)
    if "kp" in cache:
        quant = "kps" in cache
        ps = cache["kp"].shape[1]
        m = cache["pt"].shape[1]
        pt = cache["pt"]
        kp, vp = cache["kp"], cache["vp"]
        kps, vps = cache.get("kps"), cache.get("vps")
        for i in range(kq):
            p = pos + i
            pidx = jnp.minimum(p // ps, m - 1)
            off = p % ps
            page = pt[rows, pidx]
            ok = (i < n_acc) & (page > 0)
            page_w = jnp.where(ok, page, 0)
            kp = kp.at[page_w, off].set(pending["k"][:, i].astype(kp.dtype))
            vp = vp.at[page_w, off].set(pending["v"][:, i].astype(vp.dtype))
            if quant:
                kps = kps.at[page_w, off].set(pending["ks"][:, i])
                vps = vps.at[page_w, off].set(pending["vs"][:, i])
        new_cache = {"kp": kp, "vp": vp, "pt": pt,
                     "index": jnp.max(pos + n_acc).astype(jnp.int32)}
        if quant:
            new_cache["kps"], new_cache["vps"] = kps, vps
        return new_cache

    quant = "ks" in cache
    cache_len = cache["k"].shape[1]
    ck, cv = cache["k"], cache["v"]
    cks, cvs = cache.get("ks"), cache.get("vs")
    for i in range(kq):
        p = pos + i
        if window is not None:
            slot = p % cache_len
        else:
            # masked rows may sit past the cache end; clamp the index and
            # let the where() below rewrite the current value harmlessly
            slot = jnp.minimum(p, cache_len - 1)
        sel = (i < n_acc)[:, None, None]
        ck = ck.at[rows, slot].set(
            jnp.where(sel, pending["k"][:, i].astype(ck.dtype),
                      ck[rows, slot]))
        cv = cv.at[rows, slot].set(
            jnp.where(sel, pending["v"][:, i].astype(cv.dtype),
                      cv[rows, slot]))
        if quant:
            cks = cks.at[rows, slot].set(
                jnp.where(sel, pending["ks"][:, i], cks[rows, slot]))
            cvs = cvs.at[rows, slot].set(
                jnp.where(sel, pending["vs"][:, i], cvs[rows, slot]))
    new_cache = {"k": ck, "v": cv,
                 "index": jnp.max(pos + n_acc).astype(jnp.int32)}
    if quant:
        new_cache["ks"], new_cache["vs"] = cks, cvs
    return new_cache


# ---------------------------------------------------------------------------
# cross attention (Whisper decoder)
# ---------------------------------------------------------------------------

def cross_attend(params: dict, x: jnp.ndarray, memory_kv: tuple, cfg
                 ) -> jnp.ndarray:
    """x (B, Sq, d); memory_kv = (k, v) each (B, Sm, Hkv, D) precomputed."""
    n_h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b, sq, _ = x.shape
    q = _split_heads(cm.linear(params["wq"], x), n_h, hd)
    k, v = memory_kv
    k = _repeat_kv(k.astype(q.dtype), n_h // n_kv)
    v = _repeat_kv(v.astype(q.dtype), n_h // n_kv)
    o = sdpa(q, k, v, None)
    return cm.linear(params["wo"], o.reshape(b, sq, n_h * hd))


def memory_kv(params: dict, mem: jnp.ndarray, cfg) -> tuple:
    """Precompute cross-attention K/V from encoder output (B, Sm, d)."""
    n_kv, hd = cfg.n_kv_heads, cfg.head_dim
    k = _split_heads(cm.linear(params["wk"], mem), n_kv, hd)
    v = _split_heads(cm.linear(params["wv"], mem), n_kv, hd)
    return (k, v)
