"""Grouped-query attention with RoPE / M-RoPE, sliding windows and KV caches.

Three entry points:
  * ``attend_train``   — full-sequence causal (or bidirectional) attention.
  * ``attend_decode``  — one new token against a pre-filled KV cache.
  * ``cross_attend``   — decoder query over encoder memory (Whisper).

The jnp paths here (``sdpa``, masks, the blockwise flash in
``flash_jnp``) are the reference implementations; the Pallas kernels in
``repro.kernels`` implement the same math with explicit VMEM tiling and are
validated against these in tests.  Backend selection — which of the two
families a call lowers through, bare or shard_map'd over a mesh — lives
entirely in ``repro.kernels.dispatch``; the entry points here just forward
``backend`` (default ``"auto"``) to it.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed import ctx
from repro.kernels import dispatch
from repro.models import common as cm

NEG_INF = -1e30


class AttnParams(NamedTuple):
    pass  # attention params are plain dicts; NamedTuple kept for doc purposes


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, *, qkv_bias: bool = False,
                   out_bias: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": cm.init_linear(ks[0], d_model, n_heads * head_dim, bias=qkv_bias),
        "wk": cm.init_linear(ks[1], d_model, n_kv_heads * head_dim, bias=qkv_bias),
        "wv": cm.init_linear(ks[2], d_model, n_kv_heads * head_dim, bias=qkv_bias),
        "wo": cm.init_linear(ks[3], n_heads * head_dim, d_model, bias=out_bias),
    }


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim)


def _repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, Hkv, D) -> (B, S, Hkv * n_rep, D)."""
    if n_rep == 1:
        return x
    b, s, h, d = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, n_rep, d))
    return x.reshape(b, s, h * n_rep, d)


def sdpa(q, k, v, mask, *, scale: Optional[float] = None) -> jnp.ndarray:
    """q (B,Sq,H,D), k/v (B,Sk,H,D), mask broadcastable to (B,H,Sq,Sk)."""
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def causal_mask(sq: int, sk: int, *, window: Optional[int] = None,
                offset: int = 0) -> jnp.ndarray:
    """(1, 1, Sq, Sk) boolean mask.  ``offset`` = absolute position of q row 0
    minus position of k col 0.  ``window`` keeps only the last ``window`` keys
    (sliding-window / chunked-local attention)."""
    qpos = jnp.arange(sq)[:, None] + offset
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window is not None:
        m = m & (kpos > qpos - window)
    return m[None, None]


def attend_train(params: dict, x: jnp.ndarray, cos, sin, cfg,
                 *, window: Optional[int] = None, use_rope: bool = True,
                 bidirectional: bool = False,
                 backend: str = "auto") -> jnp.ndarray:
    """Full-sequence self attention.  x (B, S, d_model)."""
    n_h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(cm.linear(params["wq"], x), n_h, hd)
    k = _split_heads(cm.linear(params["wk"], x), n_kv, hd)
    v = _split_heads(cm.linear(params["wv"], x), n_kv, hd)
    if use_rope:
        rd = getattr(cfg, "rotary_dim", None)
        q = cm.apply_rope(q, cos, sin, rotary_dim=rd)
        k = cm.apply_rope(k, cos, sin, rotary_dim=rd)
    # Megatron-TP: attention is head-local on the model axis; without these
    # constraints GSPMD re-gathers K/V blocks inside the flash scan.
    q = ctx.constrain(q, "attn_q")
    k = ctx.constrain(k, "attn_kv")
    v = ctx.constrain(v, "attn_kv")
    o = dispatch.flash_attention(q, k, v, causal=not bidirectional,
                                 window=window, backend=backend)
    b, s = x.shape[:2]
    return cm.linear(params["wo"], o.reshape(b, s, n_h * hd))


# ---------------------------------------------------------------------------
# KV cache (decode path)
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, cache_len: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> dict:
    """Cache for one attention layer.  ``index`` is the next write slot; for
    ring caches (sliding window) writes wrap modulo ``cache_len``."""
    return {
        "k": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def _decode_cp_rule(cache_len: int) -> Optional[dict]:
    """The active ``decode_cp`` rule when it actually owns this cache's
    sequence dim (divisible into one slice per shard), else None."""
    cp = (ctx.current_rules() or {}).get("decode_cp")
    if cp is None:
        return None
    n = cp["n_shards"]
    if cache_len % n != 0 or cache_len < n:
        return None
    return cp


def _update_kv_cache_cp(cache: dict, k, v, slot, cp) -> tuple:
    """Write the new token's K/V on the owning sequence shard only.

    The cache's sequence dim is sharded over ``cp['seq_axes']``; a plain
    dynamic_update_slice would make GSPMD re-gather the multi-GB cache, so
    the write is a predicated dynamic_update_slice inside shard_map — each
    shard updates its slice iff the slot falls in its range.  (The attention
    over the updated cache then routes through ``dispatch.decode_attention``,
    which resolves the matching ``pallas_cp`` combine.)
    """
    from jax.experimental.shard_map import shard_map

    from repro.distributed.sharding import decode_cp_spec

    # same layout spec the dispatch combine uses — the write and the
    # attention must agree on the cache's partitioning
    spec = decode_cp_spec(cp, batch=k.shape[0])
    mesh, seq_axes = spec.mesh, spec.seq_axes
    cache_len = cache["k"].shape[1]
    l_loc = cache_len // cp["n_shards"]

    def write(k_, v_, ck, cv):
        # shard coordinate along the (possibly multi-axis) seq sharding
        idx = jnp.zeros((), jnp.int32)
        for a in seq_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        local_slot = slot - idx * l_loc
        in_range = (local_slot >= 0) & (local_slot < l_loc)
        ls = jnp.clip(local_slot, 0, l_loc - 1)
        ck2 = jax.lax.dynamic_update_slice(
            ck, k_.astype(ck.dtype), (0, ls, 0, 0))
        cv2 = jax.lax.dynamic_update_slice(
            cv, v_.astype(cv.dtype), (0, ls, 0, 0))
        return jnp.where(in_range, ck2, ck), jnp.where(in_range, cv2, cv)

    return shard_map(write, mesh=mesh,
                     in_specs=(spec.new_kv, spec.new_kv, spec.kv, spec.kv),
                     out_specs=(spec.kv, spec.kv),
                     check_rep=False)(k, v, cache["k"], cache["v"])


def attend_decode(params: dict, x: jnp.ndarray, cache: dict, pos: jnp.ndarray,
                  cfg, *, window: Optional[int] = None, use_rope: bool = True,
                  backend: str = "auto"):
    """One-token decode.  x (B, 1, d_model); pos () absolute position.

    Returns (out (B, 1, d_model), new_cache).  When ``window`` is set the
    cache is a ring buffer of length == window (sub-linear memory for
    long-context decode); otherwise cache_len == max seq and slot == pos.

    One entry point serves both cache layouts: when the ``decode_cp`` rules
    own the cache's sequence dim, the cache write is a predicated
    shard_map'd update on the owning shard and ``dispatch.decode_attention``
    resolves to the ``pallas_cp`` flash-decoding combine; otherwise the
    write is a plain dynamic_update_slice and dispatch shard_maps over
    (batch, heads) / runs the bare kernel.
    """
    n_h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b = x.shape[0]
    q = _split_heads(cm.linear(params["wq"], x), n_h, hd)
    k = _split_heads(cm.linear(params["wk"], x), n_kv, hd)
    v = _split_heads(cm.linear(params["wv"], x), n_kv, hd)
    if use_rope:
        cos, sin = cm.rope_cos_sin(pos[None, None], hd, cfg.rope_theta)
        rd = getattr(cfg, "rotary_dim", None)
        q = cm.apply_rope(q, cos, sin, rotary_dim=rd)
        k = cm.apply_rope(k, cos, sin, rotary_dim=rd)

    cache_len = cache["k"].shape[1]
    # full cache: slot == pos (pos < cache_len); ring cache: wrap around.
    slot = pos % cache_len
    cp = _decode_cp_rule(cache_len)
    if cp is not None:
        ck, cv = _update_kv_cache_cp(cache, k, v, slot, cp)
    else:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    new_cache = {"k": ck, "v": cv, "index": pos + 1}

    kpos = _cache_positions(cache_len, pos, window)
    o = dispatch.decode_attention(q[:, 0], ck, cv, kpos, pos,
                                  backend=backend)[:, None]
    return cm.linear(params["wo"], o.reshape(b, 1, n_h * hd)), new_cache


def _cache_positions(cache_len: int, pos: jnp.ndarray,
                     window: Optional[int]) -> jnp.ndarray:
    """Absolute position of each cache slot; -1 for not-yet-written slots."""
    idx = jnp.arange(cache_len)
    if window is None:
        return jnp.where(idx <= pos, idx, -1)
    # ring buffer: slot s holds position p iff p % cache_len == s and
    # pos - cache_len < p <= pos.
    cand = pos - (pos % cache_len) + idx
    cand = jnp.where(cand > pos, cand - cache_len, cand)
    return jnp.where(cand >= 0, cand, -1)


# ---------------------------------------------------------------------------
# cross attention (Whisper decoder)
# ---------------------------------------------------------------------------

def cross_attend(params: dict, x: jnp.ndarray, memory_kv: tuple, cfg
                 ) -> jnp.ndarray:
    """x (B, Sq, d); memory_kv = (k, v) each (B, Sm, Hkv, D) precomputed."""
    n_h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b, sq, _ = x.shape
    q = _split_heads(cm.linear(params["wq"], x), n_h, hd)
    k, v = memory_kv
    k = _repeat_kv(k.astype(q.dtype), n_h // n_kv)
    v = _repeat_kv(v.astype(q.dtype), n_h // n_kv)
    o = sdpa(q, k, v, None)
    return cm.linear(params["wo"], o.reshape(b, sq, n_h * hd))


def memory_kv(params: dict, mem: jnp.ndarray, cfg) -> tuple:
    """Precompute cross-attention K/V from encoder output (B, Sm, d)."""
    n_kv, hd = cfg.n_kv_heads, cfg.head_dim
    k = _split_heads(cm.linear(params["wk"], mem), n_kv, hd)
    v = _split_heads(cm.linear(params["wv"], mem), n_kv, hd)
    return (k, v)
