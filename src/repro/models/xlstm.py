"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory with true recurrence), per Beck et al. 2024 (arXiv:2405.04517).

TPU adaptation: the mLSTM training pass uses the same chunkwise decomposition
as our Mamba2 SSD path — exponential gating with a running stabilizer maps to
log-space decays, the within-chunk part is MXU einsums, the cross-chunk part
is a short ``lax.scan`` over (C, n, m) chunk states.  sLSTM has a genuine
step-to-step nonlinearity (recurrent R @ h_{t-1} inside the gates), so it
cannot be chunk-parallelized — it runs as ``lax.scan`` over time, which is
exactly the recurrent-agent setting of the A3C paper (their LSTM agents).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, d_model: int, *, n_heads: int, expand: int = 2,
               conv_width: int = 4) -> dict:
    d_inner = expand * d_model
    hd = d_inner // n_heads
    ks = jax.random.split(key, 8)
    return {
        "up_x": cm.init_linear(ks[0], d_model, d_inner),
        "up_z": cm.init_linear(ks[1], d_model, d_inner),
        "conv_w": cm.trunc_normal(ks[2], (conv_width, d_inner), 0.2),
        "conv_b": jnp.zeros((d_inner,), jnp.float32),
        "wq": cm.init_linear(ks[3], d_inner, d_inner),
        "wk": cm.init_linear(ks[4], d_inner, d_inner),
        "wv": cm.init_linear(ks[5], d_inner, d_inner),
        "w_i": cm.init_linear(ks[6], d_inner, n_heads, bias=True),
        "w_f": cm.init_linear(ks[7], d_inner, n_heads, bias=True),
        "norm": cm.init_rmsnorm(d_inner),   # stand-in for per-head groupnorm
        "down": cm.init_linear(jax.random.fold_in(key, 99), d_inner, d_model),
    }


def _mlstm_chunked(q, k, v, log_f, log_i, *, chunk: int, state=None):
    """Chunkwise mLSTM with exponential-gating stabilizer.

    q,k,v (B,S,H,D); log_f,log_i (B,S,H).  Returns (y, (C,n,m) final).
    Math (xLSTM eq. 19-27): C_t = f_t C_{t-1} + i_t v_t k_t^T,
    n_t = f_t n_{t-1} + i_t k_t, y_t = C_t q_t / max(|n_t.q_t|, 1), with all
    gates stabilized by m_t = max(log f_t + m_{t-1}, log i_t).
    """
    bsz, s, h, d = q.shape
    qc = min(chunk, s)
    assert s % qc == 0
    nc = s // qc

    def r(t):
        return t.reshape((bsz, nc, qc) + t.shape[2:])

    q, k, v = r(q), r(k), r(v)
    log_f = r(log_f.astype(jnp.float32))
    log_i = r(log_i.astype(jnp.float32))
    cum_f = jnp.cumsum(log_f, axis=2)                    # (B,nc,q,H)
    total_f = cum_f[:, :, -1]                            # (B,nc,H)

    # within-chunk attention-like term with decay exp(cum_i - cum_j + log_i_j)
    logw = (cum_f[:, :, :, None] - cum_f[:, :, None, :]
            + log_i[:, :, None, :, :])                   # (B,nc,i,j,H)
    mask = jnp.tril(jnp.ones((qc, qc), bool))
    logw = jnp.where(mask[None, None, :, :, None], logw, -jnp.inf)
    # local stabilizer: row max of logw (i.e. max over j)
    m_loc = jnp.max(logw, axis=3)                        # (B,nc,i,H)

    # chunk-state contributions: weight exp(total_f - cum_f_j + log_i_j)
    logs = total_f[:, :, None] - cum_f + log_i           # (B,nc,j,H)

    scale = d ** -0.5
    qk = jnp.einsum("bcihd,bcjhd->bcijh", q, k,
                    preferred_element_type=jnp.float32) * scale

    if state is None:
        c0 = jnp.zeros((bsz, h, d, d), jnp.float32)
        n0 = jnp.zeros((bsz, h, d), jnp.float32)
        m0 = jnp.full((bsz, h), -1e30)
    else:
        c0, n0, m0 = state

    # scan over chunks; each step consumes one chunk's tensors
    cum_f_sw = jnp.moveaxis(cum_f, 1, 0)                 # (nc,B,q,H)
    total_sw = jnp.moveaxis(total_f, 1, 0)
    q_sw = jnp.moveaxis(q, 1, 0)
    v_sw = jnp.moveaxis(v, 1, 0)
    k_sw = jnp.moveaxis(k, 1, 0)
    qk_sw = jnp.moveaxis(qk, 1, 0)                       # (nc,B,i,j,H)
    logw_sw = jnp.moveaxis(logw, 1, 0)
    logs_sw = jnp.moveaxis(logs, 1, 0)
    m_loc_sw = jnp.moveaxis(m_loc, 1, 0)

    def step(carry, inp):
        c_prev, n_prev, m_prev = carry                   # (B,H,D,D),(B,H,D),(B,H)
        qi, ki, vi, qki, logwi, logsi, cumfi, toti, mloci = inp
        # stabilizer per row i: max(inherited m decayed, local max)
        m_inh = m_prev[:, None, :] + cumfi               # (B,i,H)
        m_row = jnp.maximum(m_inh, mloci)                # (B,i,H)
        # within-chunk weights, stabilized
        w_loc = jnp.exp(logwi - m_row[:, :, None, :])    # (B,i,j,H)
        # inherited contribution, stabilized
        w_inh = jnp.exp(m_inh - m_row)                   # (B,i,H)
        num_loc = jnp.einsum("bijh,bijh,bjhd->bihd", qki, w_loc, vi)
        # C is stored (v-index d, k-index e): contract q against the k index
        num_inh = jnp.einsum("bihe,bhde->bihd", qi * w_inh[..., None] *
                             (qi.shape[-1] ** -0.5), c_prev)
        # denominator: n_t . q_t with same stabilization
        nq_loc = jnp.einsum("bijh,bijh->bih", qki, w_loc)
        nq_inh = jnp.einsum("bihd,bhd->bih", qi * (qi.shape[-1] ** -0.5),
                            n_prev) * w_inh
        den = jnp.maximum(jnp.abs(nq_loc + nq_inh), jnp.exp(-m_row))
        y = (num_loc + num_inh) / den[..., None]
        # chunk-state update (stabilized by new m at chunk end)
        m_end = jnp.maximum(m_prev + toti, jnp.max(logsi + 0.0, axis=1))
        s_w = jnp.exp(logsi - m_end[:, None, :])         # (B,j,H)
        c_new = (jnp.exp(m_prev + toti - m_end)[:, :, None, None] * c_prev
                 + jnp.einsum("bjh,bjhd,bjhe->bhde", s_w, vi, ki))
        n_new = (jnp.exp(m_prev + toti - m_end)[:, :, None] * n_prev
                 + jnp.einsum("bjh,bjhd->bhd", s_w, ki))
        return (c_new, n_new, m_end), y

    (c_f, n_f, m_f), ys = jax.lax.scan(
        step, (c0, n0, m0),
        (q_sw, k_sw, v_sw, qk_sw, logw_sw, logs_sw, cum_f_sw, total_sw,
         m_loc_sw))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, d)
    return y, (c_f, n_f, m_f)


def mlstm_train(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """x (B, S, d_model) -> (B, S, d_model)."""
    bsz, s, _ = x.shape
    h = cfg.n_heads
    xi = cm.linear(p["up_x"], x)
    z = cm.linear(p["up_z"], x)
    xc, _ = _causal_conv_x(xi, p["conv_w"], p["conv_b"])
    xc = cm.silu(xc)
    d_inner = xi.shape[-1]
    hd = d_inner // h
    q = cm.linear(p["wq"], xc).reshape(bsz, s, h, hd)
    k = cm.linear(p["wk"], xc).reshape(bsz, s, h, hd)
    v = cm.linear(p["wv"], xi).reshape(bsz, s, h, hd)
    log_i = cm.linear(p["w_i"], xc).astype(jnp.float32)            # (B,S,H)
    log_f = jax.nn.log_sigmoid(cm.linear(p["w_f"], xc).astype(jnp.float32))
    y, _ = _mlstm_chunked(q, k, v, log_f, log_i, chunk=cfg.ssm_chunk)
    y = y.astype(x.dtype).reshape(bsz, s, d_inner)
    y = cm.rmsnorm(p["norm"], y) * cm.silu(z)
    return cm.linear(p["down"], y)


def init_mlstm_state(batch: int, d_model: int, n_heads: int, *,
                     expand: int = 2, conv_width: int = 4) -> dict:
    d_inner = expand * d_model
    hd = d_inner // n_heads
    return {
        "C": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, n_heads, hd), jnp.float32),
        "m": jnp.full((batch, n_heads), -1e30),
        "conv": jnp.zeros((batch, conv_width - 1, d_inner), jnp.float32),
    }


def mlstm_decode(p: dict, x: jnp.ndarray, state: dict, cfg):
    """One-token decode.  x (B, 1, d_model)."""
    bsz = x.shape[0]
    h = cfg.n_heads
    xi = cm.linear(p["up_x"], x)
    z = cm.linear(p["up_z"], x)
    xc, conv_state = _causal_conv_x(xi, p["conv_w"], p["conv_b"],
                                    state["conv"])
    xc = cm.silu(xc)
    d_inner = xi.shape[-1]
    hd = d_inner // h
    q = cm.linear(p["wq"], xc).reshape(bsz, h, hd).astype(jnp.float32)
    k = cm.linear(p["wk"], xc).reshape(bsz, h, hd).astype(jnp.float32)
    v = cm.linear(p["wv"], xi).reshape(bsz, h, hd).astype(jnp.float32)
    log_i = cm.linear(p["w_i"], xc)[:, 0].astype(jnp.float32)      # (B,H)
    log_f = jax.nn.log_sigmoid(cm.linear(p["w_f"], xc))[:, 0].astype(jnp.float32)

    m_new = jnp.maximum(log_f + state["m"], log_i)
    f_s = jnp.exp(log_f + state["m"] - m_new)
    i_s = jnp.exp(log_i - m_new)
    c_new = f_s[:, :, None, None] * state["C"] + \
        i_s[:, :, None, None] * jnp.einsum("bhd,bhe->bhde", v, k)
    n_new = f_s[:, :, None] * state["n"] + i_s[:, :, None] * k
    scale = hd ** -0.5
    num = jnp.einsum("bhde,bhe->bhd", c_new, q * scale)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q * scale)),
                      jnp.exp(-m_new))
    y = (num / den[:, :, None]).astype(x.dtype).reshape(bsz, 1, d_inner)
    y = cm.rmsnorm(p["norm"], y) * cm.silu(z)
    out = cm.linear(p["down"], y)
    return out, {"C": c_new, "n": n_new, "m": m_new, "conv": conv_state}


def _causal_conv_x(x, w, b, state=None):
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width)) + b
    return y.astype(x.dtype), xp[:, -(width - 1):]


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, d_model: int, *, n_heads: int, ff_factor: float = 4 / 3
               ) -> dict:
    hd = d_model // n_heads
    ks = jax.random.split(key, 7)
    d_ff = int(ff_factor * d_model)
    # round d_ff to a multiple of 64 for TPU-friendly shapes
    d_ff = max(64, (d_ff // 64) * 64)
    return {
        "w_in": cm.init_linear(ks[0], d_model, 4 * d_model, bias=True),
        # block-diagonal recurrent weights, one (hd, 4*hd) block per head
        "r": cm.trunc_normal(ks[1], (n_heads, hd, 4 * hd), 1.0 / hd ** 0.5),
        "norm": cm.init_rmsnorm(d_model),
        "ff_gate": cm.init_linear(ks[2], d_model, d_ff),
        "ff_up": cm.init_linear(ks[3], d_model, d_ff),
        "ff_down": cm.init_linear(ks[4], d_ff, d_model),
    }


def init_slstm_state(batch: int, d_model: int, n_heads: int) -> dict:
    hd = d_model // n_heads
    z = jnp.zeros((batch, n_heads, hd), jnp.float32)
    return {"h": z, "c": z, "n": jnp.ones_like(z),
            "m": jnp.zeros((batch, n_heads, hd), jnp.float32)}


def _slstm_step(p, state, xt, n_heads):
    """xt (B, 4*d_model) preactivations from input; recurrent part added here."""
    bsz = xt.shape[0]
    hd = state["h"].shape[-1]
    rec = jnp.einsum("bhd,hde->bhe", state["h"], p["r"])    # (B,H,4*hd)
    pre = xt.reshape(bsz, n_heads, 4 * hd).astype(jnp.float32) + rec
    zi, ii, fi, oi = jnp.split(pre, 4, axis=-1)             # (B,H,hd) each
    zt = jnp.tanh(zi)
    ot = jax.nn.sigmoid(oi)
    # exponential input gate + sigmoid-ish forget gate w/ stabilizer m
    log_f = jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(log_f + state["m"], ii)
    i_s = jnp.exp(ii - m_new)
    f_s = jnp.exp(log_f + state["m"] - m_new)
    c_new = f_s * state["c"] + i_s * zt
    n_new = f_s * state["n"] + i_s
    h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
    return {"h": h_new, "c": c_new, "n": n_new, "m": m_new}


def slstm_train(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """True recurrence: lax.scan over time.  x (B, S, d)."""
    bsz, s, d = x.shape
    n_heads = cfg.n_heads
    pre = cm.linear(p["w_in"], x)                           # (B,S,4d)
    state0 = init_slstm_state(bsz, d, n_heads)

    def step(st, xt):
        st2 = _slstm_step(p, st, xt, n_heads)
        return st2, st2["h"]

    _, hs = jax.lax.scan(step, state0, jnp.moveaxis(pre, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(bsz, s, d).astype(x.dtype)
    y = cm.rmsnorm(p["norm"], y)
    ff = cm.linear(p["ff_down"],
                   cm.gelu(cm.linear(p["ff_gate"], y)) * cm.linear(p["ff_up"], y))
    return ff


def slstm_decode(p: dict, x: jnp.ndarray, state: dict, cfg):
    bsz, _, d = x.shape
    pre = cm.linear(p["w_in"], x)[:, 0]
    st2 = _slstm_step(p, state, pre, cfg.n_heads)
    y = st2["h"].reshape(bsz, 1, d).astype(x.dtype)
    y = cm.rmsnorm(p["norm"], y)
    ff = cm.linear(p["ff_down"],
                   cm.gelu(cm.linear(p["ff_gate"], y)) * cm.linear(p["ff_up"], y))
    return ff, st2
