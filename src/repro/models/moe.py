"""Mixture-of-Experts layer: top-k router + capacity-based dispatch.

GShard/Switch-style dispatch adapted for TPU sharding: tokens are scattered
into a fixed-capacity per-expert buffer (E, C, d) which is sharded over the
``model`` mesh axis (expert parallelism) — under GSPMD the data->expert
re-layout lowers to an all-to-all.  The expert computation itself is a single
grouped einsum over the stacked expert weights, which keeps the MXU busy with
one big contraction instead of E small ones.

Returns the auxiliary load-balance loss (Switch §4: E * sum_e f_e * P_e) along
with the output so the training loss can add ``aux_weight * lb_loss``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed import ctx
from repro.models import common as cm


def init_moe(key, d_model: int, d_ff: int, n_experts: int, *,
             router_stddev: float = 0.02) -> dict:
    ks = jax.random.split(key, 4)
    sd_in = 1.0 / (d_model ** 0.5)
    sd_out = 1.0 / (d_ff ** 0.5)
    return {
        "router": cm.trunc_normal(ks[0], (d_model, n_experts), router_stddev),
        "w_gate": cm.trunc_normal(ks[1], (n_experts, d_model, d_ff), sd_in),
        "w_up": cm.trunc_normal(ks[2], (n_experts, d_model, d_ff), sd_in),
        "w_down": cm.trunc_normal(ks[3], (n_experts, d_ff, d_model), sd_out),
    }


def moe_apply(p: dict, x: jnp.ndarray, *, top_k: int,
              capacity_factor: float = 1.25,
              act: str = "silu") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) -> (y (B, S, d), load_balance_loss ())."""
    b, s, d = x.shape
    e = p["router"].shape[1]
    t = b * s
    xf = x.reshape(t, d)

    router_logits = (xf.astype(jnp.float32) @ p["router"])        # (T, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, top_k)                      # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- load-balance auxiliary loss (computed on full probs) ---
    assign1 = jax.nn.one_hot(eidx[:, 0], e, dtype=jnp.float32)     # top-1 frac
    f_e = assign1.mean(0)
    p_e = probs.mean(0)
    lb_loss = e * jnp.sum(f_e * p_e)

    # --- capacity-based dispatch ---
    cap = int(max(top_k, capacity_factor * t * top_k / e))
    cap = min(cap, t)  # never more slots than tokens
    e_flat = eidx.reshape(-1)                                      # (T*k,)
    g_flat = gates.reshape(-1).astype(x.dtype)
    tok_flat = jnp.repeat(jnp.arange(t), top_k)                    # (T*k,)
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)            # (T*k, E)
    pos_all = jnp.cumsum(onehot, axis=0) - 1                       # (T*k, E)
    pos = jnp.take_along_axis(pos_all, e_flat[:, None], axis=1)[:, 0]
    keep = (pos < cap)
    pos_c = jnp.where(keep, pos, 0)

    buf = jnp.zeros((e, cap, d), x.dtype)
    contrib = xf[tok_flat] * keep[:, None].astype(x.dtype)
    buf = buf.at[e_flat, pos_c].add(contrib, mode="drop")
    # expert-parallel layout: the token->expert re-shuffle under this
    # constraint is GSPMD's all-to-all
    buf = ctx.constrain(buf, "expert_buffer")

    # --- expert computation: grouped gated MLP ---
    f = cm.ACTIVATIONS[act]
    h = f(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])           # (E, C, d)

    # --- combine back ---
    gathered = out_buf[e_flat, pos_c] * (g_flat * keep.astype(x.dtype))[:, None]
    y = jnp.zeros((t, d), x.dtype).at[tok_flat].add(gathered)
    return y.reshape(b, s, d), lb_loss
