"""Expert-parallel MoE dispatch via shard_map + explicit all-to-all.

The pure-jnp ``moe_apply`` (moe.py) expresses dispatch as a dynamic scatter,
which GSPMD cannot shard — it replicates the (T·k, d) dispatch operand on
every device (~1.5 TB/device/step for granite train_4k; EXPERIMENTS.md
§Perf iter #4).  This module is the TPU-native formulation (GShard /
DeepSpeed-MoE pattern):

  per device: route local tokens -> pack per-expert send buffer (E, C, d)
  all_to_all over the `model` axis (experts live there)   <- the real cost
  local grouped expert matmuls on (E_loc, tp*C, d)
  all_to_all back -> local combine with gates

Token shards: batch over the data axes, sequence over `model` (the
sequence-parallel residual layout), so every device routes a distinct token
slice.  Expert weights are sharded over `model` only (E_loc = E / tp per
device, replicated over data — the FSDP saving is tiny next to the
dispatch-traffic saving).

The dense path remains the oracle: with a (1, 1) mesh the two are
numerically identical (tests/test_moe_ep.py).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common as cm


def _local_route(router, xf, *, top_k: int, n_experts: int, cap: int):
    """Route T_loc tokens; build the (E, cap, d) send buffer."""
    t, d = xf.shape
    logits = xf.astype(jnp.float32) @ router                  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    assign1 = jax.nn.one_hot(eidx[:, 0], n_experts, dtype=jnp.float32)
    lb_loss = n_experts * jnp.sum(assign1.mean(0) * probs.mean(0))

    e_flat = eidx.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(t), top_k)
    onehot = jax.nn.one_hot(e_flat, n_experts, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, 0) - 1,
                              e_flat[:, None], 1)[:, 0]
    keep = pos < cap
    pos_c = jnp.where(keep, pos, 0)

    send = jnp.zeros((n_experts, cap, d), xf.dtype)
    send = send.at[e_flat, pos_c].add(
        xf[tok_flat] * keep[:, None].astype(xf.dtype), mode="drop")
    route = {"e_flat": e_flat, "pos": pos_c, "keep": keep,
             "tok": tok_flat,
             "gates": gates.reshape(-1).astype(xf.dtype)}
    return send, route, lb_loss


def _local_combine(out_buf, route, t: int, d: int):
    gathered = out_buf[route["e_flat"], route["pos"]] * \
        (route["gates"] * route["keep"].astype(out_buf.dtype))[:, None]
    return jnp.zeros((t, d), out_buf.dtype).at[route["tok"]].add(gathered)


def moe_apply_ep(p: dict, x: jnp.ndarray, *, top_k: int,
                 capacity_factor: float, act: str,
                 mesh, dp_axes: Tuple[str, ...],
                 tp_axis: str = "model") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE.  x (B, S, d); S must divide by |tp_axis|."""
    from jax.experimental.shard_map import shard_map

    b, s, d = x.shape
    e = p["router"].shape[1]
    tp = mesh.shape[tp_axis]
    assert e % tp == 0, (e, tp)
    dp = 1
    for a in dp_axes:
        dp = dp * mesh.shape[a]
    t_loc = (b // dp if b % dp == 0 else b) * (s // tp)
    cap = int(max(top_k, capacity_factor * t_loc * top_k / e))

    f = cm.ACTIVATIONS[act]

    def local_fn(router, w_gate, w_up, w_down, xl):
        bl, sl, _ = xl.shape
        xf = xl.reshape(bl * sl, d)
        send, route, lb = _local_route(router, xf, top_k=top_k,
                                       n_experts=e, cap=cap)
        # exchange: (E, C, d) -> (E_loc, tp*C, d); experts to their owners
        recv = jax.lax.all_to_all(send, tp_axis, split_axis=0,
                                  concat_axis=1, tiled=True)
        h = f(jnp.einsum("ecd,edf->ecf", recv, w_gate,
                         preferred_element_type=jnp.float32).astype(xl.dtype)) \
            * jnp.einsum("ecd,edf->ecf", recv, w_up,
                         preferred_element_type=jnp.float32).astype(xl.dtype)
        out = jnp.einsum("ecf,efd->ecd", h, w_down,
                         preferred_element_type=jnp.float32).astype(xl.dtype)
        back = jax.lax.all_to_all(out, tp_axis, split_axis=1,
                                  concat_axis=0, tiled=True)
        y = _local_combine(back, route, bl * sl, d)
        lb = jax.lax.pmean(lb, (tp_axis,) + tuple(dp_axes))
        return y.reshape(bl, sl, d), lb

    dp_spec = dp_axes if (dp_axes and b % dp == 0) else None
    x_spec = P(dp_spec, tp_axis, None)
    out = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(None, None),                 # router replicated
                  P(tp_axis, None, None),        # experts on model axis
                  P(tp_axis, None, None),
                  P(tp_axis, None, None),
                  x_spec),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)
    return out
