"""Whisper-style encoder-decoder backbone.

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
``batch["enc_frames"]`` carries precomputed frame embeddings (B, F, d_model).
Everything downstream — bidirectional encoder, causal decoder with
self + cross attention, sinusoidal positions — is fully implemented.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common as cm
from repro.models import mlp as mlp_mod
from repro.models.config import ModelConfig


def _sinusoid(s: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None]
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((s, d))
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe


def _init_enc_layer(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": cm.init_norm(cfg.norm, cfg.d_model),
        "attn": attn.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.hd, qkv_bias=True),
        "ln2": cm.init_norm(cfg.norm, cfg.d_model),
        "mlp": mlp_mod.init_mlp(ks[1], cfg.d_model, cfg.d_ff),
    }


def _init_dec_layer(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "ln1": cm.init_norm(cfg.norm, cfg.d_model),
        "self_attn": attn.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.hd,
                                         qkv_bias=True),
        "ln_x": cm.init_norm(cfg.norm, cfg.d_model),
        "cross_attn": attn.init_attention(ks[1], cfg.d_model, cfg.n_heads,
                                          cfg.n_kv_heads, cfg.hd,
                                          qkv_bias=True),
        "ln2": cm.init_norm(cfg.norm, cfg.d_model),
        "mlp": mlp_mod.init_mlp(ks[2], cfg.d_model, cfg.d_ff),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    n_enc, n_dec = cfg.encoder_layers, cfg.n_layers
    keys = jax.random.split(key, n_enc + n_dec + 4)
    p: Dict[str, Any] = {
        "embed": cm.init_embedding(keys[-1], cfg.vocab_size, cfg.d_model),
        "enc_layers": [_init_enc_layer(cfg, keys[i]) for i in range(n_enc)],
        "dec_layers": [_init_dec_layer(cfg, keys[n_enc + i])
                       for i in range(n_dec)],
        "enc_norm": cm.init_norm(cfg.norm, cfg.d_model),
        "final_norm": cm.init_norm(cfg.norm, cfg.d_model),
    }
    if cfg.value_head:
        p["value_head"] = cm.init_linear(keys[-2], cfg.d_model, 1)
    return p


def encode(cfg: ModelConfig, params, frames: jnp.ndarray) -> jnp.ndarray:
    """frames (B, F, d_model) — stub conv output.  Bidirectional encoder."""
    x = frames.astype(cfg.dtype)
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    for lyr in params["enc_layers"]:
        h = attn.attend_train(lyr["attn"],
                              cm.apply_norm(cfg.norm, lyr["ln1"], x),
                              None, None, cfg, use_rope=False,
                              bidirectional=True)
        x = x + h
        x = x + mlp_mod.mlp(lyr["mlp"],
                            cm.apply_norm(cfg.norm, lyr["ln2"], x),
                            act=cfg.act)
    return cm.apply_norm(cfg.norm, params["enc_norm"], x)


def forward(cfg: ModelConfig, params, batch):
    mem = encode(cfg, params, batch["enc_frames"])
    x = cm.embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    mem_kvs = [attn.memory_kv(l["cross_attn"], mem, cfg)
               for l in params["dec_layers"]]
    for lyr, mkv in zip(params["dec_layers"], mem_kvs):
        h = attn.attend_train(lyr["self_attn"],
                              cm.apply_norm(cfg.norm, lyr["ln1"], x),
                              None, None, cfg, use_rope=False)
        x = x + h
        x = x + attn.cross_attend(lyr["cross_attn"],
                                  cm.apply_norm(cfg.norm, lyr["ln_x"], x),
                                  mkv, cfg)
        x = x + mlp_mod.mlp(lyr["mlp"],
                            cm.apply_norm(cfg.norm, lyr["ln2"], x),
                            act=cfg.act)
    x = cm.apply_norm(cfg.norm, params["final_norm"], x)
    out = {"aux_loss": jnp.zeros((), jnp.float32),
           "logits": x @ params["embed"]["table"].T.astype(x.dtype)}
    if cfg.value_head:
        out["value"] = cm.linear(params["value_head"], x)[..., 0] \
            .astype(jnp.float32)
    return out


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16) -> dict:
    return {
        "self": [attn.init_kv_cache(batch, cache_len, cfg.n_kv_heads, cfg.hd,
                                    dtype) for _ in range(cfg.n_layers)],
        # cross-attention K/V precomputed at prefill time from the encoder
        "cross": [
            {"k": jnp.zeros((batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd),
                            dtype),
             "v": jnp.zeros((batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd),
                            dtype)}
            for _ in range(cfg.n_layers)],
    }


def prefill_cross(cfg: ModelConfig, params, cache, frames):
    """Run the encoder once and stash cross-attention K/V in the cache."""
    mem = encode(cfg, params, frames)
    cross = []
    for lyr in params["dec_layers"]:
        k, v = attn.memory_kv(lyr["cross_attn"], mem, cfg)
        cross.append({"k": k.astype(cache["cross"][0]["k"].dtype),
                      "v": v.astype(cache["cross"][0]["v"].dtype)})
    return {**cache, "cross": cross}


def decode_step(cfg: ModelConfig, params, cache, batch, pos):
    x = cm.embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
    # positional embedding at absolute pos (sinusoid computed directly);
    # pos is a lockstep scalar () or per-slot (B,)
    posb = pos if jnp.ndim(pos) == 1 else pos[None]
    dim = jnp.arange(0, cfg.d_model, 2, dtype=jnp.float32)[None]
    ang = posb.astype(jnp.float32)[:, None] / \
        jnp.power(10000.0, dim / cfg.d_model)
    pe_t = jnp.zeros((ang.shape[0], cfg.d_model))
    pe_t = pe_t.at[:, 0::2].set(jnp.sin(ang))
    pe_t = pe_t.at[:, 1::2].set(jnp.cos(ang))
    x = x + pe_t.astype(x.dtype)[:, None]

    new_self = []
    for i, lyr in enumerate(params["dec_layers"]):
        h, c = attn.attend_decode(lyr["self_attn"],
                                  cm.apply_norm(cfg.norm, lyr["ln1"], x),
                                  cache["self"][i], pos, cfg,
                                  use_rope=False)
        new_self.append(c)
        x = x + h
        mkv = (cache["cross"][i]["k"], cache["cross"][i]["v"])
        x = x + attn.cross_attend(lyr["cross_attn"],
                                  cm.apply_norm(cfg.norm, lyr["ln_x"], x),
                                  mkv, cfg)
        x = x + mlp_mod.mlp(lyr["mlp"],
                            cm.apply_norm(cfg.norm, lyr["ln2"], x),
                            act=cfg.act)
    x = cm.apply_norm(cfg.norm, params["final_norm"], x)
    out = {"logits": x @ params["embed"]["table"].T.astype(x.dtype)}
    if cfg.value_head:
        out["value"] = cm.linear(params["value_head"], x)[..., 0] \
            .astype(jnp.float32)
    return out, {**cache, "self": new_self}
