"""Mamba2 (SSD) block — TPU-adapted chunkwise-parallel implementation.

The GPU reference implementation of Mamba2 is a fused Triton kernel built
around warp-level parallel scans.  The TPU adaptation here uses the *chunked*
SSD decomposition (Dao & Gu 2024, §6): split the sequence into chunks of Q
steps, compute the within-chunk (quadratic in Q, MXU-friendly einsums) and
cross-chunk (a short ``lax.scan`` over chunk states) parts separately.  This
turns the recurrence into large matmuls — exactly what the MXU wants — while
keeping O(S·Q) compute, i.e. sub-quadratic end-to-end.

Decode is the plain O(1) recurrence ``h <- a*h + dt*B⊗x;  y = C·h + D*x``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm


def init_mamba2(key, d_model: int, *, d_state: int, n_heads: int,
                head_dim: int, n_groups: int = 1, conv_width: int = 4,
                expand: int = 2) -> dict:
    """d_inner = n_heads * head_dim (== expand * d_model by construction)."""
    d_inner = n_heads * head_dim
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * d_inner + 2 * n_groups * d_state + n_heads
    conv_ch = d_inner + 2 * n_groups * d_state
    return {
        "in_proj": cm.init_linear(ks[0], d_model, d_in_proj),
        "conv_w": cm.trunc_normal(ks[1], (conv_width, conv_ch), 0.2),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (n_heads,),
                                       minval=jnp.log(1e-3),
                                       maxval=jnp.log(1e-1))))),
        "norm": cm.init_rmsnorm(d_inner),
        "out_proj": cm.init_linear(ks[3], d_inner, d_model),
    }


def _split_in_proj(z_all, d_inner, n_groups, d_state, n_heads):
    zi = d_inner
    xi = 2 * d_inner
    bi = xi + n_groups * d_state
    ci = bi + n_groups * d_state
    return (z_all[..., :zi], z_all[..., zi:xi], z_all[..., xi:bi],
            z_all[..., bi:ci], z_all[..., ci:])


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray = None):
    """Depthwise causal conv.  x (B, S, C), w (W, C).  Returns (y, new_state)
    where state holds the last W-1 inputs (for decode)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width)) + b
    new_state = xp[:, -(width - 1):] if width > 1 else pad
    return y.astype(x.dtype), new_state


def ssd_chunked(x, log_a, b, c, *, chunk: int = 256,
                h0: jnp.ndarray = None):
    """Chunked SSD scan.

    x     (B, S, H, P)   per-head inputs (already dt-scaled)
    log_a (B, S, H)      per-step log decay (<= 0)
    b     (B, S, H, N)   input maps (already dt-free, group-expanded)
    c     (B, S, H, N)   output maps
    Returns (y (B,S,H,P), h_last (B,H,N,P)).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, f"seq {s} not divisible by chunk {q}"
    nc = s // q

    def r(t):  # (B, S, ...) -> (B, nc, q, ...)
        return t.reshape((bsz, nc, q) + t.shape[2:])

    x, log_a, b, c = r(x), r(log_a.astype(jnp.float32)), r(b), r(c)
    cum = jnp.cumsum(log_a, axis=2)                       # (B,nc,q,H)
    total = cum[:, :, -1]                                 # (B,nc,H)

    # within-chunk: Y_diag[i] = sum_{j<=i} exp(cum_i - cum_j) (c_i.b_j) x_j
    decay = jnp.exp(cum[:, :, :, None] - cum[:, :, None, :])   # (B,nc,i,j,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(mask[None, None, :, :, None], decay, 0.0)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", c, b,
                    preferred_element_type=jnp.float32)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", cb * decay, x,
                        preferred_element_type=jnp.float32)

    # chunk states: S_c = sum_j exp(total - cum_j) b_j ⊗ x_j
    w = jnp.exp(total[:, :, None] - cum)                  # (B,nc,q,H)
    states = jnp.einsum("bcjhn,bcjh,bcjhp->bchnp", b, w, x,
                        preferred_element_type=jnp.float32)

    # cross-chunk scan over chunk states
    if h0 is None:
        h0 = jnp.zeros((bsz, h, n, p), jnp.float32)

    def body(carry, inp):
        st, tot = inp                                     # (B,H,N,P), (B,H)
        h_prev = carry
        h_new = jnp.exp(tot)[:, :, None, None] * h_prev + st
        return h_new, h_prev

    h_last, h_prevs = jax.lax.scan(
        body, h0, (states.swapaxes(0, 1), total.swapaxes(0, 1)))
    h_prevs = h_prevs.swapaxes(0, 1)                      # (B,nc,H,N,P)

    # off-chunk contribution: Y_off[i] = c_i . (exp(cum_i) * h_prev_chunk)
    y_off = jnp.einsum("bcihn,bcih,bchnp->bcihp", c, jnp.exp(cum), h_prevs,
                       preferred_element_type=jnp.float32)
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, h_last


def mamba2_train(p: dict, xin: jnp.ndarray, cfg) -> jnp.ndarray:
    """xin (B, S, d_model) -> (B, S, d_model)."""
    h, pd, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    d_inner = h * pd
    zxbcdt = cm.linear(p["in_proj"], xin)
    z, xs, bb, cc, dt = _split_in_proj(zxbcdt, d_inner, g, n, h)
    conv_in = jnp.concatenate([xs, bb, cc], axis=-1)
    conv_out, _ = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    conv_out = cm.silu(conv_out)
    xs = conv_out[..., :d_inner]
    bb = conv_out[..., d_inner:d_inner + g * n]
    cc = conv_out[..., d_inner + g * n:]

    bsz, s = xin.shape[:2]
    xs = xs.reshape(bsz, s, h, pd)
    bb = bb.reshape(bsz, s, g, n)
    cc = cc.reshape(bsz, s, g, n)
    rep = h // g
    bb = jnp.repeat(bb, rep, axis=2)
    cc = jnp.repeat(cc, rep, axis=2)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    a = -jnp.exp(p["A_log"])                                      # (H,)
    log_decay = dt * a                                            # (B,S,H)
    x_dt = xs * dt[..., None].astype(xs.dtype)

    y, _ = ssd_chunked(x_dt, log_decay, bb, cc, chunk=cfg.ssm_chunk)
    y = y.astype(xin.dtype) + xs * p["D"].astype(xs.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, d_inner)
    y = cm.rmsnorm(p["norm"], y * cm.silu(z))
    return cm.linear(p["out_proj"], y)


def init_mamba2_state(batch: int, cfg, dtype=jnp.float32) -> dict:
    h, pd, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    d_inner = h * pd
    conv_ch = d_inner + 2 * g * n
    return {
        "h": jnp.zeros((batch, h, n, pd), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
    }


def mamba2_decode(p: dict, xin: jnp.ndarray, state: dict, cfg):
    """One-token decode.  xin (B, 1, d_model) -> (y, new_state)."""
    h, pd, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    d_inner = h * pd
    zxbcdt = cm.linear(p["in_proj"], xin)
    z, xs, bb, cc, dt = _split_in_proj(zxbcdt, d_inner, g, n, h)
    conv_in = jnp.concatenate([xs, bb, cc], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                        state["conv"])
    conv_out = cm.silu(conv_out)
    xs = conv_out[..., :d_inner]
    bb = conv_out[..., d_inner:d_inner + g * n]
    cc = conv_out[..., d_inner + g * n:]

    bsz = xin.shape[0]
    xs = xs.reshape(bsz, h, pd)
    bb = jnp.repeat(bb.reshape(bsz, g, n), h // g, axis=1)
    cc = jnp.repeat(cc.reshape(bsz, g, n), h // g, axis=1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(dt * -jnp.exp(p["A_log"]))                             # (B,H)

    hh = state["h"]
    hh = a[:, :, None, None] * hh + jnp.einsum(
        "bhn,bh,bhp->bhnp", bb.astype(jnp.float32), dt,
        xs.astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", cc.astype(jnp.float32), hh)
    y = y.astype(xin.dtype) + xs * p["D"].astype(xs.dtype)[None, :, None]
    y = y.reshape(bsz, 1, d_inner)
    y = cm.rmsnorm(p["norm"], y * cm.silu(z))
    return cm.linear(p["out_proj"], y), {"h": hh, "conv": conv_state}
