"""Config-driven backbone assembly.

Public API (uniform across all 10 assigned architectures):

  init_params(cfg, key)                        -> params pytree
  forward(cfg, params, batch)                  -> {"logits", "value", "aux_loss"}
  init_cache(cfg, batch, cache_len)            -> cache pytree
  decode_step(cfg, params, cache, batch, pos)  -> ({"logits", "value"}, cache)

``batch`` is a dict: {"tokens": (B,S) int32} or {"embeds": (B,S,d)} (VLM /
audio stub), optionally {"positions": (3,B,S)} for M-RoPE and
{"enc_frames": (B,F,d)} for the Whisper encoder (handled in encdec.py).

Layers whose pattern tiles evenly (and with no Zamba2 shared block) are
stacked and driven by ``lax.scan`` so an 80-layer model compiles as one loop;
heterogeneous stacks fall back to a python loop.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import ctx
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.config import ModelConfig

Params = Any


# ---------------------------------------------------------------------------
# per-block init
# ---------------------------------------------------------------------------

def _init_block(cfg: ModelConfig, kind: str, key) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if kind in ("attn", "attn_local"):
        p = {
            "ln1": cm.init_norm(cfg.norm, d),
            "attn": attn.init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                        cfg.hd, qkv_bias=cfg.qkv_bias),
            "ln2": cm.init_norm(cfg.norm, d),
        }
        if cfg.n_experts:
            p["moe"] = moe_mod.init_moe(ks[1], d, cfg.d_ff_expert,
                                        cfg.n_experts)
        elif cfg.d_ff:
            p["mlp"] = mlp_mod.init_gated_mlp(ks[1], d, cfg.d_ff)
        return p
    if kind == "mamba2":
        return {
            "ln1": cm.init_norm(cfg.norm, d),
            "mamba": ssm_mod.init_mamba2(
                ks[0], d, d_state=cfg.ssm_state, n_heads=cfg.ssm_heads,
                head_dim=cfg.ssm_head_dim, n_groups=cfg.ssm_groups,
                conv_width=cfg.ssm_conv_width),
        }
    if kind == "mlstm":
        return {
            "ln1": cm.init_norm(cfg.norm, d),
            "mlstm": xlstm_mod.init_mlstm(ks[0], d, n_heads=cfg.n_heads,
                                          expand=cfg.lstm_expand,
                                          conv_width=cfg.ssm_conv_width),
        }
    if kind == "slstm":
        return {
            "ln1": cm.init_norm(cfg.norm, d),
            "slstm": xlstm_mod.init_slstm(ks[0], d, n_heads=cfg.n_heads),
        }
    raise ValueError(f"unknown block kind {kind}")


def _apply_block_train(cfg: ModelConfig, kind: str, p: Params, x, cos, sin,
                       aux):
    """One residual block, training (full-sequence) mode."""
    window = cfg.sliding_window if kind == "attn_local" else None
    if kind in ("attn", "attn_local"):
        h = attn.attend_train(p["attn"], cm.apply_norm(cfg.norm, p["ln1"], x),
                              cos, sin, cfg, window=window)
        # seq-parallel block outputs: turns the model-axis gradient
        # all-reduce into a reduce-scatter (Megatron-SP, perf iter #2)
        x = x + ctx.constrain(h, "residual")
        y = cm.apply_norm(cfg.norm, p["ln2"], x)
        if cfg.n_experts:
            ep = (ctx.current_rules() or {}).get("moe_ep")
            if ep is not None and y.shape[1] % ep["tp"] == 0:
                # explicit expert-parallel all-to-all (perf iter #4)
                from repro.models import moe_ep as moe_ep_mod
                y, lb = moe_ep_mod.moe_apply_ep(
                    p["moe"], y, top_k=cfg.top_k,
                    capacity_factor=cfg.capacity_factor, act=cfg.act,
                    mesh=ep["mesh"], dp_axes=ep["dp_axes"])
            else:
                y, lb = moe_mod.moe_apply(
                    p["moe"], y, top_k=cfg.top_k,
                    capacity_factor=cfg.capacity_factor, act=cfg.act)
            aux = aux + lb
        else:
            y = mlp_mod.gated_mlp(p["mlp"], y, act=cfg.act)
        return x + ctx.constrain(y, "residual"), aux
    if kind == "mamba2":
        return x + ctx.constrain(ssm_mod.mamba2_train(
            p["mamba"], cm.apply_norm(cfg.norm, p["ln1"], x), cfg),
            "residual"), aux
    if kind == "mlstm":
        return x + ctx.constrain(xlstm_mod.mlstm_train(
            p["mlstm"], cm.apply_norm(cfg.norm, p["ln1"], x), cfg),
            "residual"), aux
    if kind == "slstm":
        return x + ctx.constrain(xlstm_mod.slstm_train(
            p["slstm"], cm.apply_norm(cfg.norm, p["ln1"], x), cfg),
            "residual"), aux
    raise ValueError(kind)


def _block_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int,
                 dtype, paged=None, kv_dtype=None) -> Params:
    # kv_dtype overrides dtype for ATTENTION caches only — recurrent state
    # below keeps `dtype` (int8 SSM/LSTM state would be numerically
    # meaningless; only KV rows carry the quantization scheme)
    kvd = dtype if kv_dtype is None else kv_dtype
    if kind == "attn":
        if paged is not None:
            # shared page pool + per-slot page table; ring layers below
            # keep contiguous caches (a rotating window has no reusable
            # prefix to share)
            return attn.init_paged_kv_cache(
                batch, cache_len, cfg.n_kv_heads, cfg.hd,
                page_size=paged.page_size, n_pages=paged.n_pages,
                dtype=kvd)
        return attn.init_kv_cache(batch, cache_len, cfg.n_kv_heads, cfg.hd,
                                  kvd)
    if kind == "attn_local":
        clen = min(cache_len, cfg.sliding_window or cache_len)
        return attn.init_kv_cache(batch, clen, cfg.n_kv_heads, cfg.hd, kvd)
    if kind == "mamba2":
        return ssm_mod.init_mamba2_state(batch, cfg, dtype)
    if kind == "mlstm":
        return xlstm_mod.init_mlstm_state(batch, cfg.d_model, cfg.n_heads,
                                          expand=cfg.lstm_expand,
                                          conv_width=cfg.ssm_conv_width)
    if kind == "slstm":
        return xlstm_mod.init_slstm_state(batch, cfg.d_model, cfg.n_heads)
    raise ValueError(kind)


def _apply_block_decode(cfg: ModelConfig, kind: str, p: Params, x, cache,
                        pos):
    window = cfg.sliding_window if kind == "attn_local" else None
    if kind in ("attn", "attn_local"):
        # one decode path for both cache layouts: attend_decode routes the
        # context-parallel (decode_cp-ruled) case through the dispatch
        # layer's pallas_cp arm itself
        h, cache = attn.attend_decode(
            p["attn"], cm.apply_norm(cfg.norm, p["ln1"], x),
            cache, pos, cfg, window=window)
        x = x + h
        y = cm.apply_norm(cfg.norm, p["ln2"], x)
        if cfg.n_experts:
            y, _ = moe_mod.moe_apply(p["moe"], y, top_k=cfg.top_k,
                                     capacity_factor=cfg.capacity_factor,
                                     act=cfg.act)
        else:
            y = mlp_mod.gated_mlp(p["mlp"], y, act=cfg.act)
        return x + y, cache
    if kind == "mamba2":
        h, cache = ssm_mod.mamba2_decode(
            p["mamba"], cm.apply_norm(cfg.norm, p["ln1"], x), cache, cfg)
        return x + h, cache
    if kind == "mlstm":
        h, cache = xlstm_mod.mlstm_decode(
            p["mlstm"], cm.apply_norm(cfg.norm, p["ln1"], x), cache, cfg)
        return x + h, cache
    if kind == "slstm":
        h, cache = xlstm_mod.slstm_decode(
            p["slstm"], cm.apply_norm(cfg.norm, p["ln1"], x), cache, cfg)
        return x + h, cache
    raise ValueError(kind)


def _apply_block_prefill(cfg: ModelConfig, kind: str, p: Params, x, cache,
                         pos0: int, true_len=None):
    """One residual block over a whole prompt chunk, writing the KV cache.
    Only attention blocks support this (checked by
    ``supports_chunked_prefill``); recurrent caches need their own scan.
    ``true_len`` (B,) masks ring-cache writes past each row's real prompt
    length (right-padded admission chunks)."""
    window = cfg.sliding_window if kind == "attn_local" else None
    if kind not in ("attn", "attn_local"):
        raise NotImplementedError(
            f"chunked prefill is KV-cache only, got block kind {kind}")
    h, cache = attn.attend_prefill(
        p["attn"], cm.apply_norm(cfg.norm, p["ln1"], x), cache, pos0, cfg,
        window=window, true_len=true_len)
    x = x + h
    y = cm.apply_norm(cfg.norm, p["ln2"], x)
    if cfg.n_experts:
        y, _ = moe_mod.moe_apply(p["moe"], y, top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor,
                                 act=cfg.act)
    else:
        y = mlp_mod.gated_mlp(p["mlp"], y, act=cfg.act)
    return x + y, cache


# ---------------------------------------------------------------------------
# stacking helpers
# ---------------------------------------------------------------------------

def _use_scan(cfg: ModelConfig) -> bool:
    return (cfg.n_layers % len(cfg.block_cycle) == 0
            and cfg.shared_attn_every == 0
            and not cfg.is_encdec)


def _n_cycles(cfg: ModelConfig) -> int:
    return cfg.n_layers // len(cfg.block_cycle)


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Params:
    if cfg.is_encdec:
        from repro.models import encdec
        return encdec.init_params(cfg, key)
    keys = jax.random.split(key, cfg.n_layers + 5)
    kinds = cfg.layer_kinds()
    p: Dict[str, Params] = {
        "embed": cm.init_embedding(keys[-1], cfg.vocab_size, cfg.d_model),
        "final_norm": cm.init_norm(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = cm.init_linear(keys[-2], cfg.d_model, cfg.vocab_size)
    if cfg.value_head:
        p["value_head"] = cm.init_linear(keys[-3], cfg.d_model, 1)
    if cfg.shared_attn_every:
        # Zamba2: one shared attention+MLP block reused at every k-th layer
        p["shared_attn"] = _init_block(
            cfg, "attn", keys[-4])

    layer_ps = [_init_block(cfg, kinds[i], keys[i])
                for i in range(cfg.n_layers)]
    if _use_scan(cfg):
        cyc = len(cfg.block_cycle)
        cycles = [tuple(layer_ps[i * cyc + j] for j in range(cyc))
                  for i in range(_n_cycles(cfg))]
        p["layers"] = _stack(cycles)
    else:
        p["layers"] = layer_ps
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def cast_params(cfg: ModelConfig, params: Params) -> Params:
    """Mixed precision: cast matrix params to the compute dtype (bf16 on
    TPU); vectors (norm scales, biases, SSM time constants) stay f32.  Master
    params and optimizer state remain f32 — this cast sits inside the loss so
    gradients flow back to the f32 masters."""
    dt = jnp.dtype(cfg.dtype)
    if dt == jnp.float32:
        return params

    def c(x):
        if hasattr(x, "dtype") and x.dtype == jnp.float32 and x.ndim >= 2:
            return x.astype(dt)
        return x

    return jax.tree.map(c, params)


def _embed_inputs(cfg: ModelConfig, params, batch) -> jnp.ndarray:
    if "embeds" in batch:
        x = batch["embeds"]
    else:
        x = cm.embed(params["embed"], batch["tokens"])
    return x.astype(cfg.dtype)


def _rope_tables(cfg: ModelConfig, batch, s: int):
    if cfg.mrope_sections is not None:
        pos = batch.get("positions")
        if pos is None:
            b = (batch.get("tokens", batch.get("embeds"))).shape[0]
            pos = jnp.broadcast_to(jnp.arange(s)[None, None], (3, b, s))
        return cm.mrope_cos_sin(pos, cfg.hd, cfg.rope_theta,
                                cfg.mrope_sections)
    positions = jnp.arange(s)[None]                      # (1, S)
    return cm.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)


def forward(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray]
            ) -> Dict[str, jnp.ndarray]:
    params = cast_params(cfg, params)
    if cfg.is_encdec:
        from repro.models import encdec
        return encdec.forward(cfg, params, batch)
    x = _embed_inputs(cfg, params, batch)
    s = x.shape[1]
    cos, sin = _rope_tables(cfg, batch, s)
    aux = jnp.zeros((), jnp.float32)
    kinds = cfg.layer_kinds()

    if _use_scan(cfg):
        cyc_kinds = cfg.block_cycle

        def cycle_fn(x, aux, cyc_params):
            for j, kind in enumerate(cyc_kinds):
                x, aux = _apply_block_train(cfg, kind, cyc_params[j], x,
                                            cos, sin, aux)
            return x, aux

        if cfg.remat:
            cycle_fn = jax.checkpoint(cycle_fn)

        def body(carry, cyc_params):
            x, aux = carry
            x, aux = cycle_fn(x, aux, cyc_params)
            # sequence-parallel residual stream between cycles (Megatron-SP):
            # keeps the saved scan carry sharded over the model axis.
            x = ctx.constrain(x, "residual")
            return (x, aux), None

        x = ctx.constrain(x, "residual")
        (x, aux), _ = jax.lax.scan(body, (x, aux), params["layers"])
    else:
        step_fn = _apply_block_train
        if cfg.remat:
            step_fn = jax.checkpoint(_apply_block_train,
                                     static_argnums=(0, 1))
        for i, kind in enumerate(kinds):
            x, aux = step_fn(cfg, kind, params["layers"][i], x, cos, sin,
                             aux)
            x = ctx.constrain(x, "residual")
            if cfg.shared_attn_every and (i + 1) % cfg.shared_attn_every == 0:
                x, aux = step_fn(cfg, "attn", params["shared_attn"], x,
                                 cos, sin, aux)
                x = ctx.constrain(x, "residual")

    x = cm.apply_norm(cfg.norm, params["final_norm"], x)
    out = {"aux_loss": aux}
    if cfg.tie_embeddings:
        out["logits"] = (x @ params["embed"]["table"].T.astype(x.dtype))
    else:
        out["logits"] = cm.linear(params["lm_head"], x, dtype=x.dtype)
    if cfg.value_head:
        out["value"] = cm.linear(params["value_head"], x)[..., 0] \
            .astype(jnp.float32)
    return out


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16, paged=None, kv_dtype=None) -> Params:
    """``paged`` — an ``attention.PagedLayout`` switches every global
    (kind == "attn") layer to the page-pool layout; sliding-window and
    recurrent layers keep their contiguous/recurrent state either way.

    ``kv_dtype`` overrides ``dtype`` for attention KV caches only (int8
    adds per-row scale leaves; recurrent state keeps ``dtype``)."""
    if cfg.is_encdec:
        from repro.models import encdec
        return encdec.init_cache(cfg, batch, cache_len, dtype)
    kinds = cfg.layer_kinds()
    caches = [_block_cache(cfg, k, batch, cache_len, dtype, paged, kv_dtype)
              for k in kinds]
    cache: Dict[str, Any] = {}
    if _use_scan(cfg):
        cyc = len(cfg.block_cycle)
        per_cycle = [tuple(caches[i * cyc + j] for j in range(cyc))
                     for i in range(_n_cycles(cfg))]
        cache["layers"] = _stack(per_cycle)
    else:
        cache["layers"] = caches
    if cfg.shared_attn_every:
        n_apps = cfg.n_layers // cfg.shared_attn_every
        cache["shared"] = [
            _block_cache(cfg, "attn", batch, cache_len, dtype,
                         kv_dtype=kv_dtype)
            for _ in range(n_apps)]
    return cache


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                batch: Dict[str, jnp.ndarray], pos: jnp.ndarray):
    """One-token decode.  batch: {"tokens": (B,1)} or {"embeds": (B,1,d)};
    pos int32 — current absolute position, lockstep scalar () or per-slot
    (B,) (continuous batching; KV-cache blocks handle ragged depths, the
    recurrent blocks are position-free).  Returns (out, new_cache)."""
    params = cast_params(cfg, params)
    if cfg.is_encdec:
        from repro.models import encdec
        return encdec.decode_step(cfg, params, cache, batch, pos)
    x = _embed_inputs(cfg, params, batch)
    kinds = cfg.layer_kinds()

    if _use_scan(cfg):
        cyc_kinds = cfg.block_cycle

        def body(x, inp):
            cyc_params, cyc_cache = inp
            new_caches = []
            for j, kind in enumerate(cyc_kinds):
                x, c = _apply_block_decode(cfg, kind, cyc_params[j], x,
                                           cyc_cache[j], pos)
                new_caches.append(c)
            return x, tuple(new_caches)

        x, new_cache = jax.lax.scan(body, x,
                                    (params["layers"], cache["layers"]))
        cache = dict(cache)
        cache["layers"] = new_cache
    else:
        new_caches = []
        new_shared = []
        shared_i = 0
        for i, kind in enumerate(kinds):
            x, c = _apply_block_decode(cfg, kind, params["layers"][i], x,
                                       cache["layers"][i], pos)
            new_caches.append(c)
            if cfg.shared_attn_every and (i + 1) % cfg.shared_attn_every == 0:
                x, cs = _apply_block_decode(cfg, "attn", params["shared_attn"],
                                            x, cache["shared"][shared_i], pos)
                new_shared.append(cs)
                shared_i += 1
        cache = dict(cache)
        cache["layers"] = new_caches
        if cfg.shared_attn_every:
            cache["shared"] = new_shared

    x = cm.apply_norm(cfg.norm, params["final_norm"], x)
    out = {}
    if cfg.tie_embeddings:
        out["logits"] = (x @ params["embed"]["table"].T.astype(x.dtype))
    else:
        out["logits"] = cm.linear(params["lm_head"], x, dtype=x.dtype)
    if cfg.value_head:
        out["value"] = cm.linear(params["value_head"], x)[..., 0] \
            .astype(jnp.float32)
    return out, cache


# ---------------------------------------------------------------------------
# speculative verify + deferred commit
# ---------------------------------------------------------------------------

def _apply_block_verify(cfg: ModelConfig, kind: str, p: Params, x, cache,
                        pos, shift: int):
    """One residual block over a per-slot K-token draft chunk, cache
    read-only.  Returns (x, pending) — the chunk K/V ``commit_step``
    scatters for accepted rows."""
    window = cfg.sliding_window if kind == "attn_local" else None
    if kind not in ("attn", "attn_local"):
        raise NotImplementedError(
            f"speculative verify is KV-cache only, got block kind {kind}")
    h, pending = attn.attend_verify(
        p["attn"], cm.apply_norm(cfg.norm, p["ln1"], x), cache, pos, cfg,
        shift=shift, window=window)
    x = x + h
    y = cm.apply_norm(cfg.norm, p["ln2"], x)
    if cfg.n_experts:
        y, _ = moe_mod.moe_apply(p["moe"], y, top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor,
                                 act=cfg.act)
    else:
        y = mlp_mod.gated_mlp(p["mlp"], y, act=cfg.act)
    return x + y, pending


def verify_step(cfg: ModelConfig, params: Params, cache: Params,
                batch: Dict[str, jnp.ndarray], pos: jnp.ndarray,
                shift: int):
    """Speculative verify: batch {"tokens": (B, K)} — row j's current
    token + drafts at absolute positions pos[j] + i; pos (B,) int32;
    ``shift`` a static upper bound on pos (the logical cache length).

    Unlike ``decode_step`` this writes NOTHING: it returns
    (out {"logits" (B, K, V)}, pendings) where ``pendings`` mirrors
    ``cache["layers"]`` with each attention layer's chunk K/V, and the
    caller commits the accepted prefix via ``commit_step`` after the
    host-side accept decision — KV rollback on rejection is therefore a
    no-op by construction.  Requires ``supports_chunked_prefill`` (the
    engine gates speculation the same way)."""
    if not supports_chunked_prefill(cfg):
        raise NotImplementedError(
            f"{cfg.name}: speculative verify needs attention-only caches")
    params = cast_params(cfg, params)
    x = _embed_inputs(cfg, params, batch)
    kinds = cfg.layer_kinds()

    if _use_scan(cfg):
        cyc_kinds = cfg.block_cycle

        def body(x, inp):
            cyc_params, cyc_cache = inp
            pendings = []
            for j, kind in enumerate(cyc_kinds):
                x, pend = _apply_block_verify(cfg, kind, cyc_params[j], x,
                                              cyc_cache[j], pos, shift)
                pendings.append(pend)
            return x, tuple(pendings)

        x, pendings = jax.lax.scan(body, x,
                                   (params["layers"], cache["layers"]))
    else:
        pendings = []
        for i, kind in enumerate(kinds):
            x, pend = _apply_block_verify(cfg, kind, params["layers"][i], x,
                                          cache["layers"][i], pos, shift)
            pendings.append(pend)

    x = cm.apply_norm(cfg.norm, params["final_norm"], x)
    out = {}
    if cfg.tie_embeddings:
        out["logits"] = (x @ params["embed"]["table"].T.astype(x.dtype))
    else:
        out["logits"] = cm.linear(params["lm_head"], x, dtype=x.dtype)
    return out, pendings


def commit_step(cfg: ModelConfig, cache: Params, pendings,
                pos: jnp.ndarray, n_acc: jnp.ndarray) -> Params:
    """Commit the accepted prefix of a verify chunk: row j writes pending
    rows i < n_acc[j] at positions pos[j] + i into every layer's cache
    (ring wrap / page-table indirection per layout).  n_acc[j] == 0
    writes nothing for that row."""
    kinds = cfg.layer_kinds()

    if _use_scan(cfg):
        cyc_kinds = cfg.block_cycle

        def body(carry, inp):
            cyc_cache, cyc_pend = inp
            new_caches = []
            for j, kind in enumerate(cyc_kinds):
                window = cfg.sliding_window if kind == "attn_local" else None
                new_caches.append(attn.commit_kv(cyc_cache[j], cyc_pend[j],
                                                 pos, n_acc, window=window))
            return carry, tuple(new_caches)

        _, new_cache = jax.lax.scan(body, jnp.zeros(()),
                                    (cache["layers"], pendings))
        cache = dict(cache)
        cache["layers"] = new_cache
    else:
        new_caches = []
        for i, kind in enumerate(kinds):
            window = cfg.sliding_window if kind == "attn_local" else None
            new_caches.append(attn.commit_kv(cache["layers"][i],
                                             pendings[i], pos, n_acc,
                                             window=window))
        cache = dict(cache)
        cache["layers"] = new_caches
    return cache


# ---------------------------------------------------------------------------
# chunked flash prefill
# ---------------------------------------------------------------------------

def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Chunked prefill block-writes KV caches; recurrent states (SSM,
    xLSTM) and the enc-dec family would need state-returning train scans —
    those architectures fall back to the token-by-token decode loop."""
    return (not cfg.is_encdec
            and not cfg.shared_attn_every
            and all(k in ("attn", "attn_local") for k in cfg.layer_kinds()))


def prefill_step(cfg: ModelConfig, params: Params, cache: Params,
                 batch: Dict[str, jnp.ndarray], pos0: int = 0,
                 true_len=None):
    """Prefill one whole prompt chunk.  batch: {"tokens": (B, C)} (or
    embeds) covering absolute positions [pos0, pos0 + C); pos0 is a static
    python int (one compile per chunk offset — offsets are multiples of the
    chunk size, so a handful of traces serve any prompt length).

    Every attention layer — every chunk, not just the first — runs through
    one ``dispatch.flash_attention_append`` launch (q-offset grid over the
    cache prefix plus the chunk) and writes its KV cache rows in one
    block — replacing C single-token ``decode_step`` launches, the
    dominant serving-latency term for long prompts.  Returns
    (out {"logits" (B, C, V), ...}, new_cache); callers gather each row's
    true last-prompt-token logits (prompts are right-padded; ``true_len``
    (B,) additionally masks ring-cache writes past each row's real length,
    which is what lets right-padded engine admission chunk sliding-window
    architectures) and continue with per-slot decode.
    """
    if not supports_chunked_prefill(cfg):
        raise NotImplementedError(
            f"{cfg.name}: chunked prefill needs attention-only caches")
    params = cast_params(cfg, params)
    x = _embed_inputs(cfg, params, batch)
    kinds = cfg.layer_kinds()

    if _use_scan(cfg):
        cyc_kinds = cfg.block_cycle

        def body(x, inp):
            cyc_params, cyc_cache = inp
            new_caches = []
            for j, kind in enumerate(cyc_kinds):
                x, c = _apply_block_prefill(cfg, kind, cyc_params[j], x,
                                            cyc_cache[j], pos0, true_len)
                new_caches.append(c)
            return x, tuple(new_caches)

        x, new_cache = jax.lax.scan(body, x,
                                    (params["layers"], cache["layers"]))
        cache = dict(cache)
        cache["layers"] = new_cache
    else:
        new_caches = []
        for i, kind in enumerate(kinds):
            x, c = _apply_block_prefill(cfg, kind, params["layers"][i], x,
                                        cache["layers"][i], pos0, true_len)
            new_caches.append(c)
        cache = dict(cache)
        cache["layers"] = new_caches

    x = cm.apply_norm(cfg.norm, params["final_norm"], x)
    out = {}
    if cfg.tie_embeddings:
        out["logits"] = (x @ params["embed"]["table"].T.astype(x.dtype))
    else:
        out["logits"] = cm.linear(params["lm_head"], x, dtype=x.dtype)
    if cfg.value_head:
        out["value"] = cm.linear(params["value_head"], x)[..., 0] \
            .astype(jnp.float32)
    return out, cache
