"""Blockwise (flash) attention in pure JAX with a custom VJP.

This module is one of the jnp fallbacks the kernel dispatch layer
(``repro.kernels.dispatch``) selects — it holds no backend logic of its
own.  It is the lowering-path implementation for long sequences: the S x S score
matrix is never materialized — a ``lax.scan`` over KV blocks carries the
online-softmax state (m, l, acc), and the backward pass recomputes block
scores from saved (q, k, v, out, lse) instead of checkpointing per-block
activations (which would defeat the point).

Sharding note (perf iteration #1, see EXPERIMENTS.md §Perf): tensors keep a
FLAT query-head axis (b, s, hq, ...) throughout.  An earlier version used
the GQA-grouped layout (b, s, hkv, g, ...), which partitions the kv-head
axis — for models with hkv < TP degree (qwen2: 8 kv heads on 16-way model
axis) GSPMD cannot shard it and fell back to full rematerialization of
multi-GB tensors on every KV block step (~17.9 TB/device/step).  With the
flat layout every large tensor shards on hq (64 % 16 == 0) and the KV
blocks are broadcast per group inside the einsum (never materialized 8x in
HBM).  Numerics are identical; tests pin this against sdpa and the Pallas
kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import ctx

NEG = -1e30


def _blockify(x, block: int):
    """(B, S, ...) -> (nb, B, block, ...)."""
    b, s = x.shape[:2]
    nb = s // block
    x = x.reshape((b, nb, block) + x.shape[2:])
    return jnp.moveaxis(x, 1, 0)


def _expand_kv(blk, g: int):
    """(B, bk, Hkv, D) -> (B, bk, Hq, D) by group broadcast (lazy in XLA)."""
    if g == 1:
        return blk
    b, bk, hkv, d = blk.shape
    blk = jnp.broadcast_to(blk[:, :, :, None, :], (b, bk, hkv, g, d))
    return blk.reshape(b, bk, hkv * g, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention_jnp(q, k, v, causal: bool = True,
                        window: Optional[int] = None, block_k: int = 512):
    """q (B,S,Hq,D); k,v (B,S,Hkv,D).  Returns (B,S,Hq,D)."""
    o, _ = _flash_fwd(q, k, v, causal, window, block_k)
    return o


def _score_mask(q_pos, k_pos, causal, window):
    """(Sq, bk) boolean validity mask."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


def _flash_fwd(q, k, v, causal, window, block_k):
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    bk = min(block_k, k.shape[1])
    assert k.shape[1] % bk == 0
    scale = d ** -0.5
    # keep q/k/v in their storage dtype (bf16 on TPU) — accumulation happens
    # in f32 via preferred_element_type, and collectives stay half-width
    qs = (q * scale).astype(q.dtype)
    kb = _blockify(k, bk)      # (nb,B,bk,Hkv,D)
    vb = _blockify(v, bk)
    q_pos = jnp.arange(s)
    nb = kb.shape[0]

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, i = inp
        kblk = _expand_kv(kblk, g)                  # (B,bk,Hq,D) lazy
        vblk = _expand_kv(vblk, g)
        k_pos = i * bk + jnp.arange(bk)
        logits = jnp.einsum("bshd,bkhd->bshk", qs, kblk,
                            preferred_element_type=jnp.float32)
        mask = _score_mask(q_pos, k_pos, causal, window)
        logits = jnp.where(mask[None, :, None, :], logits, NEG)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + \
            jnp.einsum("bshk,bkhd->bshd", p.astype(v.dtype), vblk,
                       preferred_element_type=jnp.float32)
        # keep the online-softmax state head-sharded across block steps
        # (otherwise GSPMD flips layouts every iteration — perf iter #3)
        acc_new = ctx.constrain(acc_new, "attn_q")
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, s, hq), NEG, jnp.float32)
    l0 = jnp.zeros((b, s, hq), jnp.float32)
    a0 = jnp.zeros((b, s, hq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nb)))
    l_safe = jnp.maximum(l, 1e-30)
    o = (acc / l_safe[..., None]).astype(q.dtype)
    lse = m + jnp.log(l_safe)                                # (B,S,Hq)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, window, block_k, res, do):
    q, k, v, o, lse = res
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    bk = min(block_k, k.shape[1])
    scale = d ** -0.5
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), -1)
    qs = (q * scale).astype(q.dtype)
    kb = _blockify(k, bk)
    vb = _blockify(v, bk)
    q_pos = jnp.arange(s)
    nb = kb.shape[0]

    def body(dq_acc, inp):
        kblk, vblk, i = inp
        kblk_e = _expand_kv(kblk, g)
        vblk_e = _expand_kv(vblk, g)
        k_pos = i * bk + jnp.arange(bk)
        logits = jnp.einsum("bshd,bkhd->bshk", qs, kblk_e,
                            preferred_element_type=jnp.float32)
        mask = _score_mask(q_pos, k_pos, causal, window)
        logits = jnp.where(mask[None, :, None, :], logits, NEG)
        p = jnp.exp(logits - lse[..., None])                 # (B,S,Hq,bk)
        pc = p.astype(q.dtype)
        # dv: reduce query-head groups back to kv heads AFTER the big
        # einsum — (B,bk,Hq,D) is small (one block) so the group-sum is
        # cheap and stays local
        dv_h = jnp.einsum("bshk,bshd->bkhd", pc, do,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bshd,bkhd->bshk", do, vblk_e,
                        preferred_element_type=jnp.float32)
        ds = (p * (dp - delta[..., None]) * scale).astype(q.dtype)
        dq_acc = ctx.constrain(
            dq_acc + jnp.einsum("bshk,bkhd->bshd", ds, kblk_e,
                                preferred_element_type=jnp.float32),
            "attn_q")
        # ds already carries the 1/sqrt(d) factor -> use UNSCALED q for dk
        dk_h = jnp.einsum("bshk,bshd->bkhd", ds, q,
                          preferred_element_type=jnp.float32)
        dk_blk = dk_h.reshape(dk_h.shape[:2] + (hkv, g, d)).sum(3)
        dv_blk = dv_h.reshape(dv_h.shape[:2] + (hkv, g, d)).sum(3)
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, s, hq, d), jnp.float32)
    dq, (dkb, dvb) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(nb)))
    dq = dq.astype(q.dtype)
    dk = jnp.moveaxis(dkb, 0, 1).reshape(b, s, hkv, d).astype(k.dtype)
    dv = jnp.moveaxis(dvb, 0, 1).reshape(b, s, hkv, d).astype(v.dtype)
    return dq, dk, dv


flash_attention_jnp.defvjp(_flash_fwd, _flash_bwd)
