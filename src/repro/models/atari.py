"""The paper's agent networks (Mnih et al. 2013/2016, §5.1).

Conv 16x8x8/4 -> Conv 32x4x4/2 -> FC 256 -> heads; ReLU throughout.  Heads:
  * actor-critic: softmax policy + scalar value (shared trunk, Alg. 3)
  * value-based : one linear Q output per action (Alg. 1/2)
  * continuous  : Gaussian mean (linear) + variance (softplus) heads (§5.2.3)
  * recurrent   : 256-cell LSTM after the final hidden layer (A3C LSTM)

These are the networks used for the *learning* experiments (the paper's
actual claims); the assigned large architectures plug into the identical
algorithm layer via the TokenMDP policy interface.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm


def _init_conv(key, h, w, cin, cout):
    fan_in = h * w * cin
    return {
        "w": cm.trunc_normal(key, (h, w, cin, cout), (1.0 / fan_in) ** 0.5),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def _conv(p, x, stride):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def init_atari_params(key, n_actions: int, *, input_hw: int = 84,
                      in_channels: int = 4, lstm: bool = False,
                      continuous: bool = False) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {
        "conv1": _init_conv(ks[0], 8, 8, in_channels, 16),
        "conv2": _init_conv(ks[1], 4, 4, 16, 32),
    }
    # conv output size for 84x84: ((84-8)/4+1)=20 -> ((20-4)/2+1)=9 -> 9*9*32
    h1 = (input_hw - 8) // 4 + 1
    h2 = (h1 - 4) // 2 + 1
    flat = h2 * h2 * 32
    p["fc"] = cm.init_linear(ks[2], flat, 256, bias=True)
    d = 256
    if lstm:
        p["lstm"] = {
            "wx": cm.init_linear(ks[3], 256, 4 * 256, bias=True),
            "wh": cm.init_linear(ks[4], 256, 4 * 256),
        }
    if continuous:
        p["mu"] = cm.init_linear(ks[5], d, n_actions, bias=True,
                                 stddev=1e-2)
        p["sigma"] = cm.init_linear(ks[6], d, 1, bias=True, stddev=1e-2)
    else:
        p["policy"] = cm.init_linear(ks[5], d, n_actions, bias=True,
                                     stddev=1e-2)
    p["value"] = cm.init_linear(ks[7], d, 1, bias=True, stddev=1e-2)
    return p


def init_mlp_agent_params(key, obs_dim: int, n_actions: int, *,
                          hidden: int = 200, lstm: bool = False,
                          lstm_size: int = 128,
                          continuous: bool = False) -> Dict[str, Any]:
    """Low-dimensional (MuJoCo-proxy) agent: 200 ReLU -> (128 LSTM) -> heads
    (paper §5.2.3)."""
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"fc": cm.init_linear(ks[0], obs_dim, hidden,
                                              bias=True)}
    d = hidden
    if lstm:
        p["lstm"] = {
            "wx": cm.init_linear(ks[1], hidden, 4 * lstm_size, bias=True),
            "wh": cm.init_linear(ks[2], lstm_size, 4 * lstm_size),
        }
        d = lstm_size
    if continuous:
        p["mu"] = cm.init_linear(ks[3], d, n_actions, bias=True, stddev=1e-2)
        p["sigma"] = cm.init_linear(ks[4], d, 1, bias=True, stddev=1e-2)
    else:
        p["policy"] = cm.init_linear(ks[3], d, n_actions, bias=True,
                                     stddev=1e-2)
    p["value"] = cm.init_linear(ks[5], d, 1, bias=True, stddev=1e-2)
    return p


def lstm_cell(p, x, state):
    """Standard LSTM.  state = (h, c)."""
    h, c = state
    gates = cm.linear(p["wx"], x) + cm.linear(p["wh"], h)
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, (h, c)


def init_lstm_state(batch: int, size: int = 256):
    z = jnp.zeros((batch, size), jnp.float32)
    return (z, z)


def trunk(params, obs, lstm_state=None):
    """obs (B, H, W, C) pixels in [0,1] or (B, obs_dim) low-dim state."""
    if obs.ndim == 4:
        x = jax.nn.relu(_conv(params["conv1"], obs, 4))
        x = jax.nn.relu(_conv(params["conv2"], x, 2))
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(cm.linear(params["fc"], x))
    else:
        x = jax.nn.relu(cm.linear(params["fc"], obs))
    if "lstm" in params:
        if lstm_state is None:
            lstm_state = init_lstm_state(x.shape[0],
                                         params["lstm"]["wh"]["w"].shape[0])
        x, lstm_state = lstm_cell(params["lstm"], x, lstm_state)
    return x, lstm_state


def actor_critic_heads(params, feats) -> Dict[str, jnp.ndarray]:
    """Discrete A3C heads: log-policy + value."""
    logits = cm.linear(params["policy"], feats)
    value = cm.linear(params["value"], feats)[..., 0]
    return {"logits": logits, "value": value}


def gaussian_heads(params, feats) -> Dict[str, jnp.ndarray]:
    """Continuous A3C heads (§5.2.3): mu linear, sigma^2 = softplus."""
    mu = cm.linear(params["mu"], feats)
    sigma2 = jax.nn.softplus(cm.linear(params["sigma"], feats))[..., 0] + 1e-4
    value = cm.linear(params["value"], feats)[..., 0]
    return {"mu": mu, "sigma2": sigma2, "value": value}


def q_heads(params, feats) -> jnp.ndarray:
    """Value-based methods: one linear output per action."""
    return cm.linear(params["policy"], feats)
