"""Feed-forward blocks: gated (SwiGLU) and plain MLPs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm


def init_gated_mlp(key, d_model: int, d_ff: int) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "gate": cm.init_linear(ks[0], d_model, d_ff),
        "up": cm.init_linear(ks[1], d_model, d_ff),
        "down": cm.init_linear(ks[2], d_ff, d_model),
    }


def gated_mlp(p: dict, x: jnp.ndarray, *, act: str = "silu") -> jnp.ndarray:
    f = cm.ACTIVATIONS[act]
    return cm.linear(p["down"], f(cm.linear(p["gate"], x)) * cm.linear(p["up"], x))


def init_mlp(key, d_model: int, d_ff: int, *, bias: bool = True) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "fc1": cm.init_linear(ks[0], d_model, d_ff, bias=bias),
        "fc2": cm.init_linear(ks[1], d_ff, d_model, bias=bias),
    }


def mlp(p: dict, x: jnp.ndarray, *, act: str = "gelu") -> jnp.ndarray:
    f = cm.ACTIVATIONS[act]
    return cm.linear(p["fc2"], f(cm.linear(p["fc1"], x)))
