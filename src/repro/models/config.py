"""Model configuration: one dataclass drives every backbone family.

A model is a stack of *blocks* described by ``block_cycle`` (a short pattern
tiled over ``n_layers``), plus embeddings and heads.  Block kinds:

  attn        global causal self-attention + FFN (gated MLP or MoE)
  attn_local  sliding-window / chunked-local attention + FFN
  mamba2      Mamba2 SSD block (no separate FFN)
  mlstm       xLSTM matrix-memory block
  slstm       xLSTM scalar-memory block (true recurrence)

``shared_attn_every > 0`` (Zamba2) additionally applies a single *shared*
attention+FFN block after every k-th layer — same weights at every
application point, distinct KV caches.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    block_cycle: Tuple[str, ...] = ("attn",)
    source: str = ""                 # citation for the config

    norm: str = "rmsnorm"
    act: str = "silu"
    qkv_bias: bool = False
    rope_theta: float = 1e6
    rotary_dim: Optional[int] = None  # partial rotary (StableLM-2: 25%)
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None   # window for attn_local blocks

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01

    # SSM / xLSTM
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    lstm_expand: int = 2

    # hybrid (Zamba2)
    shared_attn_every: int = 0

    # VLM (Qwen2-VL M-RoPE)
    mrope_sections: Optional[Tuple[int, int, int]] = None

    # encoder-decoder (Whisper)
    is_encdec: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500          # audio frames after the (stubbed) conv

    # RL heads
    value_head: bool = True

    dtype: str = "bfloat16"
    remat: bool = True               # jax.checkpoint each block cycle in train

    # reduced smoke-variant factory -------------------------------------
    def reduced(self) -> "ModelConfig":
        """2-layer, d_model<=512, <=4-expert variant of the same family for
        CPU smoke tests (spec requirement)."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads,
                          n_heads * self.n_kv_heads // self.n_heads)) or 1
        n_kv = max(1, min(n_kv, n_heads))
        cyc = len(self.block_cycle)
        n_layers = cyc if cyc >= 2 else 2
        n_layers = min(n_layers, 4)  # keep tiny but cover the cycle
        changes = dict(
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=64 if self.head_dim else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            d_ff_expert=min(self.d_ff_expert, 128),
            # no-drop capacity in smoke: batched prefill and step decode
            # must route identically for the consistency tests
            capacity_factor=8.0,
            ssm_heads=min(self.ssm_heads, 4) if self.ssm_heads else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_chunk=16,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 32),
            shared_attn_every=2 if self.shared_attn_every else 0,
            sliding_window=16 if self.sliding_window else None,
            # rescale M-RoPE sections to the reduced head_dim (sum == hd/2)
            mrope_sections=(8, 12, 12) if self.mrope_sections else None,
            rotary_dim=16 if self.rotary_dim else None,
            dtype="float32",
            remat=False,
        )
        return dataclasses.replace(self, **changes)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_kinds(self) -> Tuple[str, ...]:
        reps = -(-self.n_layers // len(self.block_cycle))
        return (self.block_cycle * reps)[: self.n_layers]

    def param_count(self) -> int:
        """Total params N (for MODEL_FLOPS = 6·N·D roofline term)."""
        import math

        import jax
        from repro.models import model as m
        shapes = jax.eval_shape(
            lambda k: m.init_params(self, k), jax.random.key(0))
        return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        total = self.param_count()
        if not self.n_experts:
            return total
        per_expert = (3 * self.d_model * self.d_ff_expert)
        layers_with_moe = sum(1 for k in self.layer_kinds()
                              if k in ("attn", "attn_local"))
        inactive = (self.n_experts - self.top_k) * per_expert * layers_with_moe
        return total - inactive
