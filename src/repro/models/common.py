"""Common building blocks: norms, linears, embeddings, rotary embeddings.

Pure-JAX (no flax): parameters are plain pytrees of jnp arrays, every layer is
an ``init_*(key, ...) -> params`` / ``apply(params, x) -> y`` pair.  All
matmul-bearing ops take an optional ``dtype`` so the backbone can run bf16 on
TPU while accumulating in f32.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

Params = Any  # pytree of jnp arrays


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def trunc_normal(key, shape, stddev, dtype=jnp.float32):
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                stddev: Optional[float] = None, dtype=jnp.float32) -> Params:
    stddev = stddev if stddev is not None else 1.0 / math.sqrt(d_in)
    p = {"w": trunc_normal(key, (d_in, d_out), stddev, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jnp.ndarray, *, dtype=None) -> jnp.ndarray:
    w = p["w"].astype(dtype) if dtype is not None else p["w"]
    y = x @ w
    if "b" in p:
        b = p["b"].astype(y.dtype)
        y = y + b
    return y


def init_embedding(key, vocab: int, d_model: int, *, stddev: float = 0.02,
                   dtype=jnp.float32) -> Params:
    return {"table": trunc_normal(key, (vocab, d_model), stddev, dtype)}


def embed(p: Params, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["table"], ids, axis=0)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jnp.ndarray, *, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(dt)


def init_layernorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p: Params, x: jnp.ndarray, *, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(dt)


def apply_norm(kind: str, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if kind == "rmsnorm":
        # dispatch layer: fused Pallas fwd+vjp on TPU, the jnp reference
        # above elsewhere (lazy import — models stay importable standalone)
        from repro.kernels import dispatch
        return dispatch.rmsnorm(x, p["scale"])
    if kind == "layernorm":
        return layernorm(p, x)
    raise ValueError(f"unknown norm {kind}")


def init_norm(kind: str, d: int) -> Params:
    if kind == "rmsnorm":
        return init_rmsnorm(d)
    if kind == "layernorm":
        return init_layernorm(d)
    raise ValueError(f"unknown norm {kind}")


# ---------------------------------------------------------------------------
# rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def rope_cos_sin(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions (...,) int32 -> cos, sin of shape (..., head_dim // 2)."""
    inv = rope_freqs(head_dim, theta)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray,
               *, rotary_dim: Optional[int] = None) -> jnp.ndarray:
    """x (B, S, H, D); cos/sin (B, S, D'/2) broadcast over heads.

    ``rotary_dim`` < D applies partial rotary (StableLM-2 style: first 25% of
    head_dim rotated, rest passed through).
    """
    d = x.shape[-1]
    rd = rotary_dim if rotary_dim is not None else d
    xr, xp = x[..., :rd], x[..., rd:]
    x1, x2 = xr[..., : rd // 2], xr[..., rd // 2:]
    cos = cos[..., None, : rd // 2]
    sin = sin[..., None, : rd // 2]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    y = jnp.concatenate([out1, out2], axis=-1)
    if rd < d:
        y = jnp.concatenate([y, xp], axis=-1)
    return y.astype(x.dtype)


def mrope_cos_sin(positions: jnp.ndarray, head_dim: int, theta: float,
                  sections: Sequence[int]):
    """Multimodal RoPE (Qwen2-VL).

    positions: (3, B, S) int32 — temporal / height / width position ids.
    sections: per-axis sizes in half-dims (e.g. (16, 24, 24); sum = D/2).
    Each frequency slot takes its angle from the axis assigned by ``sections``
    (selected with a one-hot mix so it stays a single einsum).
    Returns cos, sin of shape (B, S, D/2).
    """
    assert positions.shape[0] == 3
    inv = rope_freqs(head_dim, theta)                       # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv    # (3, B, S, D/2)
    idx = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)
    ])                                                      # (D/2,)
    onehot = jax.nn.one_hot(idx, 3, dtype=jnp.float32)      # (D/2, 3)
    mixed = jnp.einsum("absd,da->bsd", ang, onehot)
    return jnp.cos(mixed), jnp.sin(mixed)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def silu(x):
    return x * jax.nn.sigmoid(x)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


ACTIVATIONS = {"silu": silu, "gelu": gelu, "relu": jax.nn.relu}
