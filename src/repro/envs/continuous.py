"""Continuous-control proxies for the MuJoCo experiments (paper §5.2.3).

PointMass2D: drive a point mass to a random target with 2-D force actions.
Pendulum: classic torque-limited swing-up (1-D action).

State observations are low-dimensional physical states (positions,
velocities, target), matching the paper's "physical state as input" setup.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.api import Env, auto_reset


class PMState(NamedTuple):
    pos: jnp.ndarray
    vel: jnp.ndarray
    target: jnp.ndarray
    t: jnp.ndarray


def make_pointmass(episode_len: int = 100, dt: float = 0.05) -> Env:

    def reset(key):
        k1, k2 = jax.random.split(key)
        s = PMState(jax.random.uniform(k1, (2,), minval=-1, maxval=1),
                    jnp.zeros((2,)),
                    jax.random.uniform(k2, (2,), minval=-1, maxval=1),
                    jnp.zeros((), jnp.int32))
        return s, _obs(s)

    def _obs(s: PMState):
        return jnp.concatenate([s.pos, s.vel, s.target]).astype(jnp.float32)

    def step(s: PMState, action, key):
        force = jnp.clip(action, -1, 1)
        vel = 0.95 * s.vel + dt * force
        pos = jnp.clip(s.pos + dt * vel * 10.0, -1.5, 1.5)
        dist = jnp.linalg.norm(pos - s.target)
        reward = -dist + jnp.where(dist < 0.1, 1.0, 0.0)
        t = s.t + 1
        done = t >= episode_len
        s2 = PMState(pos, vel, s.target, t)
        return s2, _obs(s2), reward, done

    return Env(name="pointmass2d", reset=reset, step=auto_reset(reset, step),
               obs_shape=(6,), n_actions=2, continuous=True,
               max_episode_len=episode_len)


class PendState(NamedTuple):
    theta: jnp.ndarray
    omega: jnp.ndarray
    t: jnp.ndarray


def make_pendulum(episode_len: int = 200, dt: float = 0.05) -> Env:
    g, m, l, max_torque, max_speed = 10.0, 1.0, 1.0, 2.0, 8.0

    def reset(key):
        k1, k2 = jax.random.split(key)
        s = PendState(jax.random.uniform(k1, (), minval=-jnp.pi,
                                         maxval=jnp.pi),
                      jax.random.uniform(k2, (), minval=-1.0, maxval=1.0),
                      jnp.zeros((), jnp.int32))
        return s, _obs(s)

    def _obs(s: PendState):
        return jnp.stack([jnp.cos(s.theta), jnp.sin(s.theta),
                          s.omega / max_speed]).astype(jnp.float32)

    def step(s: PendState, action, key):
        u = jnp.clip(action[0] * max_torque, -max_torque, max_torque)
        th = ((s.theta + jnp.pi) % (2 * jnp.pi)) - jnp.pi
        cost = th ** 2 + 0.1 * s.omega ** 2 + 0.001 * u ** 2
        omega = s.omega + (3 * g / (2 * l) * jnp.sin(th)
                           + 3.0 / (m * l ** 2) * u) * dt
        omega = jnp.clip(omega, -max_speed, max_speed)
        theta = s.theta + omega * dt
        t = s.t + 1
        done = t >= episode_len
        s2 = PendState(theta, omega, t)
        return s2, _obs(s2), -cost, done

    return Env(name="pendulum", reset=reset, step=auto_reset(reset, step),
               obs_shape=(3,), n_actions=1, continuous=True,
               max_episode_len=episode_len)
