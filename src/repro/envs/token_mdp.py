"""TokenMDP — the token-level MDP that turns any assigned LLM backbone into
an A3C policy (state = token prefix, action = next token).

This is the bridge between the paper's algorithm layer and the assigned
architectures: the policy π(a|s) is the LM head softmax, V(s) the value head,
and the environment rewards structured sequence continuation.  Default task
"successor": emitting token (prev + 1) mod V earns +1 (dense rewards, so
n-step returns propagate exactly as in the paper's Alg. 2/3).

Unlike the pixel envs this one is batch-native: states are (B, S) token
buffers advanced one position per step, matching the decode path
(``serve_step``) of the serving stack.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class TokenMDPState(NamedTuple):
    tokens: jnp.ndarray   # (B, S) rolling context buffer
    pos: jnp.ndarray      # () current length (clipped at S)
    t: jnp.ndarray        # () step in episode


class TokenMDP(NamedTuple):
    vocab: int
    context: int
    episode_len: int

    def reset(self, key, batch: int) -> TokenMDPState:
        first = jax.random.randint(key, (batch, 1), 0, self.vocab)
        tokens = jnp.zeros((batch, self.context), jnp.int32)
        tokens = tokens.at[:, :1].set(first)
        return TokenMDPState(tokens, jnp.ones((), jnp.int32),
                             jnp.zeros((), jnp.int32))

    def step(self, state: TokenMDPState, actions: jnp.ndarray):
        """actions (B,) emitted tokens.  Returns (state, reward (B,), done)."""
        prev = state.tokens[jnp.arange(actions.shape[0]),
                            jnp.maximum(state.pos - 1, 0)]
        reward = (actions == (prev + 1) % self.vocab).astype(jnp.float32)
        pos = jnp.minimum(state.pos, self.context - 1)
        tokens = state.tokens.at[:, pos].set(actions)
        t = state.t + 1
        done = t >= self.episode_len
        return TokenMDPState(tokens, pos + 1, t), reward, done

    def reward_for_sequence(self, tokens: jnp.ndarray) -> jnp.ndarray:
        """Teacher-forced per-position rewards for a full (B, S) sequence:
        reward[t] = 1 iff tokens[t+1] == tokens[t] + 1 (mod V).  Used by the
        batched train path (train_4k input shape)."""
        nxt = jnp.roll(tokens, -1, axis=1)
        r = (nxt == (tokens + 1) % self.vocab).astype(jnp.float32)
        return r.at[:, -1].set(0.0)
