"""GridMaze — Labyrinth proxy (paper §5.2.4).

Randomly generated maze each episode: walls, apples (+1, consumed) and one
portal (+10, agent respawns and apples regenerate).  Episode is time-limited.
Observation is the full grid as a (H, W, 4) one-hot image (walls, apples,
portal, agent) — a visual input, like Labyrinth's RGB frames, consumable by
the paper's conv net.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.api import Env, auto_reset


class MazeState(NamedTuple):
    walls: jnp.ndarray     # (H, W) bool
    apples: jnp.ndarray    # (H, W) bool
    portal: jnp.ndarray    # (2,) int32
    pos: jnp.ndarray       # (2,) int32
    apples0: jnp.ndarray   # (H, W) bool — regenerated on portal entry
    t: jnp.ndarray         # () int32


def make(size: int = 9, wall_density: float = 0.2, n_apples: int = 5,
         episode_len: int = 200) -> Env:
    hw = size

    def _random_free_cell(key, walls):
        """Sample a cell, biased away from walls (resample once)."""
        k1, k2 = jax.random.split(key)
        flat_free = (~walls).reshape(-1).astype(jnp.float32)
        idx = jax.random.categorical(k1, jnp.log(flat_free + 1e-9))
        return jnp.stack([idx // hw, idx % hw]).astype(jnp.int32)

    def reset(key):
        k_w, k_a, k_p, k_s = jax.random.split(key, 4)
        walls = jax.random.bernoulli(k_w, wall_density, (hw, hw))
        # keep borders open is unnecessary: movement clamps to grid
        apple_logits = jnp.where(walls.reshape(-1), -1e9, 0.0)
        apple_idx = jax.random.choice(k_a, hw * hw, (n_apples,),
                                      replace=False,
                                      p=jax.nn.softmax(apple_logits))
        apples = jnp.zeros((hw, hw), bool).reshape(-1).at[apple_idx] \
            .set(True).reshape(hw, hw)
        portal = _random_free_cell(k_p, walls | apples)
        pos = _random_free_cell(k_s, walls)
        walls = walls.at[pos[0], pos[1]].set(False)
        walls = walls.at[portal[0], portal[1]].set(False)
        state = MazeState(walls, apples, portal, pos, apples,
                          jnp.zeros((), jnp.int32))
        return state, _obs(state)

    def _obs(s: MazeState):
        agent = jnp.zeros((hw, hw), bool).at[s.pos[0], s.pos[1]].set(True)
        portal = jnp.zeros((hw, hw), bool).at[s.portal[0], s.portal[1]] \
            .set(True)
        return jnp.stack([s.walls, s.apples, portal, agent],
                         axis=-1).astype(jnp.float32)

    MOVES = jnp.array([[-1, 0], [1, 0], [0, -1], [0, 1]], jnp.int32)

    def step(s: MazeState, action, key):
        nxt = jnp.clip(s.pos + MOVES[action], 0, hw - 1)
        blocked = s.walls[nxt[0], nxt[1]]
        pos = jnp.where(blocked, s.pos, nxt)

        got_apple = s.apples[pos[0], pos[1]]
        apples = s.apples.at[pos[0], pos[1]].set(False)
        got_portal = jnp.all(pos == s.portal)

        # portal: respawn agent at a random cell, apples regenerate
        respawn = _random_free_cell(key, s.walls)
        pos = jnp.where(got_portal, respawn, pos)
        apples = jnp.where(got_portal, s.apples0, apples)

        reward = got_apple.astype(jnp.float32) + 10.0 * got_portal
        t = s.t + 1
        done = t >= episode_len
        s2 = MazeState(s.walls, apples, s.portal, pos, s.apples0, t)
        return s2, _obs(s2), reward, done

    return Env(name=f"gridmaze{size}", reset=reset,
               step=auto_reset(reset, step), obs_shape=(hw, hw, 4),
               n_actions=4, max_episode_len=episode_len)
