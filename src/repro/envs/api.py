"""Functional environment interface.

Every env is a pair of pure functions so rollouts can live inside
``lax.scan`` / ``vmap``:

  reset(key)               -> (state, obs)
  step(state, action, key) -> (state, obs, reward, done)

``done`` auto-resets inside ``step`` (the returned state/obs are from the
fresh episode) so parallel actors never have to synchronize on episode
boundaries — matching the paper's per-thread independent episode streams.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple


@dataclasses.dataclass(frozen=True)
class Env:
    name: str
    reset: Callable  # (key) -> (state, obs)
    step: Callable   # (state, action, key) -> (state, obs, reward, done)
    obs_shape: Tuple[int, ...]
    n_actions: int           # discrete count, or action dim if continuous
    continuous: bool = False
    max_episode_len: int = 1000


def flatten_obs(env: "Env") -> "Env":
    """Flatten image observations to a vector (for the low-dim MLP trunk —
    the CPU-scale stand-in for the conv trunk; see DESIGN.md §7)."""
    import jax.numpy as jnp
    import numpy as np

    flat = int(np.prod(env.obs_shape))

    def reset(key):
        s, o = env.reset(key)
        return s, o.reshape(flat)

    def step(state, action, key):
        s, o, r, d = env.step(state, action, key)
        return s, o.reshape(flat), r, d

    return dataclasses.replace(env, reset=reset, step=step,
                               obs_shape=(flat,))


def auto_reset(reset_fn, step_fn):
    """Wrap a (reset, step) pair so ``done`` restarts the episode."""
    import jax
    import jax.numpy as jnp

    def step(state, action, key):
        k_step, k_reset = jax.random.split(key)
        next_state, obs, reward, done = step_fn(state, action, k_step)
        fresh_state, fresh_obs = reset_fn(k_reset)
        state_out = jax.tree.map(
            lambda a, b: jnp.where(
                jnp.reshape(done, (1,) * a.ndim) if a.ndim else done, b, a),
            next_state, fresh_state)
        obs_out = jnp.where(done, fresh_obs, obs)
        return state_out, obs_out, reward, done

    return step
