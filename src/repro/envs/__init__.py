from repro.envs.api import Env  # noqa: F401
from repro.envs import catch, continuous, gridmaze, token_mdp  # noqa: F401

REGISTRY = {
    "catch": lambda: catch.make(),
    "gridmaze": lambda: gridmaze.make(),
    "pointmass": lambda: continuous.make_pointmass(),
    "pendulum": lambda: continuous.make_pendulum(),
}


def make(name: str) -> Env:
    return REGISTRY[name]()
