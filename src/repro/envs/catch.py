"""Catch — minimal Atari proxy (pixel observations, sparse terminal reward).

A ball falls from a random column; the agent moves a paddle (left/stay/right)
along the bottom row.  +1 for catching, -1 for missing.  Episode length =
grid height.  Used for the paper's Atari-domain learning-speed experiments
(Fig. 1 analogue) at CPU scale.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.api import Env, auto_reset


class CatchState(NamedTuple):
    ball: jnp.ndarray     # (2,) row, col
    paddle: jnp.ndarray   # () col
    t: jnp.ndarray


def make(rows: int = 10, cols: int = 5) -> Env:

    def reset(key):
        col = jax.random.randint(key, (), 0, cols)
        s = CatchState(jnp.array([0, 0], jnp.int32).at[1].set(col),
                       jnp.array(cols // 2, jnp.int32),
                       jnp.zeros((), jnp.int32))
        return s, _obs(s)

    def _obs(s: CatchState):
        g = jnp.zeros((rows, cols), jnp.float32)
        g = g.at[s.ball[0], s.ball[1]].set(1.0)
        g = g.at[rows - 1, s.paddle].set(1.0)
        return g[..., None]

    def step(s: CatchState, action, key):
        paddle = jnp.clip(s.paddle + action - 1, 0, cols - 1)
        ball = s.ball.at[0].add(1)
        done = ball[0] >= rows - 1
        caught = done & (ball[1] == paddle)
        reward = jnp.where(done, jnp.where(caught, 1.0, -1.0), 0.0)
        s2 = CatchState(ball, paddle, s.t + 1)
        return s2, _obs(s2), reward, done

    return Env(name=f"catch{rows}x{cols}", reset=reset,
               step=auto_reset(reset, step), obs_shape=(rows, cols, 1),
               n_actions=3, max_episode_len=rows)
