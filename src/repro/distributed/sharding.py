"""Sharding rules for the production mesh.

Mesh axes:
  pod    — outer replica groups (multi-pod only; delayed-sync merge axis)
  data   — actor-learner groups (the paper's "threads"); batch + FSDP axis
  model  — tensor parallelism: heads / d_ff / vocab / experts / SSM heads

Parameter layout is 2-D sharded (FSDP x TP), MaxText-style: the contracting
d_model dim of every big matrix lives on ``data``, the parallel dim (heads,
ffn, vocab, experts) on ``model``.  Caches for decode are context-parallel:
the sequence dim of KV caches is sharded (over ``model``, and additionally
over ``data`` when the batch is too small to use it).

All rules are name-based on the pytree path, with a leading ``None`` added
automatically for stacked (scanned) layers.
"""
from __future__ import annotations

import re
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Batch-parallel axes: ('pod','data') on the multi-pod mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# kernel-dispatch partitioning (consumed by repro.kernels.dispatch)
# ---------------------------------------------------------------------------

class AttnShardSpec(NamedTuple):
    """How to shard_map the attention kernels over a mesh.

    ``batch`` is the PartitionSpec entry for the batch dim (axis name, tuple
    of names, or None for replicated); ``heads`` likewise for the head dims.
    Hashable by construction so dispatch can use it as a jit static arg.
    """
    mesh: Any              # jax.sharding.Mesh
    batch: Any             # None | str | tuple of axis names
    heads: Optional[str]   # None | "model"

    @property
    def qo(self) -> P:
        """q / o / do / dq: (B, S, Hq, D) — batch on data, heads on model."""
        return P(self.batch, None, self.heads, None)

    @property
    def kv(self) -> P:
        """k / v / dk / dv and KV caches: (B, S|L, Hkv, D)."""
        return P(self.batch, None, self.heads, None)

    @property
    def lse(self) -> P:
        """lse / delta residuals: (B, Hq, S)."""
        return P(self.batch, self.heads, None)

    @property
    def q_decode(self) -> P:
        """decode q / o: (B, Hq, D)."""
        return P(self.batch, self.heads, None)

    @property
    def kpos_decode(self) -> P:
        """per-slot kpos (B, L): batch sharded with q, slots replicated."""
        return P(self.batch, None)

    @property
    def pos_decode(self) -> P:
        """per-slot pos (B,)."""
        return P(self.batch)


class DecodeCPSpec(NamedTuple):
    """How to shard_map the context-parallel (flash-decoding) decode kernel.

    The KV cache's *sequence* dim is sharded over ``seq_axes`` (the
    ``decode_cp`` rule's axes — 'model', plus the data axes for batch=1
    long-context decode); each shard runs the partials kernel over its
    cache slice and the combine is a psum of (m, l, acc) over ``seq_axes``.
    Heads stay shard-local (the model axis is spent on the sequence).
    Hashable by construction so dispatch can use it as a jit static arg.
    """
    mesh: Any                        # jax.sharding.Mesh
    batch: Any                       # None | str | tuple of axis names
    seq_axes: Tuple[str, ...]        # cache sequence sharding axes

    @property
    def _seq(self):
        return self.seq_axes if len(self.seq_axes) > 1 else self.seq_axes[0]

    @property
    def q_decode(self) -> P:
        """decode q / o: (B, Hq, D) — replicated over the seq axes."""
        return P(self.batch, None, None)

    @property
    def kv(self) -> P:
        """KV caches (B, L, Hkv, D): sequence dim sharded."""
        return P(self.batch, self._seq, None, None)

    @property
    def new_kv(self) -> P:
        """The step's new k/v token (B, 1, Hkv, D): replicated over seq."""
        return P(self.batch, None, None, None)

    @property
    def kpos(self) -> P:
        """per-slot kpos (B, L): batch with q, slots sliced along the same
        seq sharding as the cache."""
        return P(self.batch, self._seq)

    @property
    def pos_decode(self) -> P:
        """per-slot pos (B,): replicated over the seq axes."""
        return P(self.batch)


def decode_cp_spec(rule: dict, *, batch: int) -> DecodeCPSpec:
    """Layout (no alignment policy) for the context-parallel decode path:
    how the ``decode_cp`` rule from :func:`decode_rules` partitions the
    cache and the step tensors over its mesh.  The single source for both
    the model-layer cache write and the dispatch-layer combine — they must
    agree on the cache's partitioning."""
    mesh = rule["mesh"]
    seq_axes = tuple(rule["seq_axes"])
    dp_axes = tuple(rule.get("dp_axes") or ())
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    dp: Any = dp_axes if (dp_axes and dp_size > 1
                          and batch % dp_size == 0) else None
    if isinstance(dp, tuple) and len(dp) == 1:
        dp = dp[0]
    return DecodeCPSpec(mesh, dp, seq_axes)


def decode_cp_shard_spec(rule: dict, *, batch: int, length: int
                         ) -> Tuple[Optional[DecodeCPSpec], str]:
    """Dispatch policy for the unified context-parallel decode path.

    Returns (spec, "") or (None, reason) when the Pallas combine cannot
    serve this call — cache length not divisible into MXU-aligned local
    slices.  (The cache *write* only needs divisibility, so it uses
    :func:`decode_cp_spec` directly.)
    """
    seq_axes = tuple(rule["seq_axes"])
    n_shards = int(rule["n_shards"])
    if length % n_shards != 0:
        return None, (f"cache length {length} does not divide over the "
                      f"{n_shards}-shard seq axes {seq_axes}")
    l_loc = length // n_shards
    if n_shards > 1 and (l_loc < 128 or l_loc % 128 != 0):
        return None, (f"local cache slice {l_loc} (of {length} over "
                      f"{n_shards} shards) not MXU-aligned (need a "
                      "multiple of 128)")
    return decode_cp_spec(rule, batch=batch), ""


class RowShardSpec(NamedTuple):
    """Row-block shard_map spec for the fused rmsnorm: the (rows, d)
    activation's row dim over ``axes``, scale replicated.  Hashable so
    dispatch can use it as a jit static arg."""
    mesh: Any
    axes: Tuple[str, ...]

    @property
    def rows(self) -> P:
        return P(self.axes if len(self.axes) > 1 else self.axes[0], None)

    @property
    def rstd(self) -> P:
        """per-row residual (rows,) f32."""
        return P(self.axes if len(self.axes) > 1 else self.axes[0])


def _spec_mentions(spec, axis: str, dim: int) -> bool:
    """Does PartitionSpec ``spec`` put ``axis`` on dimension ``dim``?"""
    entries = tuple(spec)
    if dim >= len(entries):
        return False
    e = entries[dim]
    return axis in e if isinstance(e, tuple) else e == axis


def rmsnorm_shard_spec(mesh, *, rows: int, rules=None
                       ) -> Tuple[Optional[RowShardSpec], str]:
    """Partitioning for the shard_map'd fused rmsnorm.

    Rows (= batch*seq) are normalized independently, so they shard over
    every mesh axis whose product divides them; scale is replicated and
    the vjp's dscale is psum'd over the row axes.  The one layout this
    must NOT touch is the Megatron-SP seq-parallel residual: there the
    activation's seq dim is already sharded over 'model', and a row-block
    shard_map would re-gather it — that stays an explicit fallback.
    """
    msize = mesh.shape["model"] if "model" in mesh.axis_names else 1
    r = (rules or {}).get("residual")
    if r is not None and msize > 1 and \
            _spec_mentions(getattr(r, "spec", r), "model", 1):
        return None, ("seq-parallel residual shards rows over 'model'; "
                      "row-block shard_map would re-gather the residual "
                      "stream (explicit fallback, see DESIGN.md "
                      "§kernel-dispatch)")
    axes = tuple(a for a in mesh.axis_names if mesh.shape[a] > 1)
    if not axes:
        # degenerate 1-device mesh: replicated (benches may force it)
        return RowShardSpec(mesh, tuple(mesh.axis_names)[:1] or ("data",)), ""
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if rows % n != 0 or rows // n < 8:
        return None, (f"rows={rows} do not divide into >=8-row blocks "
                      f"over the {n}-device mesh axes {axes}")
    return RowShardSpec(mesh, axes), ""


def attention_shard_spec(mesh, *, batch: int, n_q_heads: int,
                         n_kv_heads: int
                         ) -> Tuple[Optional[AttnShardSpec], str]:
    """Partitioning for the shard_map'd Pallas attention kernels.

    Batch goes over the data axes, q *and* kv heads over ``model`` —
    contiguous head blocks keep every GQA group local to its shard (shard j
    owns q heads [j*hq/m, (j+1)*hq/m) and exactly the kv heads they read,
    because hq/m = g * hkv/m).  The sequence dim stays unsharded: the flash
    grid scans it on-chip, and causal/window masks use absolute positions.

    Returns (spec, "") or (None, reason) when the mesh axes divide neither
    tensor dim — the dispatcher records the reason and falls back to jnp.
    """
    d_ax = data_axes(mesh)
    d_size = 1
    for a in d_ax:
        d_size *= mesh.shape[a]
    m_size = mesh.shape["model"] if "model" in mesh.axis_names else 1
    if d_size == 1 and m_size == 1:
        # degenerate 1-device mesh: everything replicated (benches force
        # the shard_map path through it; auto dispatch never picks it)
        return AttnShardSpec(mesh, None, None), ""

    dp: Any = d_ax if (d_ax and batch % d_size == 0 and d_size > 1) else None
    if isinstance(dp, tuple) and len(dp) == 1:
        dp = dp[0]
    heads = None
    if m_size > 1:
        if n_q_heads % m_size == 0 and n_kv_heads % m_size == 0:
            heads = "model"
        else:
            return None, (f"heads ({n_q_heads}q/{n_kv_heads}kv) do not "
                          f"divide the {m_size}-way model axis")
    if dp is None and heads is None:
        return None, (f"mesh axes divide neither batch={batch} "
                      f"(data={d_size}) nor heads (model={m_size})")
    return AttnShardSpec(mesh, dp, heads), ""


# ---------------------------------------------------------------------------
# parameter sharding
# ---------------------------------------------------------------------------

# (regex on path, spec for the UNSTACKED param). "F" = fsdp/data axis,
# "M" = model axis; resolved per-mesh.
_PARAM_RULES = [
    (r"embed/table$",              ("M", "F")),
    (r"lm_head/w$",                ("F", "M")),
    (r"value_head/w$",             ("F", None)),
    (r"(wq|wk|wv|up_x|up_z|w_in|ff_gate|ff_up)/w$", ("F", "M")),
    (r"(wo|down|ff_down|out_proj)/w$",              ("M", "F")),
    (r"(gate|up)/w$",              ("F", "M")),
    (r"(mlp/fc1|fc1)/w$",          ("F", "M")),
    (r"(mlp/fc2|fc2)/w$",          ("M", "F")),
    (r"in_proj/w$",                ("F", "M")),
    (r"(wq|wk|wv)/b$",             ("M",)),
    (r"(gate|up|fc1)/b$",          ("M",)),
    (r"router$",                   ("F", None)),
    # expert weights: EP over model only (shard_map all-to-all dispatch
    # owns them per-device; replicating over data costs ~MBs and removes a
    # per-layer gather — perf iter #4)
    (r"w_(gate|up)$",              ("M", None, None)),  # (E, d, f)
    (r"w_down$",                   ("M", None, None)),  # (E, f, d)
    (r"conv_w$",                   (None, "M")),
    (r"conv_b$",                   ("M",)),
    (r"(A_log|D|dt_bias)$",        ("M",)),
    (r"(mamba|mlstm)/norm/scale$", ("M",)),
    (r"w_[if]/w$",                 ("F", None)),
    # sLSTM recurrent weights: sharded (iter #9 measured the alternative —
    # replicating them moves the per-step collective from a 1 MB activation
    # psum to a 16.8 MB gradient-accumulator psum, 2x worse; the real fix
    # is a shard_map'd recurrence with deferred dr reduction, future work)
    (r"slstm/r$",                  (None, "F", "M")),   # (H, hd, 4hd)
]


def _resolve(spec_tpl, mesh: Mesh, *, fsdp: bool = True):
    d_ax = data_axes(mesh)
    out = []
    for s in spec_tpl:
        if s == "M":
            out.append("model")
        elif s == "F":
            # newer jax canonicalizes P(('data',)) to P('data'); 0.4.x
            # keeps the 1-tuple — emit the canonical bare name ourselves
            ax = d_ax if (fsdp and d_ax) else None
            out.append(ax[0] if isinstance(ax, tuple) and len(ax) == 1
                       else ax)
        else:
            out.append(None)
    return P(*out)


def param_spec(path_str: str, leaf, mesh: Mesh, *, stacked: bool,
               fsdp: bool = True) -> P:
    for pat, tpl in _PARAM_RULES:
        if re.search(pat, path_str):
            spec = _resolve(tpl, mesh, fsdp=fsdp)
            if len(spec) > leaf.ndim:
                return P()  # degenerate (smoke-size) leaf: replicate
            if stacked and leaf.ndim == len(spec) + 1:
                return P(*((None,) + tuple(spec)))
            return spec
    return P()  # norms, small biases, scalars: replicated


def _divisible(leaf, spec: P, mesh: Mesh) -> bool:
    for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if dim % size != 0:
            return False
    return True


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_tree,
                    *, fsdp: bool = True):
    """params_tree: pytree of ShapeDtypeStruct (or arrays)."""
    from repro.models import model as M
    stacked = M._use_scan(cfg)

    def one(path, leaf):
        ps = _path_str(path)
        is_stacked = stacked and ps.startswith("layers")
        spec = param_spec(ps, leaf, mesh, stacked=is_stacked, fsdp=fsdp)
        if not _divisible(leaf, spec, mesh):
            # drop offending axes rather than fail (e.g. 4-head xLSTM)
            new = []
            for dim, ax in zip(leaf.shape,
                               tuple(spec) + (None,) * leaf.ndim):
                if ax is None:
                    new.append(None)
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = 1
                for a in axes:
                    size *= mesh.shape[a]
                new.append(ax if dim % size == 0 else None)
            spec = P(*new)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_tree)


# ---------------------------------------------------------------------------
# batch and cache sharding
# ---------------------------------------------------------------------------

def batch_shardings(mesh: Mesh, batch_tree, *, batch_size: int):
    """Shard the leading batch dim over the data axes (when divisible)."""
    d_ax = data_axes(mesh)
    dp_size = 1
    for a in d_ax:
        dp_size *= mesh.shape[a]
    dp: Any = d_ax if (d_ax and batch_size % dp_size == 0) else None

    def one(path, leaf):
        ps = _path_str(path)
        if ps.endswith("positions"):               # (3, B, S)
            return NamedSharding(mesh, P(None, dp, None))
        return NamedSharding(mesh, P(*((dp,) + (None,) * (leaf.ndim - 1))))

    return jax.tree_util.tree_map_with_path(one, batch_tree)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_tree,
                    *, batch_size: int):
    """Context-parallel decode caches.

    KV caches (B, L, Hkv, hd): seq dim over 'model'; batch over data axes
    when divisible, otherwise the seq dim additionally takes the data axes
    (batch=1 long-context decode -> full-mesh context parallelism).
    SSM/LSTM states: shard the head/state dims over 'model' when divisible.
    """
    from repro.models import model as M
    stacked = M._use_scan(cfg)
    d_ax = data_axes(mesh)
    dp_size = 1
    for a in d_ax:
        dp_size *= mesh.shape[a]
    batch_ok = bool(d_ax) and batch_size % dp_size == 0
    b_ax: Any = d_ax if batch_ok else None
    seq_ax: Any = "model" if batch_ok else (d_ax + ("model",)
                                            if d_ax else "model")

    def shard_state(ps, leaf, base_rank_offset):
        """SSM / LSTM states: try model on the largest non-batch dim."""
        nd = leaf.ndim
        spec = [None] * nd
        if nd >= 1:
            spec[base_rank_offset] = b_ax          # batch dim
        # choose the last dim divisible by model size for the model axis
        for d in range(nd - 1, base_rank_offset, -1):
            if leaf.shape[d] % mesh.shape["model"] == 0 and \
                    leaf.shape[d] >= mesh.shape["model"]:
                spec[d] = "model"
                break
        return P(*spec)

    def one(path, leaf):
        ps = _path_str(path)
        off = 1 if (stacked and ps.startswith("layers")) else 0
        if leaf.ndim == 0 or ps.endswith("index"):
            return NamedSharding(mesh, P())
        if re.search(r"/(kp|vp|kps|vps)$", ps) and leaf.ndim >= 4:
            # paged pool (P, page_size, Hkv, hd) [+leading stack dim] and
            # its rank-matched scale pools (P, page_size, Hkv, 1): no
            # batch dim to give the data axes.  Replicated-cache layout:
            # heads on 'model' (the same dim the gathered dense view
            # shards); context-parallel layout: the page dim takes the seq
            # axes — page boundaries are 128-multiples, so whole pages per
            # shard keep the gathered slices MXU-aligned.
            hkv = leaf.shape[off + 2]
            n_pages = leaf.shape[off + 0]
            m_size = mesh.shape["model"] if "model" in mesh.axis_names else 1
            if batch_ok:
                heads = "model" if (m_size > 1 and hkv % m_size == 0
                                    and hkv >= m_size) else None
                spec = (None,) * off + (None, None, heads, None)
            else:
                axes = seq_ax if isinstance(seq_ax, tuple) else (seq_ax,)
                size = 1
                for a in axes:
                    size *= mesh.shape[a]
                pages = seq_ax if n_pages % size == 0 else None
                spec = (None,) * off + (pages, None, None, None)
            return NamedSharding(mesh, P(*spec))
        if re.search(r"/pt$", ps):
            # page tables are gather/scatter indices — replicate
            return NamedSharding(mesh, P())
        if re.search(r"/(k|v|ks|vs)$", ps) and leaf.ndim >= 4:
            # (B, L, Hkv, hd) [+leading stack dim]; int8 caches carry
            # rank-matched scale leaves (B, L, Hkv, 1) that take the same
            # (batch, seq) spec — the trailing singleton stays unsharded
            cache_len = leaf.shape[off + 1]
            seq = seq_ax
            # guard divisibility of the seq dim
            axes = seq if isinstance(seq, tuple) else (seq,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if cache_len % size != 0:
                seq = None
            spec = (None,) * off + (b_ax, seq, None, None)
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, shard_state(ps, leaf, off))

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def decode_rules(cfg: ModelConfig, mesh: Mesh, *, batch_size: int):
    """Context-parallel decode (flash-decoding combine) rule set."""
    d_ax = data_axes(mesh)
    dp_size = 1
    for a in d_ax:
        dp_size *= mesh.shape[a]
    batch_ok = bool(d_ax) and batch_size % dp_size == 0
    seq_axes = ("model",) if batch_ok else tuple(d_ax) + ("model",)
    n = 1
    for a in seq_axes:
        n *= mesh.shape[a]
    return {"decode_cp": {"mesh": mesh, "seq_axes": seq_axes,
                          "dp_axes": d_ax if batch_ok else (),
                          "n_shards": n}}


def opt_state_shardings(cfg: ModelConfig, mesh: Mesh, params_shardings):
    """Optimizer state mirrors the parameter layout (g has params' shape)."""
    return {"g": params_shardings}


def activation_rules(mesh: Mesh, *, batch_size: int,
                     cfg: ModelConfig = None):
    """Logical activation constraints installed via repro.distributed.ctx."""
    d_ax = data_axes(mesh)
    dp_size = 1
    for a in d_ax:
        dp_size *= mesh.shape[a]
    dp: Any = d_ax if (d_ax and batch_size % dp_size == 0) else None
    msize = mesh.shape["model"]
    rules = {
        # Megatron-style sequence parallelism for the saved residual stream
        "residual": NamedSharding(mesh, P(dp, "model", None)),
        # expert-parallel MoE buffer (E, C, d)
        "expert_buffer": NamedSharding(mesh, P("model", None, None)),
        # Megatron-TP attention: heads local to the model axis
        "attn_q": NamedSharding(mesh, P(dp, None, "model", None)),
        "attn_kv": NamedSharding(mesh, P(dp, None, "model", None)),
    }
    if cfg is not None:
        # when the head count does not divide the TP degree, head-local
        # attention is impossible; pin the SEQUENCE dim instead
        # (context-parallel flash: q rows stay local, KV blocks broadcast
        # per scan step — perf iters #7/#8).  Forcing replication here
        # regressed minicpm/llama4 prefill 5-19x; free GSPMD choice left
        # whisper prefill at 2.1 TB of per-block psums.
        seq_sharded = NamedSharding(mesh, P(dp, "model", None, None))
        if cfg.n_heads % msize != 0:
            rules["attn_q"] = seq_sharded
        if cfg.n_kv_heads % msize != 0:
            rules["attn_kv"] = seq_sharded
    if cfg is not None and cfg.n_experts:
        rules["moe_ep"] = {"mesh": mesh, "tp": msize,
                           "dp_axes": d_ax if dp is not None else ()}
    return rules
