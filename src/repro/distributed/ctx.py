"""Sharding context: lets mesh-agnostic model code request activation
sharding constraints that only take effect when the launcher has installed a
rule set (no-ops on single-device CPU runs, so tests/benches are unaffected).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax

_state = threading.local()


def current_rules() -> Optional[Dict[str, jax.sharding.PartitionSpec]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def sharding_rules(rules: Dict[str, jax.sharding.PartitionSpec]):
    """rules: logical-name -> PartitionSpec (e.g. "residual", "expert_buffer").
    Installed by the launcher around trace/lower time."""
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def constrain(x, name: str):
    """Apply the named activation constraint if a rule set is installed."""
    rules = current_rules()
    if rules is None or name not in rules:
        return x
    return jax.lax.with_sharding_constraint(x, rules[name])
