"""Sharding context: lets mesh-agnostic model code request activation
sharding constraints that only take effect when the launcher has installed a
rule set (no-ops on single-device CPU runs, so tests/benches are unaffected).

Also carries the *dispatch mesh*: the mesh the launcher is lowering for.
The kernel dispatch layer (``repro.kernels.dispatch``) keys backend
selection off this mesh's device platform — the lowering *target* — rather
than ``jax.default_backend()``, so a host process lowering for a TPU mesh
picks the same kernels the TPU mesh will run.

Dispatch resolves at *trace* time, but jax caches traces by function
identity — without countermeasures, re-lowering one jitted callable under
a different mesh would replay the stale dispatch decision baked into the
cached trace.  ``use_mesh`` and ``sharding_rules`` therefore install a
*dispatch token* (a hashable digest of the mesh + rule set) into jax's jit
cache key via ``compat.set_trace_token``; switching meshes changes the key
and the callable re-traces, re-running dispatch resolution.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax

from repro import compat

_state = threading.local()


# ---------------------------------------------------------------------------
# dispatch trace token
# ---------------------------------------------------------------------------

def _freeze(v):
    """Hashable digest of a rules/mesh value (dicts recursed, arrays et al
    collapsed to repr — the token only needs equality, not round-tripping)."""
    try:
        hash(v)
        return v
    except TypeError:
        pass
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze(x)) for k, x in v.items()))
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    return repr(v)


def dispatch_token():
    """The current dispatch-relevant state as a jit-cache-key component
    (None when no mesh or rules are installed — nothing to assert)."""
    mesh = getattr(_state, "mesh", None)
    rules = getattr(_state, "rules", None)
    if mesh is None and rules is None:
        return None
    return (compat._TOKEN_TAG, _freeze(mesh), _freeze(rules))


def _install_token():
    return compat.set_trace_token(dispatch_token())


# compat.set_mesh re-asserts this token around Mesh context transitions
# (Mesh.__enter__/__exit__ rebuild the carrier state and would drop it)
compat.register_trace_token_provider(dispatch_token)


def current_rules() -> Optional[Dict[str, jax.sharding.PartitionSpec]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def sharding_rules(rules: Dict[str, jax.sharding.PartitionSpec]):
    """rules: logical-name -> PartitionSpec (e.g. "residual", "expert_buffer").
    Installed by the launcher around trace/lower time.  Folds the rule set
    into the jit cache key (see module docstring) so cached traces are not
    replayed across rule-set changes."""
    prev = current_rules()
    _state.rules = rules
    tok = _install_token()
    try:
        yield
    finally:
        _state.rules = prev
        compat.restore_trace_token(tok)


def constrain(x, name: str):
    """Apply the named activation constraint if a rule set is installed."""
    rules = current_rules()
    if rules is None or name not in rules:
        return x
    return jax.lax.with_sharding_constraint(x, rules[name])


# ---------------------------------------------------------------------------
# dispatch mesh
# ---------------------------------------------------------------------------

def current_mesh():
    """The mesh installed by the launcher (None on plain single-device runs)."""
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh):
    """Install ``mesh`` as the kernel-dispatch target around trace/lower time.

    Orthogonal to ``compat.set_mesh`` (which feeds jax's sharding machinery):
    this one makes the mesh *visible* to the dispatch layer so it can
    shard_map the Pallas kernels over it and resolve the target platform,
    and folds the mesh into the jit cache key (see module docstring) so one
    jitted callable re-lowered under a different mesh re-resolves dispatch
    instead of replaying the stale trace."""
    prev = current_mesh()
    _state.mesh = mesh
    tok = _install_token()
    try:
        yield mesh
    finally:
        _state.mesh = prev
        compat.restore_trace_token(tok)


def mesh_platform(mesh) -> str:
    """Device platform of ``mesh`` ("cpu"/"tpu"/"gpu").  AbstractMesh carries
    no devices; assume the local default backend in that case."""
    devs = getattr(mesh, "devices", None)
    if devs is None:
        return jax.default_backend()
    return devs.flat[0].platform


def current_platform() -> str:
    """Platform of the lowering target: the dispatch mesh's device platform
    when a mesh is installed, else the process default backend."""
    mesh = current_mesh()
    if mesh is None:
        return jax.default_backend()
    return mesh_platform(mesh)


def mesh_devices(mesh) -> int:
    """Total device count of a (possibly abstract) mesh."""
    n = 1
    for s in dict(mesh.shape).values():
        n *= s
    return n
