"""Sharding context: lets mesh-agnostic model code request activation
sharding constraints that only take effect when the launcher has installed a
rule set (no-ops on single-device CPU runs, so tests/benches are unaffected).

Also carries the *dispatch mesh*: the mesh the launcher is lowering for.
The kernel dispatch layer (``repro.kernels.dispatch``) keys backend
selection off this mesh's device platform — the lowering *target* — rather
than ``jax.default_backend()``, so a host process lowering for a TPU mesh
picks the same kernels the TPU mesh will run.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax

_state = threading.local()


def current_rules() -> Optional[Dict[str, jax.sharding.PartitionSpec]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def sharding_rules(rules: Dict[str, jax.sharding.PartitionSpec]):
    """rules: logical-name -> PartitionSpec (e.g. "residual", "expert_buffer").
    Installed by the launcher around trace/lower time."""
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def constrain(x, name: str):
    """Apply the named activation constraint if a rule set is installed."""
    rules = current_rules()
    if rules is None or name not in rules:
        return x
    return jax.lax.with_sharding_constraint(x, rules[name])


# ---------------------------------------------------------------------------
# dispatch mesh
# ---------------------------------------------------------------------------

def current_mesh():
    """The mesh installed by the launcher (None on plain single-device runs)."""
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh):
    """Install ``mesh`` as the kernel-dispatch target around trace/lower time.

    Orthogonal to ``compat.set_mesh`` (which feeds jax's sharding machinery):
    this one only makes the mesh *visible* to the dispatch layer so it can
    shard_map the Pallas kernels over it and resolve the target platform."""
    prev = current_mesh()
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


def mesh_platform(mesh) -> str:
    """Device platform of ``mesh`` ("cpu"/"tpu"/"gpu").  AbstractMesh carries
    no devices; assume the local default backend in that case."""
    devs = getattr(mesh, "devices", None)
    if devs is None:
        return jax.default_backend()
    return devs.flat[0].platform


def current_platform() -> str:
    """Platform of the lowering target: the dispatch mesh's device platform
    when a mesh is installed, else the process default backend."""
    mesh = current_mesh()
    if mesh is None:
        return jax.default_backend()
    return mesh_platform(mesh)


def mesh_devices(mesh) -> int:
    """Total device count of a (possibly abstract) mesh."""
    n = 1
    for s in dict(mesh.shape).values():
        n *= s
    return n
