import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers, partitions, and compiles on the production mesh —
without touching real hardware.  See the module-leading XLA_FLAGS line:
512 placeholder host devices, set before ANY jax import.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 baselines
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Per run: compiled.memory_analysis() (fits?), cost_analysis() (FLOPs/bytes),
collective bytes parsed from partitioned HLO, and the three roofline terms.
Records are appended to benchmarks/results/dryrun.jsonl.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import get_config, ARCH_IDS, ALIASES
from repro.core import llm_a3c
from repro.distributed import ctx, sharding
from repro.kernels import dispatch
from repro.launch import hlo_analysis, traffic
from repro.launch import specs as specs_mod
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models import model as M
from repro.optim import optimizers as opt_mod

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results")


def _mem_summary(compiled):
    try:
        ma = compiled.memory_analysis()
        return {
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "peak_bytes": getattr(ma, "peak_memory_in_bytes", None)
                if hasattr(ma, "peak_memory_in_bytes") else None,
            "generated_code_bytes":
                getattr(ma, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)}


def lower_case(arch: str, shape_id: str, *, multi_pod: bool = False,
               fsdp: bool = True, mode: str = "sync",
               verbose: bool = True) -> dict:
    """Lower + compile one (arch x shape x mesh).  mode: sync | delayed."""
    cfg = get_config(arch)
    cfg = specs_mod.maybe_long_variant(cfg, shape_id)
    if shape_id == "long_500k" and \
            specs_mod.LONG_DECODE.get(get_config(arch).name) is None:
        return {"arch": arch, "shape": shape_id, "status": "skipped",
                "reason": "enc-dec / full attention (DESIGN.md §4)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    kind, in_specs = specs_mod.input_specs(cfg, shape_id)
    bsz = specs_mod.INPUT_SHAPES[shape_id]["batch"]

    p_specs = specs_mod.params_specs(cfg)
    p_shard = sharding.param_shardings(cfg, mesh, p_specs, fsdp=fsdp)
    rules = sharding.activation_rules(mesh, batch_size=bsz, cfg=cfg)

    t0 = time.time()
    # install the mesh as the kernel-dispatch target: backend resolution
    # keys off the mesh's device platform (the lowering target), and the
    # dispatcher shard_maps the Pallas kernels over (data, heads)
    dispatch.clear_decision_log()
    with compat.set_mesh(mesh), ctx.use_mesh(mesh), \
            ctx.sharding_rules(rules):
        if kind == "train" and mode == "delayed":
            # T3: paper-faithful pod-scale asynchrony — each pod updates a
            # local replica for H steps, merging on the 'pod' axis.
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.core import delayed_sync
            assert multi_pod, "delayed mode needs the pod axis"
            n_pods = mesh.shape["pod"]
            opt = opt_mod.shared_rmsprop()

            def prepend_pod(sh):
                # the pod axis becomes the replica-group dim: strip it from
                # any inner (FSDP) spec entries before prepending
                def strip(a):
                    if isinstance(a, tuple):
                        t = tuple(x for x in a if x != "pod")
                        return t if len(t) > 1 else (t[0] if t else None)
                    return None if a == "pod" else a
                spec = tuple(strip(a) for a in tuple(sh.spec))
                return NamedSharding(mesh, P(*(("pod",) + spec)))

            pg_specs = jax.eval_shape(
                lambda t: delayed_sync.replicate(t, n_pods), p_specs)
            pg_shard = jax.tree.map(prepend_pod, p_shard)
            og_specs = jax.eval_shape(
                lambda t: delayed_sync.replicate(t, n_pods),
                jax.eval_shape(opt.init, p_specs))
            og_shard = {"g": pg_shard}
            # per-pod batch shard: group dim on 'pod', batch dim on 'data'
            bg_specs = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct((n_pods, bsz // n_pods)
                                               + x.shape[1:], x.dtype)
                if x.shape[0] == bsz else
                jax.ShapeDtypeStruct((n_pods,) + x.shape, x.dtype),
                in_specs)
            inner = sharding.batch_shardings(mesh, in_specs,
                                             batch_size=bsz)

            def pod_batch_shard(sh, leaf):
                spec = tuple(sh.spec)
                # replace the ('pod','data') batch spec with 'data' and
                # prepend 'pod' for the group dim
                spec = tuple(("data",) if a == ("pod", "data") else a
                             for a in spec)
                return NamedSharding(mesh, P(*(("pod",) + spec)))

            bg_shard = jax.tree.map(pod_batch_shard, inner, in_specs)
            ds_step = delayed_sync.make_delayed_train_step(
                cfg, opt, n_groups=n_pods, merge_interval=8)
            lowered = jax.jit(
                ds_step,
                in_shardings=(pg_shard, og_shard, bg_shard, None),
                out_shardings=(pg_shard, og_shard, None),
            ).lower(pg_specs, og_specs, bg_specs,
                    jax.ShapeDtypeStruct((), jnp.int32))
        elif kind == "train":
            opt = opt_mod.shared_rmsprop()
            opt_specs = jax.eval_shape(opt.init, p_specs)
            opt_shard = {"g": p_shard}
            b_shard = sharding.batch_shardings(mesh, in_specs,
                                               batch_size=bsz)
            step_spec = jax.ShapeDtypeStruct((), jnp.int32)
            train_step = llm_a3c.make_train_step(cfg, opt)
            lowered = jax.jit(
                train_step,
                in_shardings=(p_shard, opt_shard, b_shard, None),
                out_shardings=(p_shard, opt_shard, None),
            ).lower(p_specs, opt_specs, in_specs, step_spec)
        elif kind == "prefill":
            b_shard = sharding.batch_shardings(mesh, in_specs,
                                               batch_size=bsz)

            def prefill(params, batch):
                out = M.forward(cfg, params, batch)
                # serving prefill returns ONLY the next-token logits; XLA
                # narrows the vocab matmul to the last position (without
                # this, whisper's replicated odd-vocab logits peak at
                # >100GB/device)
                return {"logits": out["logits"][:, -1],
                        "value": out.get("value",
                                         out["logits"][:, -1, :1])[:, -1]}

            lowered = jax.jit(
                prefill, in_shardings=(p_shard, b_shard),
            ).lower(p_specs, in_specs)
        else:  # decode
            serve_step = llm_a3c.make_serve_step(cfg)
            b_shard = sharding.batch_shardings(mesh, in_specs["batch"],
                                               batch_size=bsz)
            c_shard = sharding.cache_shardings(cfg, mesh, in_specs["cache"],
                                               batch_size=bsz)
            # serving replicas store bf16 weights sharded over `model` only
            # (no FSDP): removes the per-token f32 weight gathers
            # (perf iter #6)
            p_serve_specs = jax.eval_shape(
                lambda t: M.cast_params(cfg, t), p_specs)
            p_serve_shard = sharding.param_shardings(cfg, mesh,
                                                     p_serve_specs,
                                                     fsdp=False)
            dec_rules = {**rules,
                         **sharding.decode_rules(cfg, mesh, batch_size=bsz)}
            with ctx.sharding_rules(dec_rules):
                lowered = jax.jit(
                    serve_step,
                    in_shardings=(p_serve_shard, c_shard, b_shard, None,
                                  None),
                    out_shardings=(None, None, c_shard),
                ).lower(p_serve_specs, in_specs["cache"], in_specs["batch"],
                        in_specs["pos"], in_specs["key"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    cost = compat.cost_analysis(compiled)
    mem = _mem_summary(compiled)
    hlo_text = compiled.as_text()
    weighted = hlo_analysis.weighted_totals(hlo_text)
    coll = {k: weighted[k] for k in ("all-gather", "all-reduce",
                                     "reduce-scatter", "all-to-all",
                                     "collective-permute", "total")}
    n = cfg.param_count()
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = bsz * specs_mod.INPUT_SHAPES[shape_id]["seq"]
        model_flops = 6 * n_active * tokens
    elif kind == "prefill":
        tokens = bsz * specs_mod.INPUT_SHAPES[shape_id]["seq"]
        model_flops = 2 * n_active * tokens
    else:
        model_flops = 2 * n_active * bsz
    hbm = traffic.hbm_bytes(cfg, shape_id, kind, n_chips)
    # dot shapes in the partitioned module are per-device slices, so the
    # weighted flops are already per-chip; scale to whole-program for the
    # MODEL_FLOPS ratio.
    hlo_flops = weighted["flops"] * n_chips
    terms = hlo_analysis.roofline_terms(
        hlo_flops=hlo_flops, hbm_bytes=hbm, collective_total=coll["total"],
        n_chips=n_chips, peak_flops=PEAK_FLOPS_BF16,
        hbm_bw=HBM_BW, ici_bw=ICI_BW)
    rec = {
        "arch": arch, "variant": cfg.name, "shape": shape_id, "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16", "mode": mode,
        "status": "ok", "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
        "params": n, "active_params": n_active,
        "hlo_flops": hlo_flops,
        "xla_cost_flops_unweighted": float(cost.get("flops", 0.0)),
        "hbm_bytes_per_chip": hbm,
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops / hlo_flops
                               if hlo_flops else None),
        "collective_bytes": coll,
        "memory": mem,
        "roofline": terms,
        # which kernels this lowering picked, and why any call fell back
        "kernel_dispatch": hlo_analysis.kernel_dispatch_summary(),
    }
    if verbose:
        print(json.dumps(rec, indent=1, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="arch id (e.g. qwen2-72b); default: all")
    ap.add_argument("--shape", default=None,
                    choices=list(specs_mod.INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--mode", default="sync", choices=["sync", "delayed"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ALIASES)
    shapes = [args.shape] if args.shape else list(specs_mod.INPUT_SHAPES)

    os.makedirs(RESULTS, exist_ok=True)
    out_path = args.out or os.path.join(RESULTS, "dryrun.jsonl")
    results = []
    for arch in archs:
        for shape in shapes:
            print(f"=== {arch} x {shape} "
                  f"({'2x16x16' if args.multi_pod else '16x16'}) ===",
                  flush=True)
            try:
                rec = lower_case(arch, shape, multi_pod=args.multi_pod,
                                 fsdp=not args.no_fsdp, mode=args.mode)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if args.multi_pod else "16x16",
                       "status": "error", "error": str(e)[:2000]}
            results.append(rec)
            with open(out_path, "a") as f:
                f.write(json.dumps(rec, default=str) + "\n")
    ok = sum(r["status"] == "ok" for r in results)
    skipped = sum(r["status"] == "skipped" for r in results)
    print(f"\n{ok} ok / {skipped} skipped / "
          f"{len(results) - ok - skipped} failed of {len(results)}")
    return 0 if ok + skipped == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
