"""Production mesh definitions (TPU v5e target).

Single pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

Defined as functions so importing this module never touches jax device
state (the dry-run launcher sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, data: int = 1, model: int = 1):
    """Tiny mesh for CPU integration tests (needs data*model <= #devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


# hardware constants for the roofline model (TPU v5e)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
