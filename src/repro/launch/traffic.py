"""Analytic per-device HBM traffic model for the roofline memory term.

XLA's cost_analysis "bytes accessed" suffers the same While-body
undercounting as its FLOPs (see hlo_analysis.py), so the memory term is
computed from an explicit, implementation-aware traffic model instead.  All
tensors below are sharded across the whole mesh (params 2-D FSDPxTP, batch
on data, caches context-parallel), so totals are divided by n_chips.

Accounting (bytes, whole cluster):

train_step:
  params    3 reads bf16 (fwd + remat-refwd + bwd access)      6 * P
            master read+write f32, grad write+read f32,
            RMSProp g read+write f32                          24 * P
  residual  saved scan carries, write(fwd)+read(bwd), bf16:
            4 * L * B * S * d
  logits    f32 materialization + softmax passes: 16 * B * S * V
prefill:
  params    1 read bf16: 2 * P
  acts      2 * L * B * S * d * 2 (block in/out, bf16)
  kv        written once: cache_bytes
  logits    4 * B * S * V (bf16 out + reads)
prefill (chunked, serve engine):
  per chunk of C tokens at offset p0: params 2 * P, acts 4 * L * B * C * d,
  chunk KV rows written once, the [0, p0) KV prefix re-read by every later
  chunk (the quadratic term that bounds how small C should go — see
  ``prefill_chunk_bytes`` and DESIGN.md §serve-engine), logits 4 * B * C * V
decode (per token):
  params    1 read bf16: 2 * P   (grouped-einsum MoE reads ALL experts —
            an implementation property the roofline deliberately exposes)
  cache     full read + one-slot write: cache_bytes
  combine   context-parallel decode (seq-sharded caches) adds the
            flash-decoding (m, l, acc) psum per attention layer —
            O(B * Hq * (D + 2)) f32 per shard (``decode_cp_combine_bytes``)
            instead of all-gathering the cache; the ICI term, not HBM, but
            reported alongside so serving rooflines see the layout's cost
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import kv_quant
from repro.launch import specs as specs_mod
from repro.models import attention
from repro.models import model as M
from repro.models.config import ModelConfig


def _tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def cache_bytes(cfg: ModelConfig, batch: int, seq: int,
                kv_dtype=None) -> int:
    """Exact cache bytes via eval_shape of the real ``init_cache``.  With
    ``kv_dtype`` (name or dtype) the attention K/V leaves take that storage
    type and — for int8 — the per-(row, head) f32 scale leaves are counted
    too; recurrent state stays bf16 either way."""
    kvd = None if kv_dtype is None else kv_quant.resolve_kv_dtype(kv_dtype)
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, batch, seq, dtype=jnp.bfloat16,
                             kv_dtype=kvd))
    return _tree_bytes(cache)


def page_pool_bytes(cfg: ModelConfig, n_pages: int, page_size: int,
                    dtype=jnp.bfloat16, kv_dtype=None) -> int:
    """Bytes of K+V page pool for ``n_pages`` pages across every
    global-attention layer (the only kind the paged layout covers —
    windowed and recurrent layers keep contiguous per-slot state).

    ``kv_dtype`` overrides ``dtype`` as the pool storage type; int8 adds
    the f32 scale pools (4 bytes per pool row per KV head, amortised over
    head_dim elements — the reason int8 lands at ~(D+4)/4D of f32, not
    exactly 1/4)."""
    n_attn = sum(1 for k in cfg.layer_kinds() if k == "attn")
    kvd = jnp.dtype(dtype if kv_dtype is None
                    else kv_quant.resolve_kv_dtype(kv_dtype))
    rows = n_pages * page_size * cfg.n_kv_heads
    total = 2 * rows * cfg.head_dim * kvd.itemsize
    if kv_quant.is_quantized(kvd):
        total += 2 * rows * 4            # f32 scale per (row, kv head)
    return n_attn * total


def paged_cache_bytes(cfg: ModelConfig, batch: int, seq: int, *,
                      page_size: int, n_pages: int, kv_dtype=None) -> int:
    """Exact byte count of the paged serve cache (shared K/V pools +
    int32 page tables + contiguous non-attn leaves), via eval_shape of
    the real ``init_cache`` so layout knowledge lives in one place."""
    paged = attention.PagedLayout(page_size=page_size, n_pages=n_pages)
    kvd = None if kv_dtype is None else kv_quant.resolve_kv_dtype(kv_dtype)
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, batch, seq, dtype=jnp.bfloat16,
                             paged=paged, kv_dtype=kvd))
    return _tree_bytes(cache)


def paged_capacity(cfg: ModelConfig, *, n_slots: int, cache_len: int,
                   page_size: int, resident_tokens_per_req: int,
                   shared_tokens: int = 0, kv_dtype=None) -> dict:
    """Concurrency the paged layout sustains on the SAME HBM budget the
    contiguous layout spends on ``n_slots`` full-length slots.

    Contiguous reserves ``cache_len`` rows per slot no matter how many a
    request uses; paged charges each live request only
    ``ceil(resident_tokens_per_req / page_size)`` pages, of which the
    leading ``shared_tokens // page_size`` full blocks are deduplicated
    across all requests via the prefix index.  Per-slot overhead (int32
    page-table rows plus any contiguous non-attn layer state) is charged
    exactly via ``paged_cache_bytes``.

    The budget is ALWAYS the bf16 contiguous reservation — ``kv_dtype``
    changes only what the paged layout pays per page/slot, so int8 rows
    are directly comparable to f32 rows on the same HBM budget."""
    budget = cache_bytes(cfg, n_slots, cache_len)
    per_page = page_pool_bytes(cfg, 1, page_size, kv_dtype=kv_dtype)
    # everything in a one-slot paged cache that is NOT pool: table + the
    # contiguous leaves of windowed/recurrent layers + index scalars
    per_slot = paged_cache_bytes(cfg, 1, cache_len, page_size=page_size,
                                 n_pages=1, kv_dtype=kv_dtype) - per_page
    shared_pages = shared_tokens // page_size
    req_pages = -(-resident_tokens_per_req // page_size)
    unique = max(req_pages - shared_pages, 1)
    slots_paged = int((budget - shared_pages * per_page)
                      // (unique * per_page + per_slot))
    dedup = (slots_paged * req_pages
             / max(shared_pages + slots_paged * unique, 1))
    kvd = jnp.bfloat16 if kv_dtype is None \
        else kv_quant.resolve_kv_dtype(kv_dtype)
    return {
        "kv_dtype": kv_quant.dtype_name(kvd),
        "budget_bytes": budget,
        "page_bytes": per_page,
        "per_slot_overhead_bytes": per_slot,
        "shared_pages": shared_pages,
        "unique_pages_per_req": unique,
        "slots_contiguous": n_slots,
        "slots_paged": slots_paged,
        "slot_ratio": slots_paged / max(n_slots, 1),
        "dedup_ratio_model": dedup,
    }


def reservation_capacity(*, n_pages: int, page_size: int,
                         prompt_tokens: int, max_new: int,
                         shared_tokens: int = 0, spec_k: int = 1) -> dict:
    """Admission-control capacity of a page pool under the serve engine's
    two policies (ISSUE: reservation/overcommit math).

    ``reserve`` holds back the worst case — ceil((prompt + max_new +
    spec_k - 1) / page_size) pages per live request — so decode can NEVER
    exhaust the pool: concurrency is what fits whole worst-case
    reservations.  ``spec_k`` > 1 is speculative decoding's in-flight
    tail: a verify round pre-maps pages covering up to ``spec_k - 1``
    drafted tokens past the committed frontier before knowing how many
    commit, so the never-preempts guarantee must reserve for them too.
    ``optimistic`` reserves only the prompt's pages and overcommits the
    generated tail; decode-time exhaustion is recovered by
    preempt-and-requeue, buying ``overcommit_ratio`` more admitted
    concurrency in exchange for preemption risk.  ``shared_tokens``
    leading prompt tokens are prefix-deduplicated full blocks: they cost
    the pool once, not per request (the first admission pays them —
    capacity here counts steady-state extra requests)."""
    usable = n_pages - 1                       # page 0 is the sink
    shared_pages = min(shared_tokens, prompt_tokens) // page_size
    worst = -(-(prompt_tokens + max_new + spec_k - 1) // page_size)
    opt = -(-prompt_tokens // page_size)
    worst_u = max(worst - shared_pages, 1)
    opt_u = max(opt - shared_pages, 1)
    slots_reserve = max((usable - shared_pages) // worst_u, 0)
    slots_opt = max((usable - shared_pages) // opt_u, 0)
    return {
        "usable_pages": usable,
        "shared_pages": shared_pages,
        "worst_case_pages_per_req": worst,
        "optimistic_pages_per_req": opt,
        "slots_reserve": slots_reserve,
        "slots_optimistic": slots_opt,
        "overcommit_ratio": slots_opt / max(slots_reserve, 1),
    }


def spec_verify_bytes_per_token(cfg: ModelConfig) -> int:
    """Marginal HBM bytes ONE verify position adds to a speculative round:
    its block in/out activations, its q/o streams through the append
    kernel, and its logits row.  The param sweep and the KV-prefix read
    are paid once per round and amortized over every position in the
    chunk — that amortization IS the speculative win — so a REJECTED
    position wastes only this marginal term, not a full
    ``decode_bytes_per_token``.  Multiply by the engine's
    ``spec_wasted_tokens`` counter for the round-trip waste a bench
    reports next to its accept rate."""
    n_attn = sum(1 for k in cfg.layer_kinds()
                 if k in ("attn", "attn_local"))
    acts = 4 * cfg.n_layers * cfg.d_model            # block in/out, bf16
    qo = n_attn * 2 * cfg.n_heads * cfg.head_dim * 4  # q read + o write
    logits = 4 * cfg.vocab_size                       # f32 row + argmax read
    return acts + qo + logits


def spec_wasted_bytes(cfg: ModelConfig, wasted_tokens: int) -> int:
    """Total marginal HBM bytes burned on rejected (and over-drafted)
    verify positions across a run — the serve report's wasted-bytes
    column: ``wasted_tokens * spec_verify_bytes_per_token``."""
    return wasted_tokens * spec_verify_bytes_per_token(cfg)


def decode_bytes_per_token(cfg: ModelConfig, batch: int, cache_len: int, *,
                           kv_dtype=None, page_size: int | None = None,
                           n_pages: int | None = None) -> int:
    """Analytic HBM bytes one decode step moves: a full bf16 param read
    plus the whole KV cache streamed once (the int8 win is this second
    term — scale reads included).  Contiguous layout by default; pass
    ``page_size``/``n_pages`` for the paged pool.  Benchmarks report this
    next to measured tok/s so the roofline denominator is explicit."""
    if page_size is not None:
        cb = paged_cache_bytes(cfg, batch, cache_len, page_size=page_size,
                               n_pages=n_pages or 1, kv_dtype=kv_dtype)
    else:
        cb = cache_bytes(cfg, batch, cache_len, kv_dtype=kv_dtype)
    return 2 * cfg.param_count() + cb


def decode_cp_combine_bytes(cfg: ModelConfig, batch: int,
                            n_seq_shards: int) -> int:
    """ICI bytes per decoded token for the context-parallel flash-decoding
    combine: every attention layer psums three f32 partials — acc
    (B, Hq, D), m and l (B, Hq) — across the ``n_seq_shards`` sequence
    shards.  Whole-cluster total (each shard contributes its copy); the
    alternative this replaces is all-gathering the multi-GB KV cache every
    layer."""
    n_attn = sum(1 for k in cfg.layer_kinds()
                 if k in ("attn", "attn_local"))
    per_layer = batch * cfg.n_heads * (cfg.hd + 2) * 4
    return n_attn * per_layer * n_seq_shards


def prefill_attn_bytes(cfg: ModelConfig, batch: int, prompt_len: int,
                       chunk_len: int, *, fused: bool) -> int:
    """HBM bytes for the ATTENTION op across a whole chunked prefill —
    the term the append kernel changes (everything else in
    ``prefill_chunk_bytes`` is identical between the two paths).

    masked-sdpa (``fused=False``, the pre-append prefix path): every chunk
    materializes concat'ed K/V streams repeated to Hq (GQA fan-out leaves
    VMEM) and an f32 (C, Sk) score tensor that makes ~5 HBM passes
    (logits write, mask where read+write, softmax read+write) before the
    PV matmul reads it again.

    fused append (``fused=True``): the key-stream concat (cache prefix +
    chunk) is written once and the kernel reads it once in Hkv layout;
    q/o stream once; score tiles live in VMEM scratch and never touch
    HBM."""
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    n_attn = sum(1 for k in cfg.layer_kinds()
                 if k in ("attn", "attn_local"))
    total = 0
    for p0 in range(0, prompt_len, chunk_len):
        c = min(chunk_len, prompt_len - p0)
        sk = p0 + c
        qo = 2 * batch * c * hq * hd * 4            # q read + o write, f32
        if fused:
            # concat write + one kernel pass, both in Hkv layout
            kv = 2 * batch * sk * 2 * hkv * hd * 4
            scores = 0                              # VMEM-resident tiles
        else:
            # concat write + Hq-repeated read for both einsums
            kv = 2 * batch * sk * (hkv + 2 * hq) * hd * 4
            scores = 5 * batch * hq * c * sk * 4    # f32 materialization
        total += n_attn * (qo + kv + scores)
    return total


def prefill_chunk_bytes(cfg: ModelConfig, batch: int, prompt_len: int,
                        chunk_len: int) -> int:
    """HBM bytes for chunked flash prefill of a (batch, prompt_len) prompt
    processed in ceil(prompt_len / chunk_len) chunks.

    Each chunk re-reads the whole parameter set and the KV prefix written
    by earlier chunks, so total traffic falls with larger chunks (fewer
    param sweeps) until the quadratic prefix-re-read term takes over:
    params ~ P * n_chunks, prefix re-reads ~ row_bytes * prompt^2 / (2C).
    The token-by-token loop this replaces is the chunk_len == 1 case —
    prompt_len full param reads and an O(prompt * cache_len) cache-stream
    term, which is what makes it the dominant serving-latency cost."""
    p = cfg.param_count()
    l, d, v = cfg.n_layers, cfg.d_model, cfg.vocab_size
    row = cache_bytes(cfg, batch, prompt_len) // max(prompt_len, 1)
    total = 0
    for p0 in range(0, prompt_len, chunk_len):
        c = min(chunk_len, prompt_len - p0)
        total += (2 * p                      # one bf16 param read per chunk
                  + 4 * l * batch * c * d    # block in/out activations
                  + row * c                  # chunk KV rows written
                  + row * p0                 # prefix KV re-read (later chunks)
                  + 4 * batch * c * v)       # logits
    return total


def hbm_bytes(cfg: ModelConfig, shape_id: str, kind: str,
              n_chips: int) -> float:
    sh = specs_mod.INPUT_SHAPES[shape_id]
    b, s = sh["batch"], sh["seq"]
    p = cfg.param_count()
    l, d, v = cfg.n_layers, cfg.d_model, cfg.vocab_size
    if kind == "train":
        total = (30 * p
                 + 4 * l * b * s * d
                 + 16 * b * s * v)
    elif kind == "prefill":
        total = (2 * p
                 + 4 * l * b * s * d
                 + cache_bytes(cfg, b, s)
                 + 4 * b * s * v)
    else:  # decode
        total = 2 * p + cache_bytes(cfg, b, s)
    return total / n_chips
