"""End-to-end training driver.

Two modes:
  * ``--mode rl``  — the paper's experiments: asynchronous actor-learners
    (T1 Hogwild simulation or T2 sync) with one of the four algorithms on a
    vectorized JAX environment, paper networks (repro.models.atari).
  * ``--mode llm`` — the assigned-architecture path: A3C token-level RL on
    a (reduced or full) backbone with the synthetic TokenMDP pipeline, data-
    parallel over local devices (or the dry-run mesh via launch/dryrun.py).

Examples:
  PYTHONPATH=src python -m repro.launch.train --mode rl --env catch \
      --algo a3c --workers 8 --frames 200000
  PYTHONPATH=src python -m repro.launch.train --mode llm --arch stablelm-1.6b \
      --reduced --steps 200 --seq 128 --batch 8
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp


def run_rl(args) -> dict:
    from repro.core import agents, async_runner
    from repro.envs import make
    from repro.envs.api import flatten_obs
    from repro.models import atari as nets

    env = make(args.env)
    if len(env.obs_shape) > 1:
        env = flatten_obs(env)
    algo = agents.ALGORITHMS[args.algo](
        **({"continuous": True} if env.continuous else {}))
    key = jax.random.key(args.seed)
    params = nets.init_mlp_agent_params(
        key, env.obs_shape[0], env.n_actions, hidden=args.hidden,
        continuous=env.continuous)
    cfg = async_runner.RunnerConfig(
        n_workers=args.workers, t_max=args.t_max, lr0=args.lr,
        total_frames=args.frames, mode=args.runner_mode,
        optimizer=args.optimizer, shared_stats=not args.per_worker_stats,
        target_interval=args.target_interval)
    init_state, round_fn = async_runner.make_runner(algo, env, params, cfg)
    st = init_state(jax.random.key(args.seed + 1))
    history = []
    t0 = time.time()
    rounds = args.frames // (cfg.n_workers * cfg.t_max)
    for i in range(rounds):
        st, m = round_fn(st)
        if i % max(1, rounds // 20) == 0 or i == rounds - 1:
            rec = {"round": i, "frames": int(st["frames"]),
                   "ep_ret": float(m["ep_ret"]), "loss": float(m["loss"]),
                   "wall_s": round(time.time() - t0, 1)}
            history.append(rec)
            print(json.dumps(rec), flush=True)
    if args.checkpoint:
        from repro import checkpoint
        checkpoint.save(args.checkpoint, st["params"])
        print(f"saved params to {args.checkpoint}")
    return {"history": history, "final_ep_ret": history[-1]["ep_ret"]}


def run_llm(args) -> dict:
    import contextlib

    from repro.configs import get_config
    from repro.core import llm_a3c
    from repro.data.pipeline import TokenPipeline
    from repro.distributed import ctx
    from repro.launch.mesh import make_debug_mesh
    from repro.models import model as M
    from repro.optim import optimizers as opt_mod

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.key(args.seed)
    params = M.init_params(cfg, key)
    opt = opt_mod.OPTIMIZERS[args.optimizer]()
    opt_state = opt.init(params)
    pipe = TokenPipeline(vocab=cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch)
    # multi-device host: install a data-parallel dispatch mesh so the
    # kernel dispatch layer shard_maps the Pallas kernels over the batch
    # (backend choice itself is automatic — keyed off the mesh platform)
    n_dev = jax.local_device_count()
    mesh_ctx = contextlib.nullcontext()
    if n_dev > 1 and args.batch % n_dev == 0:
        mesh_ctx = ctx.use_mesh(make_debug_mesh(data=n_dev, model=1))
    train_step = jax.jit(llm_a3c.make_train_step(
        cfg, opt, lr0=args.lr, total_steps=args.steps))
    history = []
    t0 = time.time()
    # dispatch resolves at trace time, so the mesh stays installed for the
    # whole loop (first call traces)
    with mesh_ctx:
        for step in range(args.steps):
            batch = pipe.batch(jax.random.key(args.seed + 2), step)
            params, opt_state, metrics = train_step(
                params, opt_state, batch, jnp.asarray(step))
            if step % max(1, args.steps // 20) == 0 \
                    or step == args.steps - 1:
                rec = {"step": step,
                       "loss": float(metrics["loss"]),
                       "mean_return": float(metrics["mean_return"]),
                       "entropy": float(metrics["entropy"]),
                       "wall_s": round(time.time() - t0, 1)}
                history.append(rec)
                print(json.dumps(rec), flush=True)
    if args.checkpoint:
        from repro import checkpoint
        checkpoint.save(args.checkpoint, params)
        print(f"saved params to {args.checkpoint}")
    return {"history": history}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["rl", "llm"], default="rl")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--optimizer", default="shared_rmsprop",
                    choices=["shared_rmsprop", "rmsprop", "momentum_sgd"])
    ap.add_argument("--lr", type=float, default=7e-3)
    # rl
    ap.add_argument("--env", default="catch")
    ap.add_argument("--algo", default="a3c",
                    choices=["a3c", "one_step_q", "one_step_sarsa",
                             "n_step_q"])
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--t-max", type=int, default=5)
    ap.add_argument("--frames", type=int, default=100_000)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--runner-mode", default="hogwild",
                    choices=["hogwild", "sync"])
    ap.add_argument("--per-worker-stats", action="store_true")
    ap.add_argument("--target-interval", type=int, default=2_000)
    # llm
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()
    if args.mode == "rl":
        run_rl(args)
    else:
        run_llm(args)


if __name__ == "__main__":
    main()
