"""Serving driver: batched autoregressive decode (the actor path).

Runs prefill + N decode steps with the KV/SSM cache for a (reduced) assigned
architecture, reporting per-step latency and tokens/s.  This is the same
``serve_step`` the decode dry-run shapes lower on the production mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-1.3b --reduced \
      --batch 4 --prompt-len 32 --gen 32

``--decode-cp`` installs the context-parallel serving layout on the local
devices: the KV cache's sequence dim is sharded over a (1, n_devices) host
mesh via the ``decode_cp`` rules and the dispatch layer resolves the
``pallas_cp`` flash-decoding combine (the unified serving fast path).  The
resulting ``kernel_dispatch`` field in the output records what actually
lowered — including the fallback reason when the cache is too short to
slice per shard.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import time

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--decode-cp", action="store_true",
                    help="context-parallel serving: shard the KV cache's "
                    "sequence dim over the local devices (decode_cp rules "
                    "-> pallas_cp dispatch)")
    args = ap.parse_args()

    from repro import compat
    from repro.configs import get_config
    from repro.core import llm_a3c
    from repro.distributed import ctx, sharding
    from repro.kernels import dispatch
    from repro.launch import hlo_analysis
    from repro.models import model as M

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.key(args.seed)
    params = M.init_params(cfg, key)
    b = args.batch
    cache_len = args.prompt_len + args.gen
    cache = M.init_cache(cfg, b, cache_len, dtype=jnp.float32)

    decode_layout = "replicated"
    combine_bytes = 0
    with contextlib.ExitStack() as stack:
        if args.decode_cp:
            n_dev = len(jax.devices())
            mesh = jax.make_mesh((1, n_dev), ("data", "model"))
            rules = sharding.decode_rules(cfg, mesh, batch_size=b)
            stack.enter_context(compat.set_mesh(mesh))
            stack.enter_context(ctx.use_mesh(mesh))
            stack.enter_context(ctx.sharding_rules(rules))
            n_shards = rules["decode_cp"]["n_shards"]
            decode_layout = f"decode_cp[{n_shards}]"
            from repro.launch import traffic
            combine_bytes = traffic.decode_cp_combine_bytes(cfg, b,
                                                            n_shards)
        dispatch.clear_decision_log()

        prompt = jax.random.randint(key, (b, args.prompt_len), 0,
                                    cfg.vocab_size)
        # backend selection is automatic: the kernel dispatch layer
        # resolves Pallas vs jnp (or the context-parallel pallas_cp
        # combine) from the lowering target (see repro.kernels.dispatch)
        serve_step = jax.jit(llm_a3c.make_serve_step(cfg))

        # prefill by stepping the cache token-by-token (keeps one code
        # path for every cache kind: KV, ring, SSM, xLSTM)
        tok = prompt[:, :1]
        t0 = time.time()
        for i in range(args.prompt_len):
            batch = {"tokens": prompt[:, i:i + 1]}
            if cfg.family == "vlm":
                batch = {"embeds": jnp.zeros((b, 1, cfg.d_model)),
                         "positions": jnp.full((3, b, 1), i, jnp.int32)}
            tok, value, cache = serve_step(params, cache, batch,
                                           jnp.asarray(i), jnp.uint32(i))
        prefill_s = time.time() - t0

        out_tokens = []
        t0 = time.time()
        for i in range(args.prompt_len, cache_len):
            batch = {"tokens": tok[:, None]}
            if cfg.family == "vlm":
                batch = {"embeds": jnp.zeros((b, 1, cfg.d_model)),
                         "positions": jnp.full((3, b, 1), i, jnp.int32)}
            tok, value, cache = serve_step(params, cache, batch,
                                           jnp.asarray(i), jnp.uint32(i))
            out_tokens.append(tok)
        jax.block_until_ready(tok)
        decode_s = time.time() - t0
    toks = args.gen * b
    print(json.dumps({
        "arch": cfg.name, "batch": b, "prompt_len": args.prompt_len,
        "gen": args.gen,
        "decode_layout": decode_layout,
        "cp_combine_bytes_per_token": combine_bytes,
        "prefill_s": round(prefill_s, 3),
        "decode_s": round(decode_s, 3),
        "decode_tok_per_s": round(toks / decode_s, 1),
        "kernel_dispatch": [
            r for r in hlo_analysis.kernel_dispatch_summary()
            if r["op"] == "decode_attention"],
        "sample_tokens": [int(t) for t in out_tokens[0][:4]],
    }))


if __name__ == "__main__":
    main()
