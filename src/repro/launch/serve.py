"""Continuous-batching serve engine (the actor/serving path).

A slot table of ``--slots`` concurrent sequences, fed by a queue of
requests with Poisson (or trace-driven) arrivals and heterogeneous
prompt/generation lengths:

  * **admission** — a finished sequence frees its slot; the oldest arrived
    request is admitted, its prompt runs through *chunked flash prefill*
    (``llm_a3c.make_prefill_step``: whole prompt chunks through the flash
    forward kernel, KV caches written in blocks) and its per-slot decode
    position starts at its true prompt length.  Architectures with
    recurrent caches (SSM / xLSTM / enc-dec) fall back to a token-by-token
    prefill loop through ``serve_step``.
  * **decode** — all slots step together through one jitted ``serve_step``
    with per-slot positions ``pos (B,)`` (the per-slot decode-attention
    kernel masks each row at its own depth) and per-slot sampling keys
    (``fold_in`` per step and per slot).

Reports aggregate tokens/s, per-request latency percentiles (TTFT and
end-to-end), slot-occupancy utilization, and the kernel dispatch summary.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --slots 4 --requests 16 --prompt-range 16,64 --gen-range 8,32

``--mode lockstep`` keeps the old wave-batched driver (every slot the same
position; waves admit ``--slots`` requests at once and wait for the
slowest) — the baseline the engine is measured against in
``benchmarks/bench_serve.py``.  ``--decode-cp`` installs the
context-parallel serving layout on the local devices (seq-sharded KV cache
-> ``pallas_cp`` dispatch) under either mode.
"""
from __future__ import annotations

import argparse
import collections
import contextlib
import dataclasses
import json
import logging
import time
from typing import List, Optional

import numpy as np


# ---------------------------------------------------------------------------
# request trace
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int
    arrival: float                # seconds after engine start
    # robustness knobs (None = unbounded):
    deadline_ttft: Optional[float] = None    # max wait for the FIRST token,
    #                                          measured from the current
    #                                          (retry-adjusted) arrival
    deadline_total: Optional[float] = None   # max end-to-end, from the
    #                                          ORIGINAL arrival
    max_retries: int = 0                     # re-enqueues after an
    #                                          admission shed (client-retry
    #                                          semantics: the TTFT clock
    #                                          restarts at each retry)
    # filled by the engine:
    tokens: list = dataclasses.field(default_factory=list)
    t_admit: float = -1.0
    t_first: float = -1.0
    t_done: float = -1.0
    eff_arrival: float = -1.0     # current arrival (updated by retries)
    preemptions: int = 0
    retry_count: int = 0
    shed_reason: Optional[str] = None


def _eff_prompt(req: Request) -> np.ndarray:
    """The prompt a (re-)admission must prefill: a preempted request's
    generated-so-far tokens fold into the re-prefill prompt, so greedy
    decoding resumes with exactly the logits the uncontended run saw at
    that position (token-identity under preemption)."""
    if req.tokens:
        return np.concatenate([np.asarray(req.prompt, np.int32),
                               np.asarray(req.tokens, np.int32)])
    return np.asarray(req.prompt, np.int32)


def gen_trace(n_requests: int, *, vocab: int, prompt_range, gen_range,
              arrival_rate: float, seed: int) -> List[Request]:
    """Poisson arrivals (exponential interarrival at ``arrival_rate`` req/s;
    rate <= 0 = all at t=0) with uniform prompt/gen lengths — the same
    trace drives both the engine and the lockstep baseline."""
    if prompt_range[0] < 1 or gen_range[0] < 1:
        raise ValueError("prompt and generation lengths must be >= 1 "
                         f"(got ranges {prompt_range}, {gen_range})")
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for i in range(n_requests):
        if arrival_rate > 0:
            t += rng.exponential(1.0 / arrival_rate)
        plen = int(rng.integers(prompt_range[0], prompt_range[1] + 1))
        glen = int(rng.integers(gen_range[0], gen_range[1] + 1))
        out.append(Request(
            rid=i, prompt=rng.integers(0, vocab, plen).astype(np.int32),
            max_new=glen, arrival=t))
    return out


def min_accept_margin(cfg, params, trace: List[Request],
                      cache_len: int) -> float:
    """Smallest top-2 logit gap along completed requests' greedy streams
    (single-slot decode chain — the non-speculative reference path).

    The speculative identity contract ("accepted greedy tokens
    bit-identical to plain decode") holds up to floating point: verify
    scores a K-token chunk while decode scores one token, and the two
    lowerings' logits differ by reduction-order noise (~1e-6).  That
    noise can only flip an argmax at a near-tie, so identity tests and
    the speculative bench pin traces whose streams keep every margin
    orders of magnitude above it — this is the checker for that
    precondition (and the diagnostic that separates a near-tie flip
    from a real logic bug: a flip at a healthy margin is never noise —
    historically an async-dispatch aliasing race, since designed out by
    fusing accept + commit into the verify launch, see
    ``_spec_step_all``).
    Returns 0.0 when a stream's recorded token is not even the chain's
    argmax (the margin is inside the noise band by construction)."""
    import jax
    import jax.numpy as jnp_mod

    from repro.models import model as M

    def _step(c, t, p):
        return M.decode_step(cfg, params, c, {"tokens": t}, p)
    step = jax.jit(_step)
    worst = float("inf")
    for r in trace:
        if not r.tokens:
            continue
        seq = [int(t) for t in r.prompt] + [int(t) for t in r.tokens]
        cache = M.init_cache(cfg, 1, cache_len, dtype=jnp_mod.float32)
        p0 = len(r.prompt)
        for i, t in enumerate(seq[:-1]):
            out, cache = step(cache,
                              jnp_mod.asarray([[t]], jnp_mod.int32),
                              jnp_mod.asarray(i))
            if i >= p0 - 1:
                row = np.asarray(out["logits"][0, -1], np.float64)
                top2 = np.argpartition(row, -2)[-2:]
                top1 = top2[np.argmax(row[top2])]
                if int(top1) != seq[i + 1]:
                    return 0.0
                worst = min(worst,
                            float(row[top1] - row[top2[top2 != top1][0]]))
    return worst


def _percentiles(xs) -> dict:
    if not xs:
        return {}
    return {p: round(float(np.percentile(xs, q)), 4)
            for p, q in (("p50", 50), ("p90", 90), ("p99", 99))}


def _validate_trace(trace: List[Request], cache_len: int, *,
                    page_size: Optional[int] = None,
                    usable_pages: Optional[int] = None,
                    spec_k: int = 1) -> None:
    """A full KV cache has no wrap semantics: ``slot = pos % cache_len``
    silently clobbers row 0 onward if decode runs past the end, while kpos
    keeps attributing the old positions — so reject traces that could
    reach it (decode writes up to position prompt + max_new - 2).

    Paged engines additionally reject any request whose worst-case page
    demand exceeds the pool: such a request can never be served even
    alone, so preempt-and-requeue would thrash forever — fail clearly at
    startup instead of mid-run.  ``spec_k`` > 1 widens the worst case by
    the speculative in-flight tail: a verify round maps pages covering up
    to ``spec_k - 1`` tokens past the committed frontier (clamped to the
    cache), so the same request demands more pages mid-round than its
    final footprint — the demand ``--admission reserve`` must hold back
    for its never-preempts guarantee to survive speculation."""
    for r in trace:
        if len(r.prompt) < 1:
            raise ValueError(f"request {r.rid}: empty prompt")
        if len(r.prompt) + r.max_new - 1 > cache_len:
            raise ValueError(
                f"request {r.rid}: prompt {len(r.prompt)} + max_new "
                f"{r.max_new} overruns cache_len {cache_len}; raise "
                "--cache-len (a full cache would wrap and clobber "
                "prompt rows silently)")
        if page_size:
            need = -(-min(len(r.prompt) + r.max_new + spec_k - 1,
                          cache_len) // page_size)
            if need > usable_pages:
                raise ValueError(
                    f"request {r.rid}: worst-case page demand {need} "
                    f"(ceil((prompt {len(r.prompt)} + max_new {r.max_new}"
                    f" + spec_k {spec_k} - 1) / page_size {page_size})) "
                    f"exceeds the pool's "
                    f"{usable_pages} usable pages — it can never be "
                    "served even alone; raise --pages or shorten the "
                    "request")


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, replayable overload scenario for the serve engine.

    Every field indexes deterministic engine counters — the global
    ``try_alloc`` call number and the decode step number — so the same
    plan against the same trace replays the same faults bit-for-bit:

      * ``fail_alloc_at``  — global ``try_alloc`` call indices that return
                             None regardless of pool state (the allocator
                             itself is untouched, so reservations survive
                             an injected failure)
      * ``preempt_at``     — decode step indices that force-preempt the
                             victim-policy choice before the step runs
                             (repeated indices preempt several slots)
      * ``latency_at``     — (step, seconds) artificial per-step latency,
                             applied to the engine's virtual clock — with
                             ``clock=lambda: 0.0`` time is FULLY virtual
                             and deadline behavior is deterministic
      * ``hold_pages``     — pages seized from the pool at engine init
                             (standing pressure; released only by reset)
    """

    fail_alloc_at: frozenset = frozenset()
    preempt_at: tuple = ()
    latency_at: tuple = ()
    hold_pages: int = 0

    def alloc_fails(self, call: int) -> bool:
        return call in self.fail_alloc_at

    def forced_preempts(self, step: int) -> int:
        return sum(1 for s in self.preempt_at if s == step)

    def step_latency(self, step: int) -> float:
        return sum(lat for s, lat in self.latency_at if s == step)

    @classmethod
    def random(cls, seed: int, *, n_steps: int = 64,
               n_alloc_calls: int = 64, alloc_fail_p: float = 0.1,
               preempt_p: float = 0.05, latency_p: float = 0.1,
               max_latency: float = 0.01,
               hold_pages: int = 0) -> "FaultPlan":
        rng = np.random.default_rng(seed)
        return cls(
            fail_alloc_at=frozenset(
                int(i) for i in range(n_alloc_calls)
                if rng.random() < alloc_fail_p),
            preempt_at=tuple(int(s) for s in range(n_steps)
                             if rng.random() < preempt_p),
            latency_at=tuple(
                (int(s), float(round(rng.uniform(0.0, max_latency), 6)))
                for s in range(n_steps) if rng.random() < latency_p),
            hold_pages=hold_pages)

    def to_json(self) -> str:
        return json.dumps({
            "fail_alloc_at": sorted(self.fail_alloc_at),
            "preempt_at": list(self.preempt_at),
            "latency_at": [list(x) for x in self.latency_at],
            "hold_pages": self.hold_pages})

    @classmethod
    def from_json(cls, s: str) -> "FaultPlan":
        d = json.loads(s)
        return cls(fail_alloc_at=frozenset(d.get("fail_alloc_at", ())),
                   preempt_at=tuple(d.get("preempt_at", ())),
                   latency_at=tuple((int(a), float(b))
                                    for a, b in d.get("latency_at", ())),
                   hold_pages=int(d.get("hold_pages", 0)))


# ---------------------------------------------------------------------------
# chunked prefill plumbing (shared by the engine, the lockstep baseline and
# both warmups — one place to get the grid and the logit gather right)
# ---------------------------------------------------------------------------

def _chunk_grid(pmax: int, chunk: int, cache_len: int) -> List[tuple]:
    """(offset, length) chunks covering the padded prompt grid.

    The padded length rounds ``pmax`` up to the chunk grid but is clamped
    to ``cache_len``: a full cache has no wrap semantics and
    ``attend_prefill`` rejects writes past its end (window layers clamp
    their own ring length and wrap), so the last chunk shrinks instead of
    overflowing."""
    if pmax > cache_len:
        raise ValueError(f"prompt length {pmax} exceeds cache_len "
                         f"{cache_len}")
    padded = min(-(-pmax // chunk) * chunk, cache_len)
    grid = []
    p0 = 0
    while p0 < padded:
        grid.append((p0, min(chunk, padded - p0)))
        p0 += grid[-1][1]
    return grid


def _pad_group(prompts: List[np.ndarray], n_rows: int, chunk: int,
               cache_len: int):
    """Right-pad a group of prompt arrays onto the shared chunk grid.
    Returns (toks (n_rows, padded) int32, plens, grid); rows beyond
    len(prompts) are dummies with plen 0.  (Takes raw token arrays, not
    Requests: a requeued request prefills its EFFECTIVE prompt — original
    plus generated-so-far — via ``_eff_prompt``.)"""
    pmax = max((len(p) for p in prompts), default=1)
    grid = _chunk_grid(pmax, chunk, cache_len)
    padded = grid[-1][0] + grid[-1][1]
    toks = np.zeros((n_rows, padded), np.int32)
    plens = [0] * n_rows
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
        plens[i] = len(p)
    return toks, plens, grid


def _chunked_prefill(prefill_step, params, cache, toks, plens, grid,
                     skip=()):
    """Run one right-padded (B, padded) token block through the chunk
    chain.  Returns (last_logits (B, V) np.float32 — each row's true
    last-prompt-position logits — and the final cache).  The gather
    accumulates on device so the chunk chain is dispatched without a
    host sync per chunk; only the final (B, V) block crosses to host.
    Rows with plen 0 (dummy padding rows) keep zeros.

    ``skip`` — chunk offsets the prefix cache already covers for EVERY
    row (and that contain no row's last prompt token, whose logits feed
    the first sample): those chunks are not launched at all — the shared
    pages already hold their KV."""
    import jax.numpy as jnp

    last = None
    plens = np.asarray(plens)
    # per-row true lengths: ring (sliding-window) caches mask writes past
    # them, which is what makes right-padded admission chunks safe there
    true_len = jnp.asarray(plens, jnp.int32)
    for p0, c in grid:
        if p0 in skip:
            continue
        logits, cache = prefill_step(
            params, cache, {"tokens": jnp.asarray(toks[:, p0:p0 + c])},
            pos0=p0, true_len=true_len)
        if last is None:
            last = jnp.zeros((toks.shape[0], logits.shape[-1]),
                             jnp.float32)
        rel = plens - 1 - p0
        hit = (rel >= 0) & (rel < c)
        if hit.any():
            idx = jnp.asarray(np.clip(rel, 0, c - 1))
            rows = jnp.take_along_axis(logits, idx[:, None, None],
                                       axis=1)[:, 0]
            last = jnp.where(jnp.asarray(hit)[:, None], rows, last)
    return np.asarray(last), cache


# ---------------------------------------------------------------------------
# page allocator + prefix index (paged KV layout, host side)
# ---------------------------------------------------------------------------

class PageAllocator:
    """Host-side free-list allocator over the shared page pool.

    Page 0 is the reserved garbage sink (writes through unmapped page-table
    rows land there; reads mask it via kpos) and is never handed out.
    Pages are refcounted — prefix sharing maps one physical page into many
    slots' tables read-only — and ``version`` bumps every time a page's
    refcount returns to zero, so prefix-index entries naming a
    freed-and-reissued page fail validation instead of aliasing.

    Exhaustion is a scheduling event, not a crash: ``try_alloc`` returns
    None when the pool can't serve the request and the engine recovers
    (admission backpressure, preempt-and-requeue).  ``reserve``/
    ``unreserve`` track admission-time worst-case demand: reserved units
    are held back from UNRESERVED allocations (``free - reserved`` is the
    optimistic headroom), so a reserved allocation can never fail — the
    invariant ``reserved <= len(free)`` is what admission control buys."""

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError(f"need >= 2 pages (sink + 1), got {n_pages}")
        self.n_pages = n_pages
        self.free = list(range(n_pages - 1, 0, -1))      # LIFO, 0 reserved
        self.ref = np.zeros(n_pages, np.int32)
        self.version = np.zeros(n_pages, np.int64)
        self.reserved = 0        # admission units not yet materialized
        self.high_water = 0      # max used_pages ever (report counter)

    def try_alloc(self, *, reserved: bool = False) -> Optional[int]:
        """Allocate a page or return None (recoverable exhaustion).

        ``reserved=True`` consumes one outstanding reservation unit —
        admission already set the page aside, so this cannot fail while
        the reservation invariant holds.  Unreserved allocation fails as
        soon as the free list is down to the reserved units (they belong
        to admitted requests' worst-case tails, not to optimists)."""
        if reserved:
            if self.reserved <= 0:
                raise RuntimeError(
                    "reserved alloc without an outstanding reservation "
                    "(engine reservation accounting is out of sync)")
            if not self.free:       # invariant breach — recoverable anyway
                return None
            self.reserved -= 1
        elif len(self.free) <= self.reserved:
            return None
        p = self.free.pop()
        self.ref[p] = 1
        if self.used_pages > self.high_water:
            self.high_water = self.used_pages
        return p

    def alloc(self) -> int:
        p = self.try_alloc()
        if p is None:
            raise RuntimeError("page pool exhausted; raise --pages")
        return p

    def reserve(self, n: int) -> bool:
        """Set aside ``n`` pages of future demand; False (and no change)
        if the unreserved pool can't cover them — admission backpressure."""
        if n < 0:
            raise ValueError(f"reserve({n})")
        if len(self.free) - self.reserved < n:
            return False
        self.reserved += n
        return True

    def unreserve(self, n: int) -> None:
        if n > self.reserved:
            raise RuntimeError(
                f"unreserve({n}) exceeds outstanding {self.reserved}")
        self.reserved -= n

    def incref(self, p: int) -> None:
        self.ref[p] += 1

    def decref(self, p: int) -> None:
        self.ref[p] -= 1
        if self.ref[p] == 0:
            self.version[p] += 1
            self.free.append(p)

    @property
    def used_pages(self) -> int:
        return (self.n_pages - 1) - len(self.free)

    @property
    def free_unreserved(self) -> int:
        return len(self.free) - self.reserved


class PrefixIndex:
    """Prompt-prefix dedup: hash chains over page-sized token blocks.

    Block i of a prompt keys on ``hash((key_{i-1}, block_tokens))`` so a
    match at block i implies the whole prefix matched; lookup walks blocks
    in order and stops at the first miss.  Values carry the page, the
    allocator version at registration, and the exact token tuple — a hit
    must pass refcount > 0, version equality AND token equality, which
    makes recycled pages and hash collisions both non-events (stale
    entries are pruned lazily).  The final partial block registers too;
    its token tuple is part of the key, so it only ever matches an
    identical-length identical-content tail (i.e. identical prompts) —
    divergent continuations fork it via copy-on-write at decode time."""

    def __init__(self, page_size: int):
        self.ps = page_size
        self.entries: dict = {}          # chain hash -> (page, ver, toks)

    def _blocks(self, prompt):
        h = 0x9E3779B9
        for i in range(0, len(prompt), self.ps):
            blk = tuple(int(t) for t in prompt[i:i + self.ps])
            h = hash((h, blk))
            yield h, blk

    def lookup(self, prompt, alloc: PageAllocator) -> List[tuple]:
        """Longest valid shared-page chain covering the prompt's leading
        blocks: [(page, n_tokens), ...]."""
        out = []
        for h, blk in self._blocks(prompt):
            e = self.entries.get(h)
            if e is None:
                break
            page, ver, toks = e
            if alloc.ref[page] <= 0 or alloc.version[page] != ver \
                    or toks != blk:
                del self.entries[h]      # page recycled since registration
                break
            out.append((page, len(blk)))
        return out

    def register(self, prompt, pages, alloc: PageAllocator) -> None:
        """Record block -> page for every prompt block (first writer
        wins; re-registering a shared page is a no-op)."""
        for (h, blk), page in zip(self._blocks(prompt), pages):
            if h not in self.entries:
                self.entries[h] = (int(page), int(alloc.version[page]), blk)

    def clear(self) -> None:
        self.entries.clear()


class AllocatorModel:
    """The engine's allocator discipline as a checkable transition system.

    ``tools/audit``'s small-scope interleaving check drives REAL
    ``PageAllocator`` instances through every op sequence up to a bounded
    depth; this class is the single authority on which ops exist and what
    each does, mirroring the engine's exact allocator interactions:

      * ``alloc``      — unreserved allocation (optimistic admission,
                         decode growth past a consumed reservation, COW):
                         guarded by ``free > reserved`` — the protection
                         that keeps admitted requests' reservations honored
      * ``reserve``    — admission sets one page of worst-case demand
                         aside (``PageAllocator.reserve``)
      * ``alloc_r``    — a reserved allocation consuming one unit
                         (``try_alloc(reserved=True)``; cannot fail while
                         the reservation invariant holds)
      * ``unreserve``  — a finishing / unwinding / preempted slot releases
                         an unmaterialized unit
      * ``incref(h)``  — a prefix-cache hit maps a held page into another
                         slot's table read-only
      * ``release(h)`` — a finished slot drops one table reference
                         (``_free_slot_pages``)
      * ``cow(h)``     — first divergent write to a still-shared page:
                         allocate a private copy, drop the shared
                         reference (``ServeEngine._cow_into``)
      * ``preempt(h)`` — preempt-and-requeue: atomically drop hold ``h``
                         AND every outstanding reservation unit (the
                         victim's tail demand), the decode-time exhaustion
                         recovery path
      * ``spec``       — speculative pre-allocation: a verify round maps
                         pages covering drafted-but-unverified positions
                         BEFORE the accept decision
                         (``ServeEngine._spec_step_all``)
      * ``rewind(h)``  — rollback of a speculative hold whose page turned
                         out wholly rejected: decref-and-unmap (the
                         optimistic-admission rollback arm)
      * ``commit(h)``  — the accept decision lands at least one token in
                         a speculative page: it becomes an ordinary
                         committed hold (released later by
                         ``_free_slot_pages``, never by rewind)

    State is ``(allocator, holds)`` where ``holds`` is the tuple of
    outstanding page-table references as ``(page, version-at-acquire,
    kind)`` triples — kind ``"c"`` for committed references, ``"s"`` for
    speculative ones still awaiting their verify verdict.  The checker
    asserts, at every reachable state: refcounts equal
    outstanding holds and never go negative, free pages are never held,
    ``0 <= reserved <= len(free)`` (reserved allocs can never fail), and
    any page recycled after an index entry was recorded carries a bumped
    version (so stale prefix-index entries always fail validation)."""

    def __init__(self, n_pages: int = 4, allocator_cls=None):
        self.n_pages = n_pages
        self.allocator_cls = allocator_cls or PageAllocator

    def initial(self):
        return self.allocator_cls(self.n_pages), ()

    def enabled_ops(self, alloc, holds):
        """Op labels legal in this state (guards mirror engine call
        sites, which only ever decref pages they hold)."""
        ops = []
        reserved = int(getattr(alloc, "reserved", 0))
        if len(alloc.free) > reserved:
            ops.append(("alloc",))
            ops.append(("spec",))
        # reserve is always attemptable — the ALLOCATOR's capacity check
        # is the contract under test (a refused reserve is backpressure,
        # i.e. a no-op state)
        ops.append(("reserve",))
        if reserved > 0:
            ops.append(("alloc_r",))
            ops.append(("unreserve",))
        for i, h in enumerate(holds):
            p, kind = h[0], h[2]
            ops.append(("incref", i))
            ops.append(("release", i))
            ops.append(("preempt", i))
            if kind == "s":
                # a speculative hold resolves exactly one way per round:
                # wholly rejected (rewind) or touched by an accepted
                # token (commit) — never released while still pending
                ops.append(("rewind", i))
                ops.append(("commit", i))
            if alloc.ref[p] > 1 and len(alloc.free) > reserved:
                ops.append(("cow", i))
        return ops

    def apply(self, alloc, holds, op):
        """Apply ``op`` to copies of (alloc, holds); returns the new pair."""
        import copy
        alloc = copy.deepcopy(alloc)
        holds = list(holds)
        kind = op[0]
        if kind == "alloc":
            p = alloc.try_alloc()
            if p is None:
                raise RuntimeError("enabled unreserved alloc failed")
            holds.append((p, int(alloc.version[p]), "c"))
        elif kind == "spec":
            p = alloc.try_alloc()               # _spec_step_all pre-alloc
            if p is None:
                raise RuntimeError("enabled speculative alloc failed")
            holds.append((p, int(alloc.version[p]), "s"))
        elif kind == "reserve":
            alloc.reserve(1)    # False = backpressure (state unchanged)
        elif kind == "alloc_r":
            p = alloc.try_alloc(reserved=True)
            if p is None:
                raise RuntimeError("reserved alloc failed — the "
                                   "reservation invariant is broken")
            holds.append((p, int(alloc.version[p]), "c"))
        elif kind == "unreserve":
            alloc.unreserve(1)
        elif kind == "incref":
            p = holds[op[1]][0]
            alloc.incref(p)
            holds.append((p, int(alloc.version[p]), "c"))
        elif kind == "release":
            p = holds.pop(op[1])[0]
            alloc.decref(p)
        elif kind == "rewind":
            p, _, hk = holds.pop(op[1])         # rollback: decref-unmap
            if hk != "s":
                raise ValueError("rewind of a non-speculative hold")
            alloc.decref(p)
        elif kind == "commit":
            p, ver, hk = holds[op[1]]           # accepted token landed
            if hk != "s":
                raise ValueError("commit of a non-speculative hold")
            holds[op[1]] = (p, ver, "c")
        elif kind == "cow":
            src = holds[op[1]][0]
            hk = holds[op[1]][2]
            dst = alloc.try_alloc()             # ServeEngine._cow_into
            if dst is None:                     # order: copy rows, then
                raise RuntimeError("enabled cow failed")  # drop the
            alloc.decref(src)                   # shared ref
            holds[op[1]] = (dst, int(alloc.version[dst]), hk)
        elif kind == "preempt":
            p = holds.pop(op[1])[0]
            alloc.decref(p)
            reserved = int(getattr(alloc, "reserved", 0))
            if reserved:
                alloc.unreserve(reserved)
        else:
            raise ValueError(f"unknown op {op!r}")
        return alloc, tuple(sorted(holds))


# ---------------------------------------------------------------------------
# speculative draft sources
# ---------------------------------------------------------------------------

class NgramDraft:
    """Self-drafting n-gram lookup over each slot's prompt + generated
    tokens (zero model cost — "prompt lookup" drafting).

    ``propose_one(history, k)`` matches the longest suffix of ``history``
    (up to ``n`` tokens) against an earlier occurrence in the same
    history and proposes the up-to-``k - 1`` tokens that followed the
    most recent match.  No match -> no drafts: the slot rides the verify
    batch with an effective k of 1, which is exactly one plain decode
    step.  Repetitive generations (the regime greedy low-entropy decode
    falls into) hit near-perfect acceptance."""

    kind = "ngram"

    def __init__(self, n: int = 3):
        self.n = n

    def propose_one(self, hist: List[int], k: int) -> List[int]:
        m = len(hist)
        for n in range(min(self.n, m - 1), 0, -1):
            pat = hist[m - n:]
            best: List[int] = []
            for s in range(m - n - 1, -1, -1):
                if hist[s:s + n] == pat:
                    cont = hist[s + n:s + n + k - 1]
                    if len(cont) == k - 1:
                        # most recent match with a FULL continuation —
                        # near the tail of a periodic stream the newest
                        # match is truncated by the history end, so keep
                        # scanning older occurrences for full length
                        return [int(t) for t in cont]
                    if cont and not best:
                        best = [int(t) for t in cont]
            if best:
                return best
        return []

    def admit(self, req: "Request", j: int) -> None:
        pass

    def observe(self, js, new_pos) -> None:
        pass

    def reset(self) -> None:
        pass


class DraftModel:
    """Tiny-config greedy draft model sharing the engine's dispatch mesh.

    Keeps its own batched contiguous cache (one row per engine slot) and
    a host ``dpos[j]`` high-water mark: the draft cache holds KV for
    positions ``[0, dpos[j])`` of slot ``j``'s accepted token stream.
    Drafting runs ``k - 1`` batched greedy ``serve_step`` calls (the
    same jitted decode the target uses, under whatever mesh context the
    engine runs in); after the engine's accept decision ``observe`` drops
    ``dpos`` to the new committed frontier, and the next round's
    catch-up loop re-feeds accepted tokens from the request's own token
    history — stale rows past ``dpos`` written for rejected drafts are
    invisible (the decode kpos mask hides positions past ``pos``) and
    get overwritten in place.

    The draft config is ``get_config(arch).reduced()`` with the TARGET's
    vocab size, so draft tokens index the same logit space the verify
    step scores."""

    kind = "draft"

    def __init__(self, target_cfg, n_slots: int, cache_len: int,
                 chunk: int, *, arch: Optional[str] = None, seed: int = 0):
        import jax
        import jax.numpy as jnp

        from repro.configs import get_config
        from repro.core import llm_a3c
        from repro.models import model as M

        dcfg = get_config(arch or "stablelm-1.6b").reduced()
        dcfg = dataclasses.replace(dcfg, vocab_size=target_cfg.vocab_size)
        if not M.supports_chunked_prefill(dcfg):
            raise ValueError(
                f"draft arch {dcfg.name}: no chunked-prefill path — the "
                "draft cache can't admit prompts in blocks")
        self.cfg, self.jax, self.jnp, self.M = dcfg, jax, jnp, M
        self.n_slots, self.cache_len, self.chunk = n_slots, cache_len, \
            chunk
        self.params = M.init_params(dcfg, jax.random.key(seed + 9173))
        self.step = jax.jit(llm_a3c.make_serve_step(dcfg, sample=False))
        self.prefill = llm_a3c.make_prefill_step(dcfg)
        self.key = jax.random.key(seed)          # greedy: never consumed
        self.cache = M.init_cache(dcfg, n_slots, cache_len,
                                  dtype=jnp.float32)
        self.dpos = np.zeros(n_slots, np.int32)
        s1 = jax.eval_shape(lambda: M.init_cache(dcfg, 1, cache_len))
        s2 = jax.eval_shape(lambda: M.init_cache(dcfg, 2, cache_len))
        self._bdim = jax.tree.map(
            lambda a, b: next((d for d in range(a.ndim)
                               if a.shape[d] != b.shape[d]), -1), s1, s2)
        bdims = self._bdim

        def write_row(big, small, j):
            def one(bd, b, s):
                if bd < 0:
                    return b
                row = jnp.take(s, 0, axis=bd).astype(b.dtype)
                return jax.lax.dynamic_update_index_in_dim(b, row, j, bd)
            return jax.tree.map(one, bdims, big, small)

        self._write_row = jax.jit(write_row, static_argnames=("j",))

    def warm_prefill(self, plen: int) -> None:
        """Compile every chunk offset a ``plen``-token admission can
        reach (called from the engine's warmup, outside timed regions)."""
        toks, plens, grid = _pad_group([np.zeros(plen, np.int32)], 1,
                                       self.chunk, self.cache_len)
        cache = self.M.init_cache(self.cfg, 1, self.cache_len,
                                  dtype=self.jnp.float32)
        _chunked_prefill(self.prefill, self.params, cache, toks, plens,
                         grid)

    def admit(self, req: "Request", j: int) -> None:
        """Chunk-prefill the slot's effective prompt into draft row ``j``
        (generated tokens fold in on a preempted restore, so the draft
        frontier re-syncs to the committed token stream)."""
        prompt = _eff_prompt(req)
        toks, plens, grid = _pad_group([prompt], 1, self.chunk,
                                       self.cache_len)
        cache = self.M.init_cache(self.cfg, 1, self.cache_len,
                                  dtype=self.jnp.float32)
        _, cache = _chunked_prefill(self.prefill, self.params, cache,
                                    toks, plens, grid)
        self.cache = self._write_row(self.cache, cache, j)
        self.dpos[j] = len(prompt)

    def propose(self, active: np.ndarray, hist: List[Optional[List[int]]],
                pos: np.ndarray, tok: np.ndarray,
                kmax: int) -> np.ndarray:
        """Return an (n_slots, kmax - 1) int32 draft matrix.  First the
        catch-up loop replays accepted tokens the draft cache hasn't
        consumed (at most one in steady state: the full-accept bonus
        token); rows already synced idempotently re-feed their last token
        — rewriting identical KV at the same position is a no-op.  Then
        ``kmax - 1`` greedy steps draft the continuation for every row at
        once; rows speculating with a smaller per-slot k just ignore the
        tail columns."""
        jnp = self.jnp
        n = self.n_slots
        while True:
            gap = np.where(active, pos - self.dpos, 0)
            if gap.max() <= 0:
                break
            feed_pos = np.where(gap > 0, self.dpos,
                                np.maximum(self.dpos - 1, 0))
            feed_tok = np.array(
                [hist[j][feed_pos[j]] if active[j] else 0
                 for j in range(n)], np.int32)
            _, _, self.cache = self.step(
                self.params, self.cache,
                {"tokens": jnp.asarray(feed_tok[:, None])},
                jnp.asarray(feed_pos), self.key)
            self.dpos = np.where(gap > 0, self.dpos + 1, self.dpos)
        drafts = np.zeros((n, max(kmax - 1, 1)), np.int32)
        cur = np.where(active, tok, 0).astype(np.int32)
        dp = np.where(active, pos, 0).astype(np.int32)
        for i in range(kmax - 1):
            out, _, self.cache = self.step(
                self.params, self.cache,
                {"tokens": jnp.asarray(cur[:, None])},
                jnp.asarray(dp), self.key)
            cur = np.asarray(out, np.int32)
            drafts[:, i] = cur
            dp = dp + 1
        self._drafted = kmax - 1
        return drafts

    def observe(self, js, new_pos) -> None:
        """Accept verdict: slot ``j``'s committed frontier moved to
        ``new_pos``.  Drafting wrote rows up to ``dpos + drafted - 1``
        with tokens that match the accepted stream exactly as far as the
        accepted prefix reaches, so the new draft frontier is
        ``min(new_pos, dpos + drafted)`` — a full accept leaves a gap of
        one (the bonus token's KV) for next round's catch-up loop."""
        drafted = getattr(self, "_drafted", 0)
        for j, p in zip(js, new_pos):
            self.dpos[j] = min(int(p), int(self.dpos[j]) + drafted)

    def reset(self) -> None:
        self.dpos[:] = 0
        self.cache = self.M.init_cache(self.cfg, self.n_slots,
                                       self.cache_len,
                                       dtype=self.jnp.float32)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

class ServeEngine:
    """Slot table + schedulers around one jitted per-slot ``serve_step``.

    The model cache is one batched pytree of ``n_slots`` rows; admission
    prefills the whole arrived group in one batch-``n_slots`` chunk chain
    (recurrent archs: a token loop per request) and lands each row in its
    freed slot via a single jitted masked-permutation write — generic over
    every cache kind, KV and recurrent alike (the batch dim per leaf is
    found once by diffing eval_shapes).
    """

    def __init__(self, cfg, params, *, n_slots: int, cache_len: int,
                 chunk: int = 128, sample: bool = True, seed: int = 0,
                 page_size: int = 128, n_pages: int = 0,
                 prefix_cache: bool = True, paged: Optional[bool] = None,
                 kv_dtype="f32", admission: str = "reserve",
                 fault_plan: Optional[FaultPlan] = None, clock=None,
                 retry_backoff: float = 0.05, spec: str = "off",
                 spec_k: int = 4, draft_arch: Optional[str] = None,
                 draft_ngram: int = 3):
        import jax
        import jax.numpy as jnp

        from repro.core import llm_a3c
        from repro.kernels import kv_quant
        from repro.models import attention as attn_mod
        from repro.models import model as M

        self.cfg, self.params = cfg, params
        self.n_slots, self.cache_len, self.chunk = n_slots, cache_len, chunk
        self.sample = sample
        if admission not in ("reserve", "optimistic"):
            raise ValueError(f"admission policy {admission!r} (want "
                             "'reserve' or 'optimistic')")
        self.admission = admission
        if spec not in ("off", "ngram", "draft"):
            raise ValueError(f"spec mode {spec!r} (want 'off', 'ngram' "
                             "or 'draft')")
        self.spec = spec
        self.fault_plan = fault_plan
        # time authority: a custom clock makes time (and thus deadlines)
        # fully virtual — FaultPlan latencies advance it deterministically
        self.clock = clock if clock is not None else time.perf_counter
        self.virtual_time = clock is not None
        self.retry_backoff = retry_backoff
        self._t0: Optional[float] = None
        self._virtual = 0.0
        self.jnp, self.jax, self.M = jnp, jax, M
        self.serve_step = jax.jit(llm_a3c.make_serve_step(cfg,
                                                          sample=sample))
        self.prefill_step = llm_a3c.make_prefill_step(cfg)

        # paged layout: global-attention layers move to a shared page pool
        # + per-slot page tables.  Auto mode needs the chunked-prefill path
        # (recurrent archs keep per-request prefill loops on contiguous
        # state) and whole-page slots; ring layers stay contiguous inside
        # a paged cache either way.
        kinds = cfg.layer_kinds()

        # KV-cache storage dtype (f32/bf16/int8).  int8 only applies to
        # attention KV rows, so an arch with no attention layers has
        # nothing to quantize — logged fallback to f32, not a crash (the
        # dispatch arms themselves all consume quantized caches)
        kvd = kv_quant.resolve_kv_dtype(kv_dtype)
        if kv_quant.is_quantized(kvd) and \
                not any(k in ("attn", "attn_local") for k in kinds):
            logging.warning(
                "--kv-dtype int8 requested but arch %s has no attention "
                "layers (kinds=%s); recurrent state does not quantize — "
                "falling back to f32 cache storage", cfg.name, kinds)
            kvd = jnp.float32
        self.kv_dtype = kvd
        self.kv_dtype_name = {"float32": "f32", "bfloat16": "bf16",
                              "int8": "int8"}[jnp.dtype(kvd).name]
        if paged is None:
            paged = (self.prefill_step is not None
                     and "attn" in kinds
                     and cache_len % page_size == 0)
        self.paged = bool(paged)
        self.page_size = page_size
        self.max_pages = cache_len // page_size if self.paged else 0
        if self.paged:
            # worst case (no sharing): every slot fills its table, +1 sink
            self.n_pages = n_pages or n_slots * self.max_pages + 1
            self.paged_layout = attn_mod.PagedLayout(page_size, self.n_pages)
            self.alloc = PageAllocator(self.n_pages)
            self.prefix_cache = bool(prefix_cache)
            self.prefix_index = PrefixIndex(page_size)
            self.pt_host = np.full((n_slots, self.max_pages), -1, np.int32)
        else:
            self.n_pages = 0
            self.paged_layout = None
            self.prefix_cache = False
        self.cache = M.init_cache(cfg, n_slots, cache_len,
                                  dtype=jnp.float32,
                                  paged=self.paged_layout,
                                  kv_dtype=self.kv_dtype)
        # sampling keys are (request id, logical position) streams off the
        # session key — NOT the engine step count — so a slot that commits
        # three verified tokens in one speculative round and a slot that
        # takes three plain decode steps draw identical streams
        self.sample_first = jax.jit(
            lambda lg, key, sids, pos: llm_a3c.sample_slot_tokens(
                lg, key, sample=sample, sids=sids, pos=pos))
        self.base_key = jax.random.key(seed)
        # speculative decode: jitted verify (one fused k-position append
        # chunk per round, no cache writes) + deferred commit (scatter of
        # the accepted prefix), a draft source, per-slot adaptive k
        if spec != "off" and self.prefill_step is None:
            raise ValueError(
                f"--spec {spec}: {cfg.name} has no chunked-append path — "
                "recurrent caches can't score a k-token chunk in one "
                "call, so speculation has nothing to verify against")
        self.spec_k = max(2, int(spec_k)) if spec != "off" else 1
        if spec == "ngram":
            self.draft_src = NgramDraft(n=draft_ngram)
        elif spec == "draft":
            self.draft_src = DraftModel(cfg, n_slots, cache_len, chunk,
                                        arch=draft_arch, seed=seed)
        else:
            self.draft_src = None
        if spec != "off":
            # fused verify + accept + commit: one launch per round
            self.verify_step = jax.jit(
                llm_a3c.make_verify_step(cfg, cache_len, sample=sample))
        self.k_of = np.full(n_slots, self.spec_k, np.int32)
        self.accept_ema = np.full(n_slots, 1.0)
        self.spec_rounds = self.spec_drafted = 0
        self.spec_drafts_accepted = self.spec_wasted_tokens = 0
        self.spec_pages_rewound = 0
        self.accepted_k: List[int] = []
        # slot state (host side; shapes are static so no retraces)
        self.pos = np.zeros(n_slots, np.int32)
        self.tok = np.zeros(n_slots, np.int32)
        self.active = np.zeros(n_slots, bool)
        self.req_of: List[Optional[Request]] = [None] * n_slots
        self.step_count = 0
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self.prefill_wall = 0.0
        self.occupancy: List[float] = []
        self.page_occupancy: List[float] = []
        self.pages_requested = self.pages_alloced = 0
        self.cow_events = self.prefill_chunks_skipped = 0
        # robustness state: arrival queue (backpressure holds requests
        # here instead of admitting them into doomed slots), per-slot
        # outstanding reservation units, terminal sheds, counters
        self.queue: collections.deque = collections.deque()
        self.shed_requests: List[Request] = []
        self.resv_of = np.zeros(n_slots, np.int32)
        self.preemptions = self.requeues = 0
        self.sheds_admission = self.sheds_decode = self.retries = 0
        self.admission_alloc_failures = 0
        self.injected_alloc_failures = self.forced_preemptions = 0
        self.queue_depths: List[int] = []
        self._alloc_calls = 0
        self._fault_held: List[int] = []
        self._apply_fault_pressure()
        # batch-dim index per cache leaf (-1 for per-layer scalars like
        # "index", which have no batch dim): found once by diffing two
        # eval_shape batch sizes, so the admission scatter needs no shape
        # guessing at runtime.  Paged leaves get path-based codes on top:
        # -2 = shared page pool (kp/vp — no batch dim; admission takes the
        # group's pools wholesale since prefill updated them in place),
        # -3 = page table (pt — batch dim known from rank).
        pl = self.paged_layout
        kvd = self.kv_dtype
        s1 = jax.eval_shape(lambda: M.init_cache(cfg, 1, cache_len,
                                                 paged=pl, kv_dtype=kvd))
        s2 = jax.eval_shape(lambda: M.init_cache(cfg, 2, cache_len,
                                                 paged=pl, kv_dtype=kvd))
        bdim = jax.tree.map(
            lambda a, b: next((d for d in range(a.ndim)
                               if a.shape[d] != b.shape[d]), -1), s1, s2)

        def kind_of(path, bd):
            name = str(getattr(path[-1], "key", ""))
            if name in ("kp", "vp", "kps", "vps"):
                return -2   # scale pools ride the page pool: same code
            if name == "pt":
                return -3
            return bd
        self._bdim = jax.tree_util.tree_map_with_path(kind_of, bdim)
        # persistent admission-prefill cache (batch n_slots): stale rows
        # beyond a new request's prompt are hidden by the kpos/pos
        # invariant, so it never needs re-zeroing
        self._group_cache = M.init_cache(cfg, n_slots, cache_len,
                                         dtype=jnp.float32,
                                         paged=self.paged_layout,
                                         kv_dtype=self.kv_dtype)
        bdims = self._bdim

        def scatter(big, small, perm, mask):
            """big[j] <- small[perm[j]] where mask[j], per cache leaf —
            the whole admission scatter is one jitted call."""
            def one(bd, b, s):
                if bd == -2:
                    return s    # shared pool: the group's writes ARE the
                                # engine's (one physical pool)
                if bd == -3:
                    bd = b.ndim - 2   # page table (…, n_slots, max_pages)
                if bd < 0:
                    return b    # engine tracks per-slot pos itself
                idx = jnp.clip(perm, 0, s.shape[bd] - 1)
                taken = jnp.take(s, idx, axis=bd).astype(b.dtype)
                shape = [1] * b.ndim
                shape[bd] = -1
                return jnp.where(mask.reshape(shape), taken, b)
            return jax.tree.map(one, bdims, big, small)

        self._scatter = jax.jit(scatter)

        def build_group(group, engine, pt_rows):
            """Assemble the admission-prefill input cache: shared pools
            from the ENGINE cache (decode wrote pages since the last
            admission), page tables from the admission mapping, and
            contiguous / recurrent leaves from the persistent group
            cache."""
            def one(bd, g, e):
                if bd == -2:
                    return e
                if bd == -3:
                    return jnp.broadcast_to(pt_rows, g.shape)
                return g
            return jax.tree.map(one, bdims, group, engine)

        self._build_group = jax.jit(build_group)

        def set_pt(cache, pt):
            """Push the host page table into every pt leaf (decode-time
            incremental allocs / COW forks / completion frees)."""
            def one(bd, leaf):
                if bd == -3:
                    return jnp.broadcast_to(pt, leaf.shape)
                return leaf
            return jax.tree.map(one, bdims, cache)

        self._set_pt = jax.jit(set_pt)

        def copy_page(cache, src, dst):
            """Copy-on-write fork: pool row src -> dst in every layer's
            pools (scan-stacked pools carry a leading cycle dim)."""
            def one(bd, leaf):
                if bd != -2:
                    return leaf
                if leaf.ndim == 5:
                    return leaf.at[:, dst].set(leaf[:, src])
                return leaf.at[dst].set(leaf[src])
            return jax.tree.map(one, bdims, cache)

        self._copy_page = jax.jit(copy_page)

    # -- clock / fault plumbing --------------------------------------------

    def _apply_fault_pressure(self) -> None:
        """Seize ``FaultPlan.hold_pages`` from the pool at init/reset —
        standing pressure that shrinks the usable pool (never below one
        allocatable page)."""
        if self.paged and self.fault_plan and self.fault_plan.hold_pages:
            n = min(self.fault_plan.hold_pages, len(self.alloc.free) - 1)
            self._fault_held = [self.alloc.alloc() for _ in range(n)]
        else:
            self._fault_held = []

    @property
    def usable_pages(self) -> int:
        """Pages a request can actually get: pool minus sink minus any
        fault-plan standing pressure."""
        return self.n_pages - 1 - len(self._fault_held)

    def start_clock(self) -> None:
        self._t0 = self.clock()
        self._virtual = 0.0

    def now(self) -> float:
        """Seconds since ``start_clock`` plus injected virtual latency.
        Before the clock starts (direct ``admit``/``decode_step_all``
        driving in tests) time sits at the accumulated virtual offset."""
        if self._t0 is None:
            return self._virtual
        return self.clock() - self._t0 + self._virtual

    def advance(self, dt: float) -> None:
        """Wait ``dt`` seconds: a wall sleep on the real clock, a virtual
        jump under a test/fault clock (keeps idle waits deterministic)."""
        if dt <= 0:
            return
        if self.virtual_time:
            self._virtual += dt
        else:
            time.sleep(dt)

    def _try_alloc(self, *, reserved: bool = False) -> Optional[int]:
        """All engine page allocations funnel through here: numbers the
        global call sequence so a ``FaultPlan`` can fail chosen calls
        deterministically.  An injected failure never touches the
        allocator — reservations survive it and the caller recovers the
        same way it recovers real exhaustion."""
        i = self._alloc_calls
        self._alloc_calls += 1
        if self.fault_plan is not None and self.fault_plan.alloc_fails(i):
            self.injected_alloc_failures += 1
            return None
        return self.alloc.try_alloc(reserved=reserved)

    # -- scheduling: backpressure, deadlines, preemption --------------------

    def _need_pages(self, req: Request) -> int:
        """Pages to reserve at admission.  ``reserve`` policy: worst case,
        ceil((prompt + max_new)/page_size) clamped to the cache — decode
        can never exhaust.  ``optimistic``: just the effective prompt's
        pages — generation growth is overcommitted and recovered by
        preempt-and-requeue.

        Speculation widens the reserve worst case by ``spec_k - 1``: a
        verify round pre-maps pages covering up to that many tokens past
        the committed frontier, and under ``reserve`` those pages stay
        mapped through a rejection (the reservation already paid for
        them), so the never-preempts guarantee must cover the speculative
        in-flight tail too."""
        if not self.paged:
            return 0
        plen = len(req.prompt) + len(req.tokens)
        total = plen if self.admission == "optimistic" \
            else len(req.prompt) + req.max_new + self.spec_k - 1
        return -(-min(total, self.cache_len) // self.page_size)

    def enqueue(self, req: Request) -> None:
        if req.eff_arrival < 0:
            req.eff_arrival = req.arrival
        self.queue.append(req)

    def _shed_admission(self, req: Request, now: float) -> None:
        """TTFT deadline missed while queued: shed.  With retries left the
        request re-enqueues with exponential backoff (client-retry
        semantics — its TTFT clock restarts at the new effective
        arrival); otherwise it drops terminally."""
        self.sheds_admission += 1
        if req.retry_count < req.max_retries:
            req.retry_count += 1
            self.retries += 1
            req.eff_arrival = now + \
                self.retry_backoff * (2 ** (req.retry_count - 1))
            self.queue.append(req)
        else:
            req.shed_reason = "ttft-deadline"
            req.t_done = now
            self.shed_requests.append(req)

    def schedule_admissions(self, now: float) -> List[tuple]:
        """Pick queued requests for free slots, FIFO.  This is where
        backpressure lives: a paged admission must first ``reserve`` its
        page demand, and a head that doesn't fit blocks the line (no
        starvation — pool drain admits it first).  Retry-backoff entries
        whose effective arrival hasn't come are skipped, not blocking.
        TTFT-deadline misses shed here, before burning a prefill."""
        self.queue_depths.append(len(self.queue))
        pairs: List[tuple] = []
        free_slots = [j for j in range(self.n_slots)
                      if self.req_of[j] is None]
        i = 0
        while i < len(self.queue) and free_slots:
            req = self.queue[i]
            if req.eff_arrival > now:
                i += 1          # backoff pending; later entries may be due
                continue
            if req.deadline_ttft is not None and req.t_first < 0 \
                    and now - req.eff_arrival > req.deadline_ttft:
                del self.queue[i]
                self._shed_admission(req, now)
                continue
            need = self._need_pages(req)
            if self.paged and not self.alloc.reserve(need):
                break           # head-of-line waits for pool drain
            j = free_slots.pop(0)
            self.resv_of[j] = need
            del self.queue[i]
            pairs.append((req, j))
        return pairs

    def _release_reservation(self, j: int) -> None:
        if self.paged and self.resv_of[j]:
            self.alloc.unreserve(int(self.resv_of[j]))
            self.resv_of[j] = 0

    def _slot_alloc(self, j: int) -> Optional[int]:
        """Allocate one page for slot ``j``, consuming its admission
        reservation while any remains (reserved allocs cannot fail short
        of an injected fault, which leaves the unit intact); past the
        reservation it falls through to optimistic unreserved allocation."""
        if self.resv_of[j] > 0:
            p = self._try_alloc(reserved=True)
            if p is not None:
                self.resv_of[j] -= 1
            return p
        return self._try_alloc()

    def _choose_victim(self) -> Optional[int]:
        """Preemption victim: least decode progress first (cheapest
        re-prefill on restore), then most private pages (frees the most),
        then the youngest request — the oldest, furthest-along request is
        always protected, which is the forward-progress argument."""
        best, best_key = None, None
        for v in range(self.n_slots):
            req = self.req_of[v]
            if req is None:
                continue
            private = sum(1 for p in self.pt_host[v]
                          if p >= 0 and self.alloc.ref[int(p)] == 1) \
                if self.paged else 0
            k = (len(req.tokens), -private, -req.rid)
            if best_key is None or k < best_key:
                best, best_key = v, k
        return best

    def _preempt(self, v: int) -> None:
        """Evict slot ``v`` and requeue its request at the queue FRONT.
        Private pages free (decref); shared prefix pages keep their other
        references and stay in the ``PrefixIndex``, so restore re-maps
        them and chunk skipping makes the re-prefill cheap.  Generated
        tokens stay on the request — ``_eff_prompt`` folds them into the
        re-prefill, preserving greedy token-identity."""
        req = self.req_of[v]
        if self.paged:
            self._free_slot_pages(v)
        self.req_of[v] = None
        self.active[v] = False
        self.pos[v] = 0
        self.tok[v] = 0
        req.preemptions += 1
        self.preemptions += 1
        self.requeues += 1
        self.queue.appendleft(req)

    def _alloc_with_preemption(self, j: int) -> Optional[int]:
        """Decode-time page grab for slot ``j``: on exhaustion, preempt
        victims until the allocation succeeds or ``j`` preempts itself
        (returns None; the caller skips the now-empty slot).  Terminates:
        every failed attempt evicts one active slot, and ``j`` is always
        a candidate."""
        while True:
            p = self._slot_alloc(j)
            if p is not None:
                return p
            v = self._choose_victim()
            if v is None:       # unreachable: j itself is active
                raise RuntimeError(
                    "page pool exhausted with no preemptible slot")
            self._preempt(v)
            if v == j:
                return None

    # -- admission ----------------------------------------------------------

    def _write_rows(self, group_cache, row_to_slot):
        """Scatter rows of an admission-prefill cache into their assigned
        engine-cache slots (one jitted masked-permutation write)."""
        perm = np.zeros(self.n_slots, np.int32)
        mask = np.zeros(self.n_slots, bool)
        for i, j in row_to_slot:
            perm[j] = i
            mask[j] = True
        self.cache = self._scatter(self.cache, group_cache,
                                   self.jnp.asarray(perm),
                                   self.jnp.asarray(mask))

    def _map_prompt_pages(self, req: Request, j: int) -> Optional[int]:
        """Build one admitted request's page-table row: map matching
        cached prefix pages read-only (incref), allocate fresh pages for
        the rest, and register the prompt's blocks so LATER admissions —
        including requests in this same group — can share them.  Returns
        the shared coverage in tokens (drives chunk skipping), or None if
        the pool ran out mid-row — in which case every page already
        placed (incref'd prefix hits and fresh allocs alike) is unwound,
        so refcounts and ``used_pages`` return exactly to their
        pre-admission values (a partial row used to leak here).

        Prefix-hit increfs consume the slot's reservation units too: a
        shared page the request maps IS part of its materialized demand.

        Same-group sharing is safe because every non-skipped chunk's
        writes into a shared page replay the identical token values at the
        identical positions; first divergent DECODE writes fork the page
        via copy-on-write in ``decode_step_all``."""
        prompt = _eff_prompt(req)
        plen = len(prompt)
        n_p = -(-plen // self.page_size)
        self.pages_requested += n_p
        row = np.full(self.max_pages, -1, np.int32)
        matched = self.prefix_index.lookup(prompt, self.alloc) \
            if self.prefix_cache else []
        placed: List[int] = []
        cov = 0
        for idx, (page, ntok) in enumerate(matched):
            self.alloc.incref(page)
            if self.resv_of[j] > 0:
                self.alloc.unreserve(1)
                self.resv_of[j] -= 1
            row[idx] = page
            placed.append(page)
            cov += ntok
        for idx in range(len(matched), n_p):
            p = self._slot_alloc(j)
            if p is None:
                # unwind the partial row: the admission must be all or
                # nothing, else these pages leak unreferenced-but-held
                for q in placed:
                    self.alloc.decref(int(q))
                self._release_reservation(j)
                self.pages_requested -= n_p
                return None
            row[idx] = p
            placed.append(p)
            self.pages_alloced += 1
        if self.prefix_cache:
            self.prefix_index.register(prompt, row[:n_p], self.alloc)
        self.pt_host[j] = row
        return cov

    def _prefill_group(self, pairs: List[tuple], shared=None):
        """Chunked flash prefill for up to ``n_slots`` requests in ONE
        batched call chain (effective prompts right-padded to a shared
        chunk grid, rows beyond len(pairs) are dummies) — admission costs
        the same kernel launches as a full lockstep wave, shape-stable
        across group sizes.  Returns (first_tokens (n_slots,), cache).

        Paged layout: page tables were mapped (with prefix reuse) by
        ``admit`` before this call; ``shared`` carries each row's prefix
        coverage, and any chunk every row's coverage already spans — and
        that holds no row's last prompt token — is skipped outright: its
        KV already sits in the shared pages."""
        jnp = self.jnp
        prompts = [_eff_prompt(r) for r, _ in pairs]
        toks, plens, grid = _pad_group(prompts, self.n_slots, self.chunk,
                                       self.cache_len)
        skip: set = set()
        in_cache = self._group_cache
        if self.paged:
            pt_rows = np.full((self.n_slots, self.max_pages), -1, np.int32)
            for i, (_, j) in enumerate(pairs):
                pt_rows[i] = self.pt_host[j]
            in_cache = self._build_group(self._group_cache, self.cache,
                                         jnp.asarray(pt_rows))
            if self.prefix_cache and all(
                    k == "attn" for k in self.cfg.layer_kinds()):
                # ring layers keep contiguous caches that need every
                # chunk, so skipping is global-attention-only
                for p0, c in grid:
                    if all(pl <= p0 or (sh >= p0 + c and pl - 1 >= p0 + c)
                           for pl, sh in zip(plens[:len(pairs)], shared)):
                        skip.add(p0)
                self.prefill_chunks_skipped += len(skip)
        last, cache = _chunked_prefill(self.prefill_step, self.params,
                                       in_cache, toks, plens, grid,
                                       skip=skip)
        self._group_cache = cache
        # first token at logical position plen draws from the (rid, plen)
        # stream — same derivation every later decode/verify sample uses
        rids = np.zeros(self.n_slots, np.int32)
        for i, (r, _) in enumerate(pairs):
            rids[i] = r.rid
        first = self.sample_first(jnp.asarray(last), self.base_key,
                                  jnp.asarray(rids),
                                  jnp.asarray(plens, dtype=np.int32))
        return np.asarray(first), cache

    def _prefill_loop(self, req: Request, key):
        """Recurrent caches: token-by-token loop on a single-row cache."""
        jnp = self.jnp
        cache = self.M.init_cache(self.cfg, 1, self.cache_len,
                                  dtype=jnp.float32,
                                  kv_dtype=self.kv_dtype)
        prompt = _eff_prompt(req)
        for i in range(len(prompt)):
            tok, _, cache = self.serve_step(
                self.params, cache,
                {"tokens": jnp.asarray(prompt[None, i:i + 1])},
                jnp.asarray(i, jnp.int32),
                self.jax.random.fold_in(key, i))
        return int(tok[0]), cache

    def admit(self, pairs: List[tuple], now: float) -> List[Request]:
        """Admit ``pairs`` of (request, free slot) — one batched prefill
        for KV-cache archs, a per-request loop otherwise.  Returns the
        requests already satisfied by their prefill token (max_new == 1),
        which never occupy a slot.

        Paged page-table mapping happens first; a request whose mapping
        hits pool exhaustion is unwound (no leak) and requeued at the
        queue front — it drops out of this admission group instead of
        crashing it."""
        t0 = self.now()
        try:
            return self._admit(pairs, now)
        finally:
            # admission wall (prompt prefill + mapping + bookkeeping)
            # accumulates separately so _report can expose a decode-only
            # token rate — short-generation benches would otherwise
            # dilute decode-path comparisons with identical prefill cost
            self.prefill_wall += self.now() - t0

    def _admit(self, pairs: List[tuple], now: float) -> List[Request]:
        if not pairs:
            return []
        shared = None
        if self.paged:
            kept, shared = [], []
            for req, j in pairs:
                cov = self._map_prompt_pages(req, j)
                if cov is None:
                    self.admission_alloc_failures += 1
                    self.requeues += 1
                    req.eff_arrival = min(req.eff_arrival, now) \
                        if req.eff_arrival >= 0 else now
                    self.queue.appendleft(req)
                else:
                    kept.append((req, j))
                    shared.append(cov)
            pairs = kept
            if not pairs:
                return []
        if self.prefill_step is not None:
            first, cache = self._prefill_group(pairs, shared)
            self._write_rows(cache, [(i, j) for i, (_, j)
                                     in enumerate(pairs)])
            firsts = [int(first[i]) for i in range(len(pairs))]
        else:
            firsts = []
            for r, j in pairs:
                k = self.jax.random.fold_in(
                    self.base_key, np.uint32(2 ** 31 + r.rid))
                f, cache = self._prefill_loop(r, k)
                self._write_rows(cache, [(0, j)])
                firsts.append(f)
        finished = []
        freed = False
        for (req, j), f in zip(pairs, firsts):
            plen_eff = len(req.prompt) + len(req.tokens)
            self.prefill_tokens += plen_eff
            req.t_admit = now
            if req.t_first < 0:     # TTFT is first-ever token, so a
                req.t_first = now   # preempted restore keeps the original
            req.tokens.append(f)
            if len(req.tokens) >= req.max_new:
                req.t_done = now
                finished.append(req)    # slot stays free
                if self.paged:
                    self._free_slot_pages(j)
                    freed = True
                continue
            self.pos[j] = plen_eff
            self.tok[j] = f
            self.active[j] = True
            self.req_of[j] = req
            if self.draft_src is not None:
                # sync the draft source's frontier to the committed
                # stream (a preempted restore folds accepted tokens in)
                self.draft_src.admit(req, j)
        if freed:
            self._push_pt()
        return finished

    # -- decode -------------------------------------------------------------

    def _free_slot_pages(self, j: int) -> None:
        for p in self.pt_host[j]:
            if p >= 0:
                self.alloc.decref(int(p))
        self.pt_host[j] = -1
        # a finishing/preempted slot also drops its unmaterialized
        # worst-case tail — that headroom goes back to the queue
        self._release_reservation(j)

    def _push_pt(self) -> None:
        # snapshot: pt_host is mutated in place between pushes, and on
        # CPU both device_put and an identity-forwarding jit output can
        # alias an aligned numpy buffer — the device-side table must not
        # see later host edits
        self.cache = self._set_pt(self.cache,
                                  self.jnp.asarray(self.pt_host.copy()))

    def _cow_into(self, src: int, dst: int) -> int:
        """Fork a shared page before the first divergent write: copy the
        pool rows in every layer into the already-allocated private copy,
        drop our reference to the shared original."""
        jnp = self.jnp
        self.cache = self._copy_page(self.cache,
                                     jnp.asarray(src, jnp.int32),
                                     jnp.asarray(dst, jnp.int32))
        self.alloc.decref(src)
        self.cow_events += 1
        self.pages_alloced += 1
        return dst

    def _sids(self):
        """Per-slot sampling stream ids (request ids; idle rows draw
        from a garbage stream that is never consumed)."""
        return self.jnp.asarray(np.asarray(
            [r.rid if r is not None else 0 for r in self.req_of],
            np.int32))

    def _spec_step_all(self):
        """One speculative decode round over the slot table: draft up to
        ``k_j - 1`` tokens per slot, then score the whole (n_slots,
        spec_k) chunk, accept the longest matching draft prefix plus the
        bonus target token, and commit exactly the accepted rows' KV —
        all inside ONE fused jit launch — then roll back page-table
        state mapped for wholly-rejected positions on the host.

        Layout rules (DESIGN.md §spec-decode):

          * contiguous / ring: verify never writes, so KV rollback is a
            no-op by construction — ``pos`` simply doesn't advance past
            the accepted prefix and the kpos mask hides everything
            beyond it;
          * paged: pages covering ``[pos, pos + k_j)`` are pre-mapped
            before the verify (through ``_alloc_with_preemption``,
            consuming the slot's reservation first); a page whose every
            token was rejected is decref'd-and-unmapped under
            ``optimistic`` admission, or kept mapped under ``reserve``
            (the reservation already paid for it, and the kpos mask
            keeps its unwritten rows invisible until decode really
            reaches them — no churn, no new failure point);
          * a COW fork triggered for the round's first page (the only
            one that can be shared — shared pages hold prompt prefix)
            never rolls back: the accept rule commits at least one
            token, which is exactly the write the fork was for.

        Adaptive k: a per-slot EMA of the draft accept rate raises
        ``k_j`` back toward ``--spec-k`` on streaks of full accepts and
        drops it toward 2 when drafts keep missing, so a low-acceptance
        slot degenerates toward plain decode instead of burning verify
        positions.  Non-speculating and draft-less slots ride the same
        verify batch with an effective k of 1 (shape-stable: the batch
        is always (n_slots, spec_k))."""
        jnp = self.jnp
        step = self.step_count
        now = self.now()
        kk = self.spec_k
        if self.fault_plan is not None:
            lat = self.fault_plan.step_latency(step)
            if lat:
                self._virtual += lat
                now = self.now()
            forced = False
            for _ in range(self.fault_plan.forced_preempts(step)):
                v = self._choose_victim()
                if v is None:
                    break
                self._preempt(v)
                self.forced_preemptions += 1
                forced = True
            if forced and self.paged:
                self._push_pt()
        # -- per-slot draft chunks: row j = [tok_j, d_1 .. d_{k-1}] -----
        k_eff = np.ones(self.n_slots, np.int32)
        toks = np.zeros((self.n_slots, kk), np.int32)
        hist: List[Optional[List[int]]] = [None] * self.n_slots
        active = np.zeros(self.n_slots, bool)
        for j in range(self.n_slots):
            req = self.req_of[j]
            if req is None:
                continue
            active[j] = True
            toks[j, 0] = self.tok[j]
            # k_j clamps to the cache END only, never to the request's
            # remaining budget: verify may range past it (commit clamps
            # n_acc), which is the in-flight tail _need_pages and
            # _validate_trace charge for
            k_eff[j] = max(1, min(int(self.k_of[j]),
                                  self.cache_len - int(self.pos[j])))
            hist[j] = [int(t) for t in req.prompt] + req.tokens
        if self.spec == "draft":
            drafts = self.draft_src.propose(active, hist, self.pos,
                                            self.tok, kk)
            if kk > 1:
                toks[:, 1:] = drafts[:, :kk - 1]
        else:
            for j in range(self.n_slots):
                if active[j] and k_eff[j] >= 2:
                    props = self.draft_src.propose_one(hist[j],
                                                       int(k_eff[j]))
                    k_eff[j] = min(int(k_eff[j]), 1 + len(props))
                    if props:
                        toks[j, 1:k_eff[j]] = props[:int(k_eff[j]) - 1]
        # -- paged: pre-map every page the speculative span can touch --
        ps = self.page_size
        new_idx: dict = {}
        if self.paged:
            dirty = False
            for j in range(self.n_slots):
                if self.req_of[j] is None:
                    continue
                lo = int(self.pos[j]) // ps
                hi = (int(self.pos[j]) + int(k_eff[j]) - 1) // ps
                for idx in range(lo, hi + 1):
                    if self.req_of[j] is None:
                        break       # evicted as a victim mid-loop
                    page = int(self.pt_host[j, idx])
                    if page < 0:
                        p = self._alloc_with_preemption(j)
                        if p is None:
                            dirty = True     # j preempted itself
                            break
                        self.pt_host[j, idx] = p
                        self.pages_requested += 1
                        self.pages_alloced += 1
                        new_idx.setdefault(j, []).append(idx)
                        dirty = True
                    elif self.alloc.ref[page] > 1:
                        p = self._alloc_with_preemption(j)
                        if p is None:
                            dirty = True
                            break
                        # re-read: a preemption inside the alloc may
                        # have un-shared the page
                        page = int(self.pt_host[j, idx])
                        if page >= 0 and self.alloc.ref[page] > 1:
                            self.pt_host[j, idx] = self._cow_into(page, p)
                        else:
                            self.alloc.decref(p)  # fork no longer needed
                        dirty = True
            if dirty:
                self._push_pt()
        # preemptions above may have evicted slots already drafted
        for j in range(self.n_slots):
            if active[j] and self.req_of[j] is None:
                active[j] = False
                new_idx.pop(j, None)
        # remaining budget per slot: the fused accept clamps n_acc to it
        # (verify may range past it — the in-flight tail _need_pages and
        # _validate_trace charge for); 0 marks an idle row, which
        # accepts and commits nothing
        remaining = np.zeros(self.n_slots, np.int32)
        for j in range(self.n_slots):
            if active[j]:
                req = self.req_of[j]
                remaining[j] = req.max_new - len(req.tokens)
        # -- one fused verify + accept + commit over the whole table ---
        # single launch per round; syncing targets/n_acc below forces
        # the commit too, so every host-mutated buffer the call read
        # (pos, toks) is provably consumed before bookkeeping advances
        # it in place — the async zero-copy aliasing hazard a separate
        # commit launch had (see min_accept_margin's docstring) can't
        # recur by construction
        targets, n_acc, self.cache = self.verify_step(
            self.params, self.cache, {"tokens": jnp.asarray(toks)},
            jnp.asarray(self.pos), self.base_key, self._sids(),
            jnp.asarray(k_eff), jnp.asarray(remaining))
        targets = np.asarray(targets)
        n_acc = np.asarray(n_acc)
        # -- host bookkeeping of the device accept decision ------------
        for j in range(self.n_slots):
            if not active[j]:
                continue
            kj = int(k_eff[j])
            self.spec_drafted += kj - 1
            self.spec_drafts_accepted += int(n_acc[j]) - 1
            self.spec_wasted_tokens += kj - int(n_acc[j])
            self.accepted_k.append(int(n_acc[j]))
        self.spec_rounds += 1
        # -- paged rollback: unmap wholly-rejected pre-mapped pages ----
        if self.paged:
            dirty = False
            for j, idxs in new_idx.items():
                pos_new = int(self.pos[j]) + int(n_acc[j])
                for idx in idxs:
                    if idx * ps >= pos_new \
                            and self.admission == "optimistic":
                        self.alloc.decref(int(self.pt_host[j, idx]))
                        self.pt_host[j, idx] = -1
                        self.spec_pages_rewound += 1
                        dirty = True
            if dirty:
                self._push_pt()
        # -- bookkeeping: tokens, pos, adaptive k, finish/shed ---------
        finished = []
        freed_any = False
        obs_j, obs_pos = [], []
        for j in range(self.n_slots):
            if not active[j]:
                continue
            req = self.req_of[j]
            na = int(n_acc[j])
            req.tokens.extend(int(t) for t in targets[j, :na])
            self.decode_tokens += na
            self.pos[j] += na
            self.tok[j] = int(targets[j, na - 1])
            obs_j.append(j)
            obs_pos.append(int(self.pos[j]))
            if int(k_eff[j]) > 1:
                rate = (na - 1) / (int(k_eff[j]) - 1)
                self.accept_ema[j] = (0.7 * self.accept_ema[j]
                                      + 0.3 * rate)
                if self.accept_ema[j] > 0.75:
                    self.k_of[j] = min(int(self.k_of[j]) + 1,
                                       self.spec_k)
                elif self.accept_ema[j] < 0.35:
                    self.k_of[j] = max(int(self.k_of[j]) - 1, 2)
            if len(req.tokens) >= req.max_new:
                req.t_done = now
                self.active[j] = False
                self.req_of[j] = None
                self.pos[j] = 0
                self.tok[j] = 0
                self.k_of[j] = self.spec_k
                self.accept_ema[j] = 1.0
                finished.append(req)
                if self.paged:
                    self._free_slot_pages(j)
                    freed_any = True
            elif req.deadline_total is not None \
                    and now - req.arrival > req.deadline_total:
                req.t_done = now
                req.shed_reason = "total-deadline"
                self.sheds_decode += 1
                self.shed_requests.append(req)
                self.active[j] = False
                self.req_of[j] = None
                self.pos[j] = 0
                self.tok[j] = 0
                self.k_of[j] = self.spec_k
                self.accept_ema[j] = 1.0
                if self.paged:
                    self._free_slot_pages(j)
                    freed_any = True
        if self.spec == "draft":
            self.draft_src.observe(obs_j, obs_pos)
        self.step_count += 1
        if self.paged:
            if freed_any:
                self._push_pt()
            self.page_occupancy.append(
                self.alloc.used_pages / max(self.n_pages - 1, 1))
        self.occupancy.append(float(np.mean([r is not None
                                             for r in self.req_of])))
        return finished

    def decode_step_all(self):
        """One per-slot decode step over the whole slot table.

        Paged growth and COW forks go through ``_alloc_with_preemption``:
        pool exhaustion evicts a victim (requeued, not lost) instead of
        raising.  Total-deadline misses shed mid-decode.  FaultPlan hooks
        run first: injected latency advances the virtual clock, forced
        preemptions evict the victim-policy choice.

        With speculation on, every decode step is a speculative round
        (non-speculating slots ride the verify batch with an effective
        k of 1 — the shape-stable degenerate case)."""
        if self.spec != "off":
            return self._spec_step_all()
        jnp = self.jnp
        step = self.step_count
        now = self.now()
        if self.fault_plan is not None:
            lat = self.fault_plan.step_latency(step)
            if lat:
                self._virtual += lat
                now = self.now()
            forced = False
            for _ in range(self.fault_plan.forced_preempts(step)):
                v = self._choose_victim()
                if v is None:
                    break
                self._preempt(v)
                self.forced_preemptions += 1
                forced = True
            if forced and self.paged:
                self._push_pt()
        if self.paged:
            # the step writes row pos[j] of each active slot: grow the
            # table a page at a time, and fork (COW) any still-shared page
            # the write would land in
            dirty = False
            for j in range(self.n_slots):
                if self.req_of[j] is None:
                    continue
                idx = int(self.pos[j]) // self.page_size
                page = int(self.pt_host[j, idx])
                if page < 0:
                    p = self._alloc_with_preemption(j)
                    if p is None:
                        dirty = True        # j preempted itself
                        continue
                    self.pt_host[j, idx] = p
                    self.pages_requested += 1
                    self.pages_alloced += 1
                    dirty = True
                elif self.alloc.ref[page] > 1:
                    p = self._alloc_with_preemption(j)
                    if p is None:
                        dirty = True
                        continue
                    # re-read: a preemption inside the alloc may have
                    # dropped other references and un-shared the page
                    page = int(self.pt_host[j, idx])
                    if page >= 0 and self.alloc.ref[page] > 1:
                        self.pt_host[j, idx] = self._cow_into(page, p)
                    else:
                        self.alloc.decref(p)    # fork no longer needed
                    dirty = True
            if dirty:
                self._push_pt()
        tok, _, self.cache = self.serve_step(
            self.params, self.cache,
            {"tokens": jnp.asarray(self.tok[:, None])},
            jnp.asarray(self.pos), self.base_key, self._sids())
        self.step_count += 1
        tok = np.asarray(tok)
        finished = []
        freed_any = False
        for j in range(self.n_slots):
            req = self.req_of[j]
            if req is None:
                continue
            req.tokens.append(int(tok[j]))
            self.decode_tokens += 1
            self.pos[j] += 1
            self.tok[j] = int(tok[j])
            if len(req.tokens) >= req.max_new:
                req.t_done = now
                self.active[j] = False
                self.req_of[j] = None
                self.pos[j] = 0
                self.tok[j] = 0
                finished.append(req)
                if self.paged:
                    # free before the next step: a stale table row would
                    # let the idle slot's pos-0 write land in a page the
                    # allocator may hand to someone else
                    self._free_slot_pages(j)
                    freed_any = True
            elif req.deadline_total is not None \
                    and now - req.arrival > req.deadline_total:
                # mid-decode shed: past its total deadline the tokens are
                # worthless to the client — free the slot for the queue
                req.t_done = now
                req.shed_reason = "total-deadline"
                self.sheds_decode += 1
                self.shed_requests.append(req)
                self.active[j] = False
                self.req_of[j] = None
                self.pos[j] = 0
                self.tok[j] = 0
                if self.paged:
                    self._free_slot_pages(j)
                    freed_any = True
        if self.paged:
            if freed_any:
                self._push_pt()
            self.page_occupancy.append(
                self.alloc.used_pages / max(self.n_pages - 1, 1))
        self.occupancy.append(float(np.mean([r is not None
                                             for r in self.req_of])))
        return finished

    def reset(self):
        """Clear slot state and counters (compiled steps and caches stay
        warm) — used after the warmup pass.  Paged state resets too: fresh
        allocator, cleared prefix index, unmapped tables (stale pool
        content is unreachable once no table row names it — the kpos
        invariant)."""
        self.pos[:] = 0
        self.tok[:] = 0
        self.active[:] = False
        self.req_of = [None] * self.n_slots
        self.step_count = 0
        self.prefill_tokens = self.decode_tokens = 0
        self.prefill_wall = 0.0
        self.occupancy = []
        if self.paged:
            self.alloc = PageAllocator(self.n_pages)
            self.prefix_index.clear()
            self.pt_host[:] = -1
            self._push_pt()
        self.page_occupancy = []
        self.pages_requested = self.pages_alloced = 0
        self.cow_events = self.prefill_chunks_skipped = 0
        # robustness state: clear queue/sheds/counters, restart the fault
        # injector's deterministic counters, re-seize standing pressure on
        # the fresh allocator
        self.queue.clear()
        self.shed_requests = []
        self.resv_of[:] = 0
        self.preemptions = self.requeues = 0
        self.sheds_admission = self.sheds_decode = self.retries = 0
        self.admission_alloc_failures = 0
        self.injected_alloc_failures = self.forced_preemptions = 0
        self.queue_depths = []
        self._alloc_calls = 0
        self._t0 = None
        self._virtual = 0.0
        # speculative state: adaptive k back to the CLI ceiling, EMA
        # optimistic (first rounds draft at full k), counters zeroed,
        # draft cache re-synced to the empty slot table
        self.k_of[:] = self.spec_k
        self.accept_ema[:] = 1.0
        self.spec_rounds = self.spec_drafted = 0
        self.spec_drafts_accepted = self.spec_wasted_tokens = 0
        self.spec_pages_rewound = 0
        self.accepted_k = []
        if self.draft_src is not None:
            self.draft_src.reset()
        self._apply_fault_pressure()


def _warmup(eng: ServeEngine, trace: List[Request]) -> float:
    """Compile everything the run can hit, outside the timed region: every
    prefill chunk offset the trace can reach (admission prefills are
    always batch = n_slots, so these are exactly the run's shapes), the
    first-token sampler, and one decode step.

    Fault injection is suspended for the warmup pass (its deterministic
    call counters restart at reset anyway) so the warm request always
    completes its compile coverage."""
    t0 = time.perf_counter()
    plan, eng.fault_plan = eng.fault_plan, None
    if eng.prefill_step is not None:
        pmax = max((len(r.prompt) for r in trace), default=1)
        if eng.paged and (plan is not None
                          or eng.admission == "optimistic"
                          or eng.usable_pages <
                          eng.n_slots * eng.max_pages):
            # preemption is possible: a requeued request's re-prefill
            # folds generated tokens in, so chunk grids can reach
            # prompt + max_new - 1 — compile those offsets too
            pmax = min(eng.cache_len,
                       max((len(r.prompt) + r.max_new - 1 for r in trace),
                           default=1))
        toks, plens, grid = _pad_group(
            [np.zeros(pmax, np.int32)], eng.n_slots, eng.chunk,
            eng.cache_len)
        # paged warmup cache compiles the real (pool + table) shapes; its
        # all-unmapped tables route every write to the page-0 sink and
        # every read through fully-masked kpos — numerically safe garbage
        wc = eng.M.init_cache(eng.cfg, eng.n_slots, eng.cache_len,
                              dtype=eng.jnp.float32,
                              paged=eng.paged_layout,
                              kv_dtype=eng.kv_dtype)
        _chunked_prefill(eng.prefill_step, eng.params, wc, toks, plens,
                         grid)
        if eng.spec == "draft":
            # draft admissions are single-row prefills over the same
            # chunk grid — compile those offsets too
            eng.draft_src.warm_prefill(pmax)
    warm = Request(rid=-1, prompt=np.zeros(min(8, eng.cache_len - 1),
                                           np.int32),
                   max_new=2, arrival=0.0)
    eng.admit([(warm, 0)], 0.0)
    eng.decode_step_all()
    eng.fault_plan = plan      # before reset: it re-seizes hold_pages
    eng.reset()
    return time.perf_counter() - t0


def _report(mode: str, eng: ServeEngine, done: List[Request], wall: float,
            warmup_s: float) -> dict:
    lat = [r.t_done - r.arrival for r in done]
    ttft = [r.t_first - r.arrival for r in done]
    total_new = sum(len(r.tokens) for r in done)
    first_req = min(done, key=lambda r: r.rid) if done else None
    paged = {}
    if eng.paged:
        paged = {
            "page_size": eng.page_size,
            "n_pages": eng.n_pages,
            "usable_pages": eng.usable_pages,
            "page_occupancy": round(float(np.mean(eng.page_occupancy)), 3)
            if eng.page_occupancy else 0.0,
            "pages_requested": eng.pages_requested,
            "pages_alloced": eng.pages_alloced,
            "dedup_ratio": round(
                eng.pages_requested / max(eng.pages_alloced, 1), 3),
            "cow_events": eng.cow_events,
            "prefill_chunks_skipped": eng.prefill_chunks_skipped,
            "prefix_cache": eng.prefix_cache,
            "pool_high_water": int(eng.alloc.high_water),
        }
    robustness = {
        "admission_policy": eng.admission,
        "preemptions": eng.preemptions,
        "requeues": eng.requeues,
        "sheds": eng.sheds_admission + eng.sheds_decode,
        "sheds_admission": eng.sheds_admission,
        "sheds_decode": eng.sheds_decode,
        "shed_requests": len(eng.shed_requests),
        "retries": eng.retries,
        "admission_alloc_failures": eng.admission_alloc_failures,
        "queue_depth": _percentiles(eng.queue_depths),
        "fault_plan": eng.fault_plan is not None,
        "injected_alloc_failures": eng.injected_alloc_failures,
        "forced_preemptions": eng.forced_preemptions,
    }
    speculative = {"spec": eng.spec}
    if eng.spec != "off":
        from repro.launch import traffic
        drafted = eng.spec_drafted
        speculative.update({
            "spec_k": eng.spec_k,
            "draft_source": eng.draft_src.kind,
            "rounds": eng.spec_rounds,
            "drafted_tokens": drafted,
            "accepted_draft_tokens": eng.spec_drafts_accepted,
            "accept_rate": round(
                eng.spec_drafts_accepted / drafted, 3) if drafted else 0.0,
            "mean_accepted_k": round(
                float(np.mean(eng.accepted_k)), 3)
            if eng.accepted_k else 0.0,
            "wasted_tokens": eng.spec_wasted_tokens,
            "wasted_bytes": traffic.spec_wasted_bytes(
                eng.cfg, eng.spec_wasted_tokens),
            "pages_rewound": eng.spec_pages_rewound,
        })
    return {
        "paged": eng.paged, **paged,
        "kv_dtype": eng.kv_dtype_name,
        "mode": mode, "slots": eng.n_slots, "requests": len(done),
        "warmup_s": round(warmup_s, 3),
        "wall_s": round(wall, 3),
        "prefill_tokens": eng.prefill_tokens,
        "generated_tokens": total_new,
        "tokens_per_s": round(total_new / wall, 1) if wall else 0.0,
        # decode-phase rate: admission (prefill) wall subtracted, so legs
        # differing only in decode strategy compare undiluted
        "prefill_wall_s": round(eng.prefill_wall, 3),
        "decode_tokens_per_s": round(
            total_new / max(wall - eng.prefill_wall, 1e-9), 1)
        if wall else 0.0,
        "latency_s": _percentiles(lat),
        "ttft_s": _percentiles(ttft),
        "occupancy": round(float(np.mean(eng.occupancy)), 3)
        if eng.occupancy else 0.0,
        "chunked_prefill": eng.prefill_step is not None,
        "robustness": robustness,
        "speculative": speculative,
        # the FIRST REQUEST's first generated tokens, not the first decode
        # step across the batch
        "sample_tokens": first_req.tokens[:4] if first_req else [],
    }


def _drain(eng: ServeEngine, pending: List[Request], qi: int,
           done: List[Request]) -> int:
    """The shared serve loop: feed arrivals into the engine queue, let the
    scheduler admit (backpressure, deadlines, retries), decode; when the
    engine idles, jump to the next event (arrival or retry-backoff
    expiry) instead of spinning.  Runs until ``pending[qi:]``, the queue
    and the slot table are all empty; returns the advanced ``qi``."""
    while qi < len(pending) or eng.queue \
            or any(r is not None for r in eng.req_of):
        now = eng.now()
        while qi < len(pending) and pending[qi].arrival <= now:
            eng.enqueue(pending[qi])
            qi += 1
        done.extend(eng.admit(eng.schedule_admissions(now), now))
        if not any(r is not None for r in eng.req_of):
            nxt = [r.eff_arrival for r in eng.queue]
            if qi < len(pending):
                nxt.append(pending[qi].arrival)
            if not nxt:
                break
            eng.advance(min(nxt) - eng.now())
            continue
        done.extend(eng.decode_step_all())
    return qi


def run_engine(cfg, params, trace: List[Request], *, n_slots: int,
               cache_len: int, chunk: int, sample: bool, seed: int,
               page_size: int = 128, n_pages: int = 0,
               prefix_cache: bool = True,
               paged: Optional[bool] = None, kv_dtype="f32",
               admission: str = "reserve",
               fault_plan: Optional[FaultPlan] = None, clock=None,
               retry_backoff: float = 0.05, spec: str = "off",
               spec_k: int = 4, draft_arch: Optional[str] = None) -> dict:
    """Continuous batching: arrivals feed the engine queue, the scheduler
    admits under reservation backpressure into freed slots, per-slot
    decode (with preempt-and-requeue on pool exhaustion)."""
    eng = ServeEngine(cfg, params, n_slots=n_slots, cache_len=cache_len,
                      chunk=chunk, sample=sample, seed=seed,
                      page_size=page_size, n_pages=n_pages,
                      prefix_cache=prefix_cache, paged=paged,
                      kv_dtype=kv_dtype, admission=admission,
                      fault_plan=fault_plan, clock=clock,
                      retry_backoff=retry_backoff, spec=spec,
                      spec_k=spec_k, draft_arch=draft_arch)
    _validate_trace(trace, cache_len,
                    page_size=eng.page_size if eng.paged else None,
                    usable_pages=eng.usable_pages if eng.paged else None,
                    spec_k=eng.spec_k)
    warmup_s = _warmup(eng, trace)

    pending = sorted(trace, key=lambda r: r.arrival)
    done: List[Request] = []
    eng.start_clock()
    _drain(eng, pending, 0, done)
    wall = eng.now()
    return _report("engine", eng, done, wall, warmup_s)


def run_lockstep(cfg, params, trace: List[Request], *, n_slots: int,
                 cache_len: int, chunk: int, sample: bool, seed: int,
                 chunked_prefill: bool = True, page_size: int = 128,
                 n_pages: int = 0, prefix_cache: bool = True,
                 paged: Optional[bool] = None, kv_dtype="f32") -> dict:
    """Wave-batched baseline: admit ``n_slots`` requests at once (waiting
    until the whole wave has arrived), then decode until the wave's
    *slowest* request finishes before admitting the next wave.

    Runs on the same ``ServeEngine`` machinery as ``run_engine`` — same
    kernels, same (correct, per-request) prefill paths for every cache
    kind — so the benchmark difference between the two runners is purely
    the batching discipline: freed slots idle until the wave drains
    instead of taking the next arrival."""
    if not chunked_prefill and paged is None:
        paged = False   # the token-loop prefill writes contiguous caches
    eng = ServeEngine(cfg, params, n_slots=n_slots, cache_len=cache_len,
                      chunk=chunk, sample=sample, seed=seed,
                      page_size=page_size, n_pages=n_pages,
                      prefix_cache=prefix_cache, paged=paged,
                      kv_dtype=kv_dtype)
    if not chunked_prefill:
        eng.prefill_step = None
    _validate_trace(trace, cache_len,
                    page_size=eng.page_size if eng.paged else None,
                    usable_pages=eng.usable_pages if eng.paged else None)
    warmup_s = _warmup(eng, trace)

    pending = sorted(trace, key=lambda r: r.arrival)
    waves = [pending[i:i + n_slots]
             for i in range(0, len(pending), n_slots)]
    done: List[Request] = []
    eng.start_clock()
    for wave in waves:
        now = eng.now()
        wait = max(r.arrival for r in wave) - now
        if wait > 0:       # whole wave must have arrived (lockstep admit)
            eng.advance(wait)
            now = eng.now()
        done.extend(eng.admit(list(zip(wave, range(len(wave)))), now))
        # finished slots keep burning their decode step until the whole
        # wave drains — the cost the continuous engine removes
        while any(r is not None for r in eng.req_of):
            done.extend(eng.decode_step_all())
        # an undersized pool can have preempted wave members into the
        # queue — drain them before the next wave so lockstep stays a
        # complete baseline
        if eng.queue:
            _drain(eng, [], 0, done)
    wall = eng.now()
    return _report("lockstep", eng, done, wall, warmup_s)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _range(s: str):
    lo, hi = s.split(",")
    return int(lo), int(hi)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--mode", choices=("engine", "lockstep"),
                    default="engine")
    ap.add_argument("--slots", "--batch", dest="slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-range", type=_range, default=(16, 48),
                    help="uniform prompt-length range lo,hi")
    ap.add_argument("--gen-range", type=_range, default=(8, 32),
                    help="uniform generation-length range lo,hi")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson arrivals, requests/s (0 = all at t=0)")
    ap.add_argument("--chunk", type=int, default=128,
                    help="prefill chunk length (tokens per flash launch); "
                    "rounded to the nearest 128 multiple — the append "
                    "kernel's MXU alignment unit")
    ap.add_argument("--cache-len", type=int, default=0,
                    help="KV cache length (0 = max prompt + max gen)")
    ap.add_argument("--page-size", type=int, default=128,
                    help="paged-KV page size in tokens; rounded to the "
                    "nearest 128 multiple so page boundaries coincide "
                    "with the kernels' key-block tiles")
    ap.add_argument("--pages", type=int, default=0,
                    help="page-pool size (0 = worst case: slots * "
                    "pages-per-slot + 1 sink page)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable shared-prefix page reuse (isolates the "
                    "dedup win in benches; pages stay per-slot private)")
    ap.add_argument("--kv-dtype", default="f32",
                    choices=("f32", "bf16", "int8"),
                    help="KV cache storage dtype: f32, bf16 or int8 "
                    "(int8 stores per-(row, head) symmetric scales "
                    "alongside and dequantizes inside the kernels; archs "
                    "without attention layers log a fallback to f32)")
    ap.add_argument("--admission", choices=("reserve", "optimistic"),
                    default="reserve",
                    help="paged admission policy: 'reserve' holds back "
                    "worst-case ceil((prompt+max_new)/page_size) pages at "
                    "admission (decode can never exhaust); 'optimistic' "
                    "reserves only the prompt's pages and overcommits — "
                    "decode-time exhaustion preempts-and-requeues")
    ap.add_argument("--deadline-ttft", type=float, default=0.0,
                    help="per-request TTFT deadline in seconds (0 = none):"
                    " requests still queued past it are shed (with "
                    "--max-retries backoff re-enqueues)")
    ap.add_argument("--deadline-total", type=float, default=0.0,
                    help="per-request end-to-end deadline in seconds "
                    "(0 = none): decode past it sheds mid-flight")
    ap.add_argument("--max-retries", type=int, default=0,
                    help="re-enqueues (exponential backoff) granted to a "
                    "request shed at admission before it drops")
    ap.add_argument("--fault-plan", default="",
                    help="fault-injection plan: a JSON string or a path "
                    "to one (FaultPlan schema: fail_alloc_at, preempt_at, "
                    "latency_at, hold_pages) — deterministic overload "
                    "replay")
    ap.add_argument("--spec", choices=("off", "ngram", "draft"),
                    default="off",
                    help="speculative decoding: 'ngram' self-drafts from "
                    "each request's own history (prompt lookup, zero "
                    "model cost); 'draft' runs a tiny reduced-config "
                    "draft model on the same mesh; accepted tokens are "
                    "bit-identical to --spec off")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max verify-chunk length per slot (1 current "
                    "token + up to k-1 drafts); per-slot adaptive k "
                    "throttles below this on low acceptance")
    ap.add_argument("--draft-arch", default=None,
                    help="--spec draft: architecture name for the "
                    "reduced draft config (default stablelm-1.6b "
                    "reduced, re-vocabed to the target)")
    ap.add_argument("--greedy", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-seed", type=int, default=0)
    ap.add_argument("--decode-cp", action="store_true",
                    help="context-parallel serving: shard the KV cache's "
                    "sequence dim over the local devices (decode_cp rules "
                    "-> pallas_cp dispatch)")
    args = ap.parse_args()

    if args.chunk % 128 != 0:
        # a misaligned chunk size would push EVERY chunk of every prompt
        # off the fused append path (Sk = pos0 + C inherits the
        # misalignment) — round instead of silently serving on jnp
        rounded = max(128, round(args.chunk / 128) * 128)
        logging.warning(
            "--chunk %d is not a 128 multiple; rounding to %d so prefill "
            "chunks stay on the fused append kernel (misaligned chunks "
            "fall back to the jnp reference on every chunk)",
            args.chunk, rounded)
        args.chunk = rounded

    if args.page_size % 128 != 0:
        # a misaligned page size pushes the paged decode/append arms onto
        # the jnp oracle (page boundaries must coincide with key-block
        # tiles) — round instead of silently serving unfused
        rounded = max(128, round(args.page_size / 128) * 128)
        logging.warning(
            "--page-size %d is not a 128 multiple; rounding to %d so the "
            "paged dispatch arms stay on the fused kernels (misaligned "
            "pages fall back to the jnp reference)",
            args.page_size, rounded)
        args.page_size = rounded

    import jax

    from repro import compat
    from repro.configs import get_config
    from repro.distributed import ctx, sharding
    from repro.kernels import dispatch
    from repro.launch import hlo_analysis
    from repro.models import model as M

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family == "vlm" or cfg.is_encdec:
        raise SystemExit(
            f"{cfg.name}: the serve engine drives token-in/token-out LMs; "
            "VLM embeds / encoder-decoder memories have no request-queue "
            "source here (the decode dry-run still lowers those shapes)")
    params = M.init_params(cfg, jax.random.key(args.seed))
    cache_len = args.cache_len or (
        args.prompt_range[1] + args.gen_range[1])
    trace = gen_trace(args.requests, vocab=cfg.vocab_size,
                      prompt_range=args.prompt_range,
                      gen_range=args.gen_range,
                      arrival_rate=args.arrival_rate,
                      seed=args.trace_seed)
    for r in trace:
        r.deadline_ttft = args.deadline_ttft or None
        r.deadline_total = args.deadline_total or None
        r.max_retries = args.max_retries
    fault_plan = None
    if args.fault_plan:
        s = args.fault_plan
        if not s.lstrip().startswith("{"):
            with open(s) as f:
                s = f.read()
        fault_plan = FaultPlan.from_json(s)

    decode_layout = "replicated"
    combine_bytes = 0
    with contextlib.ExitStack() as stack:
        if args.decode_cp:
            n_dev = len(jax.devices())
            mesh = jax.make_mesh((1, n_dev), ("data", "model"))
            rules = sharding.decode_rules(cfg, mesh, batch_size=args.slots)
            stack.enter_context(compat.set_mesh(mesh))
            stack.enter_context(ctx.use_mesh(mesh))
            stack.enter_context(ctx.sharding_rules(rules))
            n_shards = rules["decode_cp"]["n_shards"]
            decode_layout = f"decode_cp[{n_shards}]"
            from repro.launch import traffic
            combine_bytes = traffic.decode_cp_combine_bytes(
                cfg, args.slots, n_shards)
        dispatch.clear_decision_log()

        kw = dict(n_slots=args.slots, cache_len=cache_len,
                  chunk=args.chunk, sample=not args.greedy,
                  seed=args.seed, page_size=args.page_size,
                  n_pages=args.pages,
                  prefix_cache=not args.no_prefix_cache,
                  kv_dtype=args.kv_dtype)
        if args.mode == "engine":
            rec = run_engine(cfg, params, trace,
                             admission=args.admission,
                             fault_plan=fault_plan, spec=args.spec,
                             spec_k=args.spec_k,
                             draft_arch=args.draft_arch, **kw)
        else:
            if args.spec != "off":
                raise SystemExit("--spec needs --mode engine (lockstep "
                                 "is the non-speculative baseline)")
            rec = run_lockstep(cfg, params, trace, **kw)

    rec.update({
        "arch": cfg.name,
        "prompt_range": list(args.prompt_range),
        "gen_range": list(args.gen_range),
        "arrival_rate": args.arrival_rate,
        "decode_layout": decode_layout,
        "cp_combine_bytes_per_token": combine_bytes,
        "kernel_dispatch": [
            r for r in hlo_analysis.kernel_dispatch_summary()
            if r["op"] in ("decode_attention", "flash_attention",
                           "flash_append", "decode_paged",
                           "append_paged", "flash_verify",
                           "verify_paged")],
    })
    print(json.dumps(rec))


if __name__ == "__main__":
    main()
