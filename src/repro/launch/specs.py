"""ShapeDtypeStruct input stand-ins for every (architecture x input shape).

``input_specs(cfg, shape_id)`` returns (kind, spec_dict) where kind is
"train" | "prefill" | "decode" and spec_dict matches what train_step /
forward / serve_step expect — weak-type-correct, shardable, no allocation.

Decode shapes mean ONE new token against a cache of seq_len (the RL actor
path); ``long_500k`` additionally requires sub-quadratic attention, which
dense archs satisfy via the sliding-window variant (``variant="+sw"``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig

S = jax.ShapeDtypeStruct

INPUT_SHAPES = {
    "train_4k":    dict(seq=4_096,   batch=256, kind="train"),
    "prefill_32k": dict(seq=32_768,  batch=32,  kind="prefill"),
    "decode_32k":  dict(seq=32_768,  batch=128, kind="decode"),
    "long_500k":   dict(seq=524_288, batch=1,   kind="decode"),
}

# long_500k applicability (DESIGN.md §4): native sub-quadratic for SSM /
# hybrid / chunked-local archs; dense & VLM run the sliding-window variant;
# whisper (enc-dec, bounded decoder context by construction) is skipped.
LONG_DECODE = {
    "qwen2-72b": "sw",
    "minicpm-2b": "sw",
    "yi-6b": "sw",
    "granite-moe-1b-a400m": "sw",
    "whisper-base": None,          # skipped — noted in DESIGN.md
    "zamba2-1.2b": "native",
    "xlstm-1.3b": "native",
    "llama4-scout-17b-a16e": "native",   # chunked-local attention layers
    "qwen2-vl-72b": "sw",
    "stablelm-1.6b": "sw",
}

SW_WINDOW = 8_192


def sliding_window_variant(cfg: ModelConfig) -> ModelConfig:
    """Dense arch -> all-local-attention variant for long-context decode."""
    return dataclasses.replace(
        cfg, name=cfg.name + "+sw",
        block_cycle=tuple("attn_local" if k == "attn" else k
                          for k in cfg.block_cycle),
        sliding_window=SW_WINDOW)


def maybe_long_variant(cfg: ModelConfig, shape_id: str) -> ModelConfig:
    if shape_id == "long_500k" and LONG_DECODE.get(cfg.name) == "sw":
        return sliding_window_variant(cfg)
    return cfg


def _token_batch(cfg: ModelConfig, b: int, s: int) -> Dict[str, Any]:
    if cfg.family == "vlm":
        # ViT stub: precomputed patch/text embeddings + M-RoPE position ids
        return {
            "embeds": S((b, s, cfg.d_model), jnp.bfloat16),
            "positions": S((3, b, s), jnp.int32),
        }
    return {"tokens": S((b, s), jnp.int32)}


def input_specs(cfg: ModelConfig, shape_id: str) -> Tuple[str, Dict[str, Any]]:
    sh = INPUT_SHAPES[shape_id]
    b, s, kind = sh["batch"], sh["seq"], sh["kind"]
    if kind == "train":
        batch = _token_batch(cfg, b, s)
        if cfg.is_encdec:
            batch["enc_frames"] = S((b, cfg.encoder_seq, cfg.d_model),
                                    jnp.bfloat16)
        if cfg.family == "vlm":
            batch["actions"] = S((b, s), jnp.int32)
        batch["rewards"] = S((b, s), jnp.float32)
        batch["discounts"] = S((b, s), jnp.float32)
        return kind, batch
    if kind == "prefill":
        batch = _token_batch(cfg, b, s)
        if cfg.is_encdec:
            batch["enc_frames"] = S((b, cfg.encoder_seq, cfg.d_model),
                                    jnp.bfloat16)
        return kind, batch
    # decode: one token + cache of length s
    batch = ({"embeds": S((b, 1, cfg.d_model), jnp.bfloat16),
              "positions": S((3, b, 1), jnp.int32)}
             if cfg.family == "vlm" else
             {"tokens": S((b, 1), jnp.int32)})
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, b, s, dtype=jnp.bfloat16))
    # per-slot decode positions + a threaded PRNG key (the engine folds the
    # step index in; serve_step folds the slot index per row)
    return kind, {"batch": batch, "cache": cache,
                  "pos": S((b,), jnp.int32),
                  "key": jax.eval_shape(lambda: jax.random.key(0))}


def params_specs(cfg: ModelConfig):
    """ShapeDtypeStructs for the full parameter pytree (no allocation)."""
    return jax.eval_shape(
        lambda k: M.init_params(cfg, k), jax.random.key(0))
