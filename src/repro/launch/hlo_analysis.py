"""Roofline-term extraction from compiled dry-run artifacts.

XLA's ``compiled.cost_analysis()`` counts each While (lax.scan) body ONCE,
not multiplied by trip count (verified in tests/test_hlo_analysis.py), which
makes it useless for scanned-layer models.  This module does a trip-count-
weighted walk of the optimized HLO text instead:

  * the module is split into computations and a module-wide symbol table of
    instruction result shapes is built (the compact printer omits operand
    types, so operands are resolved through the table);
  * ``while`` ops are matched to their condition/body computations and the
    trip count is recovered from the bound constant in the condition;
  * fusions/calls propagate weights into callee computations;
  * per-computation tallies (dot FLOPs, collective bytes) are combined
    bottom-up with the accumulated weights.

Collective byte accounting (per device, ring-algorithm upper bounds):
  all-gather: output bytes; all-reduce: 2x operand; reduce-scatter /
  all-to-all: operand; collective-permute: operand (one hop).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _type_bytes(type_str: str) -> int:
    """Sum byte sizes of every shaped literal in a type string (handles
    tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def _shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


def _elems(type_str: str) -> int:
    dims = _shape_dims(type_str)
    if dims is None:
        return 0
    n = 1
    for d in dims:
        n *= d
    return n


class Computation:
    def __init__(self, name: str, is_entry: bool):
        self.name = name
        self.is_entry = is_entry
        self.lines: List[str] = []


def split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.endswith("{") and "->" in line:
            m = _HDR_RE.match(line)
            if m and "=" not in line[:m.end()]:
                cur = Computation(m.group(2), bool(m.group(1)))
                comps[cur.name] = cur
                continue
        if line.startswith("}"):
            continue
        if cur is not None:
            cur.lines.append(line)
    return comps


def build_symbols(comps: Dict[str, Computation]) -> Dict[str, str]:
    """instruction name -> result type string."""
    sym: Dict[str, str] = {}
    for comp in comps.values():
        for line in comp.lines:
            m = _DEF_RE.match(line)
            if m:
                sym[m.group(1)] = m.group(2)
    return sym


def _operands(call_tail: str) -> List[str]:
    """'(%a, %b), attr=...' -> ['a', 'b'] (top-level operand names)."""
    out = []
    depth = 0
    for tok in re.finditer(r"[()]|%([\w.\-]+)", call_tail):
        t = tok.group(0)
        if t == "(":
            depth += 1
        elif t == ")":
            depth -= 1
            if depth <= 0:
                break
        elif depth >= 1:
            out.append(tok.group(1))
    return out


class Tally:
    __slots__ = ("collectives", "dot_flops", "calls", "whiles")

    def __init__(self):
        self.collectives = {k: 0.0 for k in _COLLECTIVE_KINDS}
        self.dot_flops = 0.0
        self.calls: List[str] = []
        self.whiles: List[Tuple[str, str]] = []   # (cond, body)


_OP_RE = re.compile(
    r"^(?:ROOT\s+)?%[\w.\-]+\s*=\s*(\(?.+?\)?)\s+([\w\-]+)\(")


def tally_computation(comp: Computation, sym: Dict[str, str]) -> Tally:
    t = Tally()
    for line in comp.lines:
        m = _OP_RE.match(line)
        if not m:
            continue
        result_type, op = m.groups()
        tail = line[m.end() - 1:]

        if op in ("all-gather", "all-gather-start", "all-reduce",
                  "all-reduce-start", "reduce-scatter", "all-to-all",
                  "ragged-all-to-all", "collective-permute",
                  "collective-permute-start"):
            kind = op.replace("-start", "").replace("ragged-", "")
            ops_ = _operands(tail)
            operand_bytes = sum(_type_bytes(sym.get(o, "")) for o in ops_)
            out_bytes = _type_bytes(result_type)
            if kind == "all-gather":
                b = out_bytes
            elif kind == "all-reduce":
                b = 2 * operand_bytes
            else:
                b = operand_bytes
            t.collectives[kind] += b
        elif op == "dot":
            ops_ = _operands(tail)
            out_elems = _elems(result_type)
            cm_ = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            lhs_dims = _shape_dims(sym.get(ops_[0], "")) if ops_ else None
            if cm_ and lhs_dims:
                contract = 1
                for i in (int(x) for x in cm_.group(1).split(",") if x):
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
                t.dot_flops += 2.0 * out_elems * contract
        elif op == "convolution":
            # flops ~= 2 * out_elems * (kernel spatial x in-channels)
            ops_ = _operands(tail)
            out_elems = _elems(result_type)
            rhs_dims = _shape_dims(sym.get(ops_[1], "")) if len(ops_) > 1 \
                else None
            if rhs_dims:
                k = 1
                for d in rhs_dims[:-1]:   # all but output-feature dim
                    k *= d
                t.dot_flops += 2.0 * out_elems * k
        elif op == "while":
            cond = re.search(r"condition=%?([\w.\-]+)", line)
            body = re.search(r"body=%?([\w.\-]+)", line)
            if cond and body:
                t.whiles.append((cond.group(1), body.group(1)))
        else:
            for callee in re.findall(
                    r"(?:calls|to_apply|condition|body|"
                    r"branch_computations)=\{?%?([\w.\-]+)", line):
                t.calls.append(callee)
    return t


def trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Largest scalar integer constant in the condition computation — the
    loop bound (condition comps contain only the counter compare)."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for line in cond.lines:
        for c in re.findall(r"constant\((\d+)\)", line):
            best = max(best, int(c))
    return best


def weighted_totals(hlo: str) -> Dict[str, float]:
    comps = split_computations(hlo)
    sym = build_symbols(comps)
    tallies = {name: tally_computation(c, sym) for name, c in comps.items()}
    entry = next((n for n, c in comps.items() if c.is_entry), None)

    memo: Dict[str, Dict[str, float]] = {}

    def visit(name: str, depth: int = 0) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        t = tallies.get(name)
        zero = {k: 0.0 for k in _COLLECTIVE_KINDS} | {"flops": 0.0}
        if t is None or depth > 60:
            return zero
        tot = dict(t.collectives)
        tot["flops"] = t.dot_flops
        memo[name] = zero  # cycle guard
        for callee in t.calls:
            sub = visit(callee, depth + 1)
            for k in tot:
                tot[k] += sub[k]
        for cond_name, body_name in t.whiles:
            n = trip_count(comps, cond_name)
            sub_b = visit(body_name, depth + 1)
            sub_c = visit(cond_name, depth + 1)
            for k in tot:
                tot[k] += n * (sub_b[k] + sub_c[k])
        memo[name] = tot
        return tot

    if entry is None:
        total = {k: 0.0 for k in _COLLECTIVE_KINDS} | {"flops": 0.0}
        for name in tallies:
            sub = visit(name)
    else:
        total = visit(entry)
    total["total"] = sum(total[k] for k in _COLLECTIVE_KINDS)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    t = weighted_totals(hlo_text)
    return {k: t[k] for k in _COLLECTIVE_KINDS} | {"total": t["total"]}


def kernel_dispatch_summary() -> List[dict]:
    """Deduped kernel-dispatch decisions recorded during the last lowering:
    which backend each op resolved to and — for jnp fallbacks — why.  Pairs
    the HLO-derived numbers above with the *reason* the program lowered the
    way it did (e.g. "heads do not divide the 16-way model axis")."""
    from repro.kernels import dispatch
    return dispatch.decision_summary()


def roofline_terms(*, hlo_flops: float, hbm_bytes: float,
                   collective_total: float, n_chips: int,
                   peak_flops: float, hbm_bw: float, ici_bw: float
                   ) -> Dict[str, float]:
    """Seconds per step for each roofline term.

    hlo_flops: whole-program weighted dot FLOPs -> / chips.
    hbm_bytes: per-chip HBM traffic (analytic model, launch/traffic.py).
    collective_total: per-chip collective bytes -> / per-chip link bw.
    """
    t_compute = hlo_flops / (n_chips * peak_flops)
    t_memory = hbm_bytes / hbm_bw
    t_coll = collective_total / ici_bw
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {"t_compute": t_compute, "t_memory": t_memory,
            "t_collective": t_coll, "dominant": dominant}
