"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base] —
fine-grained MoE: 32 experts, top-8, expert d_ff=512."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=0,                      # all FFN capacity lives in the experts
    vocab_size=49155,
    block_cycle=("attn",),
    n_experts=32,
    top_k=8,
    d_ff_expert=512,
    rope_theta=1e4,
    tie_embeddings=True,
    norm="rmsnorm",
    act="silu",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
