"""Architecture registry: the 10 assigned configs + the paper's own nets.

``get_config(arch_id)`` returns the full-size ModelConfig; every config file
also exposes ``CONFIG``.  ``input_specs(cfg, shape_id)`` builds the
ShapeDtypeStruct stand-ins for the dry-run.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import ModelConfig

ARCH_IDS = [
    "qwen2_72b",
    "minicpm_2b",
    "yi_6b",
    "granite_moe_1b_a400m",
    "whisper_base",
    "zamba2_1p2b",
    "xlstm_1p3b",
    "llama4_scout_17b_a16e",
    "qwen2_vl_72b",
    "stablelm_1p6b",
]

# CLI-facing ids (hyphenated, as assigned) -> module names
ALIASES: Dict[str, str] = {
    "qwen2-72b": "qwen2_72b",
    "minicpm-2b": "minicpm_2b",
    "yi-6b": "yi_6b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "whisper-base": "whisper_base",
    "zamba2-1.2b": "zamba2_1p2b",
    "xlstm-1.3b": "xlstm_1p3b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "stablelm-1.6b": "stablelm_1p6b",
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
