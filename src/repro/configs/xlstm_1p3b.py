"""xLSTM-1.3B [arXiv:2405.04517] — xLSTM[7:1]: 7 mLSTM blocks per sLSTM
block; no separate FFN (d_ff=0 — projections live inside the blocks)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_cycle=("mlstm",) * 7 + ("slstm",),
    lstm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    norm="rmsnorm",
    act="gelu",
    tie_embeddings=True,
    source="arXiv:2405.04517",
)
