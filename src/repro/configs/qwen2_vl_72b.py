"""Qwen2-VL-72B [arXiv:2409.12191] — Qwen2-72B backbone with M-RoPE
(temporal/height/width sections) and dynamic-resolution vision input.  The
ViT encoder is a STUB: input_specs supplies precomputed patch embeddings
(B, S, d_model) plus (3, B, S) M-RoPE position ids."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    block_cycle=("attn",),
    qkv_bias=True,
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    norm="rmsnorm",
    act="silu",
    source="arXiv:2409.12191",
)
