"""StableLM-2-1.6B [hf:stabilityai/stablelm-2-1_6b] — dense, LayerNorm,
partial rotary (25% of head_dim)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100352,
    block_cycle=("attn",),
    rotary_dim=16,               # rope_pct = 0.25 of head_dim 64
    rope_theta=1e4,
    norm="layernorm",
    act="silu",
    source="hf:stabilityai/stablelm-2-1_6b",
)
