"""Whisper-base [arXiv:2212.04356] — encoder-decoder; mel+conv frontend is a
STUB (input_specs supplies precomputed frame embeddings (B, 1500, 512))."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,                  # decoder layers
    encoder_layers=6,
    encoder_seq=1500,
    is_encdec=True,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
