"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E] — MoE
(16 experts, top-1) with iRoPE-style attention: 3 chunked-local layers per
global-attention layer.  Early fusion: forward also accepts precomputed
multimodal embeddings.  The HF shared-expert is folded into the routed
experts (noted in DESIGN.md §Arch-applicability)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=202048,
    block_cycle=("attn_local", "attn_local", "attn_local", "attn"),
    sliding_window=8192,
    n_experts=16,
    top_k=1,
    d_ff_expert=8192,
    rope_theta=5e5,
    norm="rmsnorm",
    act="silu",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
