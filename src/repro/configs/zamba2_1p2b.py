"""Zamba2-1.2B [arXiv:2411.15242] — Mamba2 backbone + one shared
attention+MLP block applied every 6 layers (distinct KV per application)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,                   # shared attention block's MLP
    vocab_size=32000,
    block_cycle=("mamba2",),
    shared_attn_every=6,
    ssm_state=64,
    ssm_heads=64,                # d_inner = 2*d_model = 4096 = 64 * 64
    ssm_head_dim=64,
    ssm_groups=1,
    rope_theta=1e4,
    tie_embeddings=True,
    norm="rmsnorm",
    act="gelu",
    source="arXiv:2411.15242",
)
