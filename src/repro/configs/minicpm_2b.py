"""MiniCPM-2B [arXiv:2404.06395] — llama-like dense; WSD lr schedule.

The WSD (warmup-stable-decay) schedule is the model's training-recipe
signature; it composes with the paper's per-worker LogUniform lr sampling in
repro.optim.schedules.wsd.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    block_cycle=("attn",),
    rope_theta=1e4,
    tie_embeddings=True,
    norm="rmsnorm",
    act="silu",
    source="arXiv:2404.06395",
)
