"""Qwen2-72B [arXiv:2407.10671] — dense GQA decoder, QKV bias."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    block_cycle=("attn",),
    qkv_bias=True,
    rope_theta=1e6,
    norm="rmsnorm",
    act="silu",
    source="arXiv:2407.10671",
)
