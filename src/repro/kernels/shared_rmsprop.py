"""Pallas TPU fused Shared-RMSProp update (paper Eq. 8-9).

The paper's optimizer contribution as a memory-bound fused kernel: the naive
HLO does 4 elementwise passes over HBM (square, ema, rsqrt, scale); this
kernel reads (g, grad) once and writes (new_g, update) once — one pass,
~2x less HBM traffic for the update step that every actor-learner executes.

Inputs are pre-flattened to (rows, 1024) lanes by ops.py (TPU vector lanes
are 128 wide; 1024 = 8 sublanes x 128 keeps the VPU saturated).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._interpret import default_interpret


def _kernel(g_ref, grad_ref, lr_ref, new_g_ref, upd_ref, *,
            alpha: float, eps: float):
    g = g_ref[...]
    dg = grad_ref[...]
    lr = lr_ref[0]
    new_g = alpha * g + (1.0 - alpha) * dg * dg
    new_g_ref[...] = new_g
    upd_ref[...] = lr * dg * jax.lax.rsqrt(new_g + eps)


def rmsprop_update_2d(g, grad, lr, *, alpha: float = 0.99, eps: float = 0.1,
                      block_rows: int = 256,
                      interpret: Optional[bool] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """g, grad: (rows, 1024) f32; lr scalar.  Returns (new_g, update)."""
    rows, lanes = g.shape
    br = min(block_rows, rows)
    assert rows % br == 0
    if interpret is None:
        interpret = default_interpret()
    kern = functools.partial(_kernel, alpha=alpha, eps=eps)
    return pl.pallas_call(
        kern,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, lanes), lambda i: (i, 0)),
            pl.BlockSpec((br, lanes), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((br, lanes), lambda i: (i, 0)),
            pl.BlockSpec((br, lanes), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((rows, lanes), g.dtype),
                   jax.ShapeDtypeStruct((rows, lanes), g.dtype)],
        interpret=interpret,
    )(g, grad, lr.reshape(1))
