"""Pallas TPU flash attention (prefill/train forward).

TPU adaptation of the GPU flash algorithm: instead of warp-level softmax
reductions, each grid step computes a (block_q x block_k) score tile as a
single MXU matmul with the online-softmax state (m, l, acc) held in VMEM
scratch across the innermost (arbitrary-order) KV grid dimension.  Block
shapes are MXU-aligned (multiples of 128 on the contracting/lane dims).

Grid: (batch, q_heads, n_q_blocks, n_k_blocks), KV innermost.
GQA: the k/v BlockSpec index maps q-head h to kv-head h // group, so
repeated KV heads are never materialized in HBM or VMEM.

``flash_attention_append`` decouples the q and kv grid dimensions for
chunked prefill (Sq != Sk): C/bq query blocks at absolute positions
``pos0 + i`` scan ceil(Sk/bk) key blocks covering the cache prefix plus
the chunk, with causal/sliding-window masks on absolute positions from a
runtime per-row ``kpos`` map (the decode kernel's validity convention)
and the ``tile_live`` skip for provably-dead prefix tiles.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels._interpret import default_interpret

NEG = -1e30


def tile_mask(iq, ik, block_q: int, block_k: int, causal: bool,
              window: Optional[int]):
    """(block_q, block_k) validity mask for score tile (iq, ik).  Shared by
    the forward and backward kernels — the backward reconstructs softmax
    tiles from the forward's saved lse, so the masks must stay identical.
    (The append kernel builds its own mask from the runtime kpos map.)"""
    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    return mask


def tile_live(iq, ik, block_q: int, block_k: int, causal: bool,
              window: Optional[int], q_offset: int = 0):
    """Scalar predicate: does score tile (iq, ik) contain ANY valid entry?

    The complement of ``tile_mask(...).any()`` but computable from the two
    program ids alone (no iota materialization), so kernels can predicate
    the whole tile body with ``pl.when``.  Returns None when no mask is
    active (every tile live) so callers can skip the guard entirely.
    ``q_offset`` places q rows at absolute positions like ``tile_mask``;
    it is only meaningful when key row index == absolute key position
    (a linear cache layout — ring layouts must not skip tiles).
    """
    live = None
    if causal:
        # live iff the smallest kpos can be <= the largest qpos
        live = ik * block_k <= q_offset + (iq + 1) * block_q - 1
    if window is not None:
        # live iff the largest kpos clears the smallest qpos' window floor
        w_live = (ik + 1) * block_k - 1 > q_offset + iq * block_q - window
        live = w_live if live is None else live & w_live
    return live


def masked_tile_fraction(s: int, block_q: int, block_k: int, causal: bool,
                         window: Optional[int]) -> float:
    """Fraction of (iq, ik) score tiles that are fully masked — the work
    the bwd kernels skip (``tile_live`` evaluated on plain ints)."""
    n_q, n_k = s // block_q, s // block_k
    dead = 0
    for iq in range(n_q):
        for ik in range(n_k):
            live = tile_live(iq, ik, block_q, block_k, causal, window)
            dead += live is not None and not live
    return dead / float(n_q * n_k)


def _kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, window: Optional[int], block_q: int, block_k: int,
            n_k: int, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                      # (bq, D)
    k = k_ref[0, :, 0, :]                # (bk, D)
    v = v_ref[0, :, 0, :]                # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    mask = tile_mask(iq, ik, block_q, block_k, causal, window)
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + \
        jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        l_safe = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        if lse_ref is not None:
            lse_ref[0, 0] = m_ref[...] + jnp.log(l_safe)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        block_q: int = 512, block_k: int = 512,
                        save_residuals: bool = False,
                        interpret: Optional[bool] = None):
    """q (B,S,Hq,D); k,v (B,S,Hkv,D) -> (B,S,Hq,D).

    With ``save_residuals`` also returns the per-row log-sum-exp
    (B,Hq,S) f32 — the statistic the backward kernel needs to
    reconstruct softmax tiles without a second online pass."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    n_q, n_k = s // bq, s // bk
    if interpret is None:
        interpret = default_interpret()

    grid = (b, hq, n_q, n_k)
    kern = functools.partial(
        _kernel, causal=causal, window=window, block_q=bq, block_k=bk,
        n_k=n_k, scale=d ** -0.5)
    out_specs = [pl.BlockSpec((1, 1, bq, d),
                              lambda b_, h, iq, ik: (b_, h, iq, 0))]
    out_shape = [jax.ShapeDtypeStruct((b, hq, s, d), q.dtype)]
    if save_residuals:
        out_specs.append(pl.BlockSpec((1, 1, bq),
                                      lambda b_, h, iq, ik: (b_, h, iq)))
        out_shape.append(jax.ShapeDtypeStruct((b, hq, s), jnp.float32))
    else:
        def kern(q_ref, k_ref, v_ref, o_ref, *scratch, _full=kern):
            _full(q_ref, k_ref, v_ref, o_ref, None, *scratch)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda b_, h, iq, ik, g=g: (b_, ik, h // g, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda b_, h, iq, ik, g=g: (b_, ik, h // g, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(jnp.moveaxis(q, 1, 2), k, v)
    o = out[0].swapaxes(1, 2)
    if save_residuals:
        return o, out[1]
    return o


# ---------------------------------------------------------------------------
# append mode (chunked prefill): Sq != Sk with a q-offset grid
# ---------------------------------------------------------------------------

def _append_kernel(q_ref, k_ref, v_ref, kpos_ref, *refs, pos0: int,
                   window: Optional[int], block_q: int, block_k: int,
                   n_k: int, scale: float, kpos_linear: bool, quant: bool):
    if quant:
        ks_ref, vs_ref, *refs = refs
    o_ref, m_ref, l_ref, acc_ref = refs
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # tile skip: on a linear key layout (key row index == absolute
    # position where valid) whole prefix tiles beyond the causal bound /
    # window floor are provably dead and the body never runs; rotated
    # (ring) layouts visit every tile and rely on the kpos mask alone
    live = tile_live(iq, ik, block_q, block_k, True, window,
                     q_offset=pos0) if kpos_linear else None

    def _body():
        q = q_ref[0, 0]                      # (bq, D)
        k = k_ref[0, :, 0, :]                # (bk, D)
        v = v_ref[0, :, 0, :]                # (bk, D)
        if quant:
            # dequant in VMEM: the int8 key stream carries per-(row, head)
            # f32 scales ((bk, 1) blocks) that broadcast over the lane dim
            k = k.astype(jnp.float32) * ks_ref[0, :, 0, :]
            v = v.astype(jnp.float32) * vs_ref[0, :, 0, :]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        # causal/window on ABSOLUTE positions: q row r sits at
        # pos0 + iq*bq + r; the key positions come from the runtime kpos
        # row map (-1 = unwritten slot), same validity the decode kernel
        # applies per cache row
        qpos = pos0 + iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kp = kpos_ref[0, :]                  # (bk,)
        mask = (kp[None, :] >= 0) & (kp[None, :] <= qpos)
        if window is not None:
            mask &= kp[None, :] > qpos - window
        s = jnp.where(mask, s, NEG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            jax.lax.dot_general(p.astype(v.dtype), v,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if live is None:
        _body()
    else:
        pl.when(live)(_body)

    @pl.when(ik == n_k - 1)
    def _finish():
        l_safe = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


def flash_attention_append(q, k, v, kpos, *, pos0: int,
                           window: Optional[int] = None,
                           block_q: int = 512, block_k: int = 512,
                           kpos_linear: bool = False,
                           interpret: Optional[bool] = None,
                           k_scale=None, v_scale=None):
    """Append-mode flash forward: a prompt chunk against a longer key
    stream (the KV-cache prefix plus the chunk itself).

    q (B, C, Hq, D) — chunk queries at absolute positions ``pos0 + i``;
    k, v (B, Sk, Hkv, D) — the key stream; kpos (B, Sk) [or (Sk,)] the
    absolute position held by each key row (-1 = invalid).  Returns
    (B, C, Hq, D).  The q and kv grid dimensions are decoupled
    (``n_q = C/bq``, ``n_k = Sk/bk``), so Sq != Sk is in-grid; causal and
    sliding-window masks evaluate on absolute positions.  With
    ``k_scale``/``v_scale`` ((B, Sk, Hkv, 1) f32) the key stream is int8
    and dequantized inside the kernel body.  Serving-only: no residuals,
    no backward."""
    b, c, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    bq = min(block_q, c)
    bk = min(block_k, sk)
    assert c % bq == 0 and sk % bk == 0, (c, sk, bq, bk)
    n_q, n_k = c // bq, sk // bk
    quant = k_scale is not None
    if kpos.ndim == 1:
        kpos = jnp.broadcast_to(kpos, (b, sk))
    if interpret is None:
        interpret = default_interpret()

    kern = functools.partial(
        _append_kernel, pos0=pos0, window=window, block_q=bq, block_k=bk,
        n_k=n_k, scale=d ** -0.5, kpos_linear=kpos_linear, quant=quant)
    in_specs = [
        pl.BlockSpec((1, 1, bq, d),
                     lambda b_, h, iq, ik: (b_, h, iq, 0)),
        pl.BlockSpec((1, bk, 1, d),
                     lambda b_, h, iq, ik, g=g: (b_, ik, h // g, 0)),
        pl.BlockSpec((1, bk, 1, d),
                     lambda b_, h, iq, ik, g=g: (b_, ik, h // g, 0)),
        pl.BlockSpec((1, bk), lambda b_, h, iq, ik: (b_, ik)),
    ]
    operands = [jnp.moveaxis(q, 1, 2), k, v, kpos.astype(jnp.int32)]
    if quant:
        in_specs += [
            pl.BlockSpec((1, bk, 1, 1),
                         lambda b_, h, iq, ik, g=g: (b_, ik, h // g, 0)),
            pl.BlockSpec((1, bk, 1, 1),
                         lambda b_, h, iq, ik, g=g: (b_, ik, h // g, 0)),
        ]
        operands += [k_scale.astype(jnp.float32),
                     v_scale.astype(jnp.float32)]
    out = pl.pallas_call(
        kern,
        grid=(b, hq, n_q, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, c, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out.swapaxes(1, 2)
