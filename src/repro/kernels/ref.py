"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None) -> jnp.ndarray:
    """q (B,S,Hq,D); k,v (B,S,Hkv,D) -> (B,S,Hq,D).  Naive softmax."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = d ** -0.5
    qg = q.reshape(b, s, hkv, g, d)
    logits = jnp.einsum("bshgd,bthd->bshgt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, :, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bshgt,bthd->bshgd", p, v.astype(jnp.float32))
    return o.reshape(b, s, hq, d).astype(q.dtype)


def flash_attention_append_ref(q, k, v, kpos, *, pos0: int,
                               window: Optional[int] = None) -> jnp.ndarray:
    """Append-mode oracle: q (B,C,Hq,D) at absolute positions pos0 + i;
    k,v (B,Sk,Hkv,D) the key stream (cache prefix + chunk); kpos (B,Sk)
    [or (Sk,)] absolute position per key row (-1 = invalid).
    -> (B,C,Hq,D).  Causal on absolute positions; grouped-head einsum so
    GQA never materializes repeated KV."""
    b, c, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = d ** -0.5
    kpos = jnp.broadcast_to(kpos, (b, sk))
    qpos = pos0 + jnp.arange(c)
    qg = q.reshape(b, c, hkv, g, d)
    logits = jnp.einsum("bshgd,bthd->bshgt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = (kpos[:, None, :] >= 0) & (kpos[:, None, :] <= qpos[None, :, None])
    if window is not None:
        mask &= kpos[:, None, :] > qpos[None, :, None] - window
    logits = jnp.where(mask[:, :, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bshgt,bthd->bshgd", p, v.astype(jnp.float32))
    return o.reshape(b, c, hq, d).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, kpos, pos) -> jnp.ndarray:
    """q (B,Hq,D); caches (B,L,Hkv,D); kpos (B,L) absolute position per slot
    (-1 = empty); pos (B,) current position per sequence.  -> (B,Hq,D).
    Lockstep shapes (kpos (L,), pos ()) broadcast to every row."""
    b, hq, d = q.shape
    hkv = k_cache.shape[2]
    g = hq // hkv
    scale = d ** -0.5
    length = k_cache.shape[1]
    kpos = jnp.broadcast_to(kpos, (b, length))
    pos = jnp.broadcast_to(jnp.asarray(pos), (b,))
    qg = q.reshape(b, hkv, g, d)
    logits = jnp.einsum("bhgd,blhd->bhgl", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    valid = (kpos >= 0) & (kpos <= pos[:, None])
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgl,blhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# int8 KV quantization (dequant-then-attend oracles)
# ---------------------------------------------------------------------------
#
# Each quant oracle is BY CONSTRUCTION the standalone dequant
# (``kv_quant.dequantize``) composed with the corresponding float oracle —
# the bit-for-bit pin the kernel parity tests rely on: a kernel that
# dequantizes inside its body must match dequantize-then-attend.

def dequant_ref(q8, scale, dtype=jnp.float32) -> jnp.ndarray:
    from repro.kernels import kv_quant
    return kv_quant.dequantize(q8, scale, dtype)


def decode_attention_quant_ref(q, k_cache, v_cache, k_scale, v_scale,
                               kpos, pos) -> jnp.ndarray:
    """int8 decode oracle: caches (B,L,Hkv,D) int8 with per-(row, head)
    scales (B,L,Hkv,1) f32; everything else as ``decode_attention_ref``."""
    return decode_attention_ref(q, dequant_ref(k_cache, k_scale),
                                dequant_ref(v_cache, v_scale), kpos, pos)


def flash_attention_append_quant_ref(q, k, v, k_scale, v_scale, kpos, *,
                                     pos0: int,
                                     window: Optional[int] = None
                                     ) -> jnp.ndarray:
    """int8 append oracle: key stream (B,Sk,Hkv,D) int8 + scales
    (B,Sk,Hkv,1) f32."""
    return flash_attention_append_ref(q, dequant_ref(k, k_scale),
                                      dequant_ref(v, v_scale), kpos,
                                      pos0=pos0, window=window)


def paged_gather_ref(pool, page_table) -> jnp.ndarray:
    """Gather a dense per-slot view from a shared page pool.

    pool (P, page_size, Hkv, D); page_table (B, M) int32, -1 = unmapped.
    -> (B, M * page_size, Hkv, D).  Unmapped rows gather page 0 (the
    reserved garbage sink); callers mask them out through kpos."""
    b, m = page_table.shape
    ps = pool.shape[1]
    safe = jnp.maximum(page_table, 0)
    dense = pool[safe]                       # (B, M, ps, Hkv, D)
    return dense.reshape(b, m * ps, pool.shape[2], pool.shape[3])


def paged_kpos_ref(page_table, page_size: int) -> jnp.ndarray:
    """kpos for a page-gathered dense view: row i of the view holds absolute
    position i iff its page is mapped, else -1.  (B, M) -> (B, M * ps)."""
    b, m = page_table.shape
    mapped = jnp.repeat(page_table >= 0, page_size, axis=1)
    idx = jnp.arange(m * page_size)
    return jnp.where(mapped, idx[None, :], -1)


def decode_attention_paged_ref(q, k_pool, v_pool, page_table, pos,
                               *, length: Optional[int] = None
                               ) -> jnp.ndarray:
    """Paged-layout decode oracle: gather the dense view through the page
    table, build the linear kpos map, and run the dense oracle.  ``length``
    statically truncates the view to the logical cache length so the
    compute stream is identical to the contiguous layout."""
    ps = k_pool.shape[1]
    k = paged_gather_ref(k_pool, page_table)
    v = paged_gather_ref(v_pool, page_table)
    kpos = paged_kpos_ref(page_table, ps)
    if length is not None:
        k, v, kpos = k[:, :length], v[:, :length], kpos[:, :length]
    return decode_attention_ref(q, k, v, kpos, pos)


def flash_attention_append_paged_ref(q, k_pool, v_pool, page_table,
                                     k_chunk, v_chunk, *, pos0: int
                                     ) -> jnp.ndarray:
    """Paged-layout append oracle: the key stream is the gathered prefix
    [0, pos0) from the page pool plus the chunk's own K/V.  Linear-attention
    only (no window — ring caches stay contiguous)."""
    ps = k_pool.shape[1]
    n_pre = -(-pos0 // ps)                   # pages covering [0, pos0)
    c = q.shape[1]
    if pos0 == 0:
        kpos = jnp.arange(c)
        return flash_attention_append_ref(q, k_chunk, v_chunk, kpos,
                                          pos0=0)
    pt = page_table[:, :n_pre]
    k_pre = paged_gather_ref(k_pool, pt)[:, :pos0].astype(q.dtype)
    v_pre = paged_gather_ref(v_pool, pt)[:, :pos0].astype(q.dtype)
    kpos_pre = paged_kpos_ref(pt, ps)[:, :pos0]
    k = jnp.concatenate([k_pre, k_chunk], axis=1)
    v = jnp.concatenate([v_pre, v_chunk], axis=1)
    b = q.shape[0]
    kpos_chunk = jnp.broadcast_to(pos0 + jnp.arange(c), (b, c))
    kpos = jnp.concatenate([kpos_pre, kpos_chunk], axis=1)
    return flash_attention_append_ref(q, k, v, kpos, pos0=pos0)


def decode_attention_paged_quant_ref(q, k_pool, v_pool, k_scale, v_scale,
                                     page_table, pos,
                                     *, length: Optional[int] = None
                                     ) -> jnp.ndarray:
    """Paged int8 decode oracle: pools (P,ps,Hkv,D) int8, scale pools
    (P,ps,Hkv,1) f32 gathered through the same page table (scales ride
    the pool), then the dense quant oracle."""
    ks = paged_gather_ref(k_scale, page_table)
    vs = paged_gather_ref(v_scale, page_table)
    if length is not None:
        ks, vs = ks[:, :length], vs[:, :length]
    k = paged_gather_ref(k_pool, page_table)
    v = paged_gather_ref(v_pool, page_table)
    kpos = paged_kpos_ref(page_table, k_pool.shape[1])
    if length is not None:
        k, v, kpos = k[:, :length], v[:, :length], kpos[:, :length]
    return decode_attention_quant_ref(q, k, v, ks, vs, kpos, pos)


def flash_attention_append_paged_quant_ref(q, k_pool, v_pool, k_scale,
                                           v_scale, page_table, k_chunk,
                                           v_chunk, ks_chunk, vs_chunk,
                                           *, pos0: int) -> jnp.ndarray:
    """Paged int8 append oracle: int8 pools + scale pools hold the prefix
    [0, pos0); the chunk rides alongside already quantized (the same
    bytes its cache write lands), so prefill attention and later decode
    reads see identical dequantized values."""
    ps = k_pool.shape[1]
    n_pre = -(-pos0 // ps)
    b, c = q.shape[:2]
    kpos_chunk = jnp.broadcast_to(pos0 + jnp.arange(c), (b, c))
    if pos0 == 0:
        return flash_attention_append_quant_ref(
            q, k_chunk, v_chunk, ks_chunk, vs_chunk, kpos_chunk, pos0=0)
    pt = page_table[:, :n_pre]
    k_pre = paged_gather_ref(k_pool, pt)[:, :pos0]
    v_pre = paged_gather_ref(v_pool, pt)[:, :pos0]
    ks_pre = paged_gather_ref(k_scale, pt)[:, :pos0]
    vs_pre = paged_gather_ref(v_scale, pt)[:, :pos0]
    kpos_pre = paged_kpos_ref(pt, ps)[:, :pos0]
    k = jnp.concatenate([k_pre, k_chunk], axis=1)
    v = jnp.concatenate([v_pre, v_chunk], axis=1)
    ks = jnp.concatenate([ks_pre, ks_chunk], axis=1)
    vs = jnp.concatenate([vs_pre, vs_chunk], axis=1)
    kpos = jnp.concatenate([kpos_pre, kpos_chunk], axis=1)
    return flash_attention_append_quant_ref(q, k, v, ks, vs, kpos,
                                            pos0=pos0)


def rmsprop_update_ref(g, grad, *, lr: float, alpha: float = 0.99,
                       eps: float = 0.1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Paper Eq. 8-9 (non-centered, shared-statistics RMSProp).
    Returns (new_g, update); caller applies params -= update."""
    new_g = alpha * g + (1.0 - alpha) * jnp.square(grad)
    update = lr * grad / jnp.sqrt(new_g + eps)
    return new_g, update


def rmsnorm_ref(x, scale, *, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(dt)
