"""Pallas TPU flash attention backward (dq, dk, dv).

Same tiling philosophy as the forward: score tiles are *recomputed* from
(q, k) one (block_q x block_k) MXU matmul at a time, softmax probabilities
are reconstructed from the forward's saved log-sum-exp (``p = exp(s - lse)``
— no second online pass), and the f32 accumulators live in VMEM scratch
across the innermost (arbitrary-order) grid dimension.

Two kernels, mirroring the classic FlashAttention-2 split:

  * ``dq``:  grid (batch, q_heads, n_q_blocks, n_k_blocks), KV innermost —
    each q block accumulates ``sum_k ds @ k`` across its KV tiles.  The
    softmax-jacobian correction ``delta = rowsum(do * o)`` is computed
    in-kernel on the first KV step (the o/do tiles are already resident —
    one fewer HBM pass than a separate precompute) and emitted as a second
    output for the dkv kernel to consume.
  * ``dkv``: grid (batch, q_heads, n_k_blocks, n_q_blocks), Q innermost —
    each (head, k block) accumulates ``p^T @ do`` and ``ds^T @ q`` across
    the q tiles that attend into it.

Fully-masked score tiles (upper-triangular causal tiles, tiles behind the
sliding window) are *skipped*: the matmul body is predicated on
``tile_live`` so the MXU never touches tiles whose softmax weight is
exactly zero.  Accumulator init/flush stay unconditional — they key off
grid position, not mask content.

GQA uses the forward's ``h // group`` BlockSpec index-map trick for the
K/V *reads* (repeated KV heads never touch HBM); the dk/dv *writes* are
per-query-head (a block revisited by every head of a group across outer
grid steps cannot accumulate safely), and the cheap ``(Hkv, G)`` group-sum
happens in jnp outside the kernel — identical to the blockwise-jnp path.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels._interpret import default_interpret
from repro.kernels.flash_attention import NEG, tile_live, tile_mask


def _recompute_p(q, k, lse, iq, ik, *, block_q, block_k, causal, window,
                 scale):
    """(block_q, block_k) softmax tile from saved statistics."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = tile_mask(iq, ik, block_q, block_k, causal, window)
    s = jnp.where(mask, s, NEG)
    return jnp.exp(s - lse[:, None])


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, o_ref, lse_ref, dq_ref,
               delta_ref, dq_acc_ref, delta_acc_ref, *, causal: bool,
               window: Optional[int], block_q: int, block_k: int, n_k: int,
               scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        dq_acc_ref[...] = jnp.zeros_like(dq_acc_ref)
        # fused delta: rowsum(do * o) over the q tile, once per q block
        delta = jnp.sum(do_ref[0, 0].astype(jnp.float32) *
                        o_ref[0, 0].astype(jnp.float32), axis=1)
        delta_acc_ref[...] = delta
        delta_ref[0, 0] = delta

    def _compute():
        q = q_ref[0, 0]                  # (bq, D)
        k = k_ref[0, :, 0, :]            # (bk, D)
        v = v_ref[0, :, 0, :]            # (bk, D)
        do = do_ref[0, 0]                # (bq, D)
        p = _recompute_p(q, k, lse_ref[0, 0], iq, ik, block_q=block_q,
                         block_k=block_k, causal=causal, window=window,
                         scale=scale)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_acc_ref[...][:, None]) * scale
        dq_acc_ref[...] += jax.lax.dot_general(
            ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    live = tile_live(iq, ik, block_q, block_k, causal, window)
    if live is None:
        _compute()
    else:
        pl.when(live)(_compute)

    @pl.when(ik == n_k - 1)
    def _finish():
        dq_ref[0, 0] = dq_acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc_ref, dv_acc_ref, *, causal: bool,
                window: Optional[int], block_q: int, block_k: int,
                n_q: int, scale: float):
    ik = pl.program_id(2)
    iq = pl.program_id(3)

    @pl.when(iq == 0)
    def _init():
        dk_acc_ref[...] = jnp.zeros_like(dk_acc_ref)
        dv_acc_ref[...] = jnp.zeros_like(dv_acc_ref)

    def _compute():
        q = q_ref[0, 0]                  # (bq, D)
        k = k_ref[0, :, 0, :]            # (bk, D)
        v = v_ref[0, :, 0, :]            # (bk, D)
        do = do_ref[0, 0]                # (bq, D)
        p = _recompute_p(q, k, lse_ref[0, 0], iq, ik, block_q=block_q,
                         block_k=block_k, causal=causal, window=window,
                         scale=scale)
        dv_acc_ref[...] += jax.lax.dot_general(
            p.astype(do.dtype), do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, 0][:, None]) * scale
        dk_acc_ref[...] += jax.lax.dot_general(
            ds.astype(q.dtype), q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    live = tile_live(iq, ik, block_q, block_k, causal, window)
    if live is None:
        _compute()
    else:
        pl.when(live)(_compute)

    @pl.when(iq == n_q - 1)
    def _finish():
        dk_ref[0, 0] = dk_acc_ref[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc_ref[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, *, causal: bool = True,
                        window: Optional[int] = None,
                        block_q: int = 512, block_k: int = 512,
                        interpret: Optional[bool] = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """q/o/do (B,S,Hq,D); k,v (B,S,Hkv,D); lse (B,Hq,S) f32.

    Returns (dq (B,S,Hq,D), dk (B,S,Hkv,D), dv (B,S,Hkv,D)).
    """
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    n_q, n_k = s // bq, s // bk
    if interpret is None:
        interpret = default_interpret()
    scale = d ** -0.5

    qh = jnp.moveaxis(q, 1, 2)                      # (B,Hq,S,D)
    doh = jnp.moveaxis(do, 1, 2)
    oh = jnp.moveaxis(o, 1, 2)

    # --- dq (+ fused delta): grid (B, Hq, n_q, n_k), KV innermost ----------
    dq, delta = pl.pallas_call(
        functools.partial(_dq_kernel, causal=causal, window=window,
                          block_q=bq, block_k=bk, n_k=n_k, scale=scale),
        grid=(b, hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda b_, h, iq, ik, g=g: (b_, ik, h // g, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda b_, h, iq, ik, g=g: (b_, ik, h // g, 0)),
            pl.BlockSpec((1, 1, bq, d),
                         lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, bq, d),
                         lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, iq, ik: (b_, h, iq)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, iq, ik: (b_, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, s, d), q.dtype),
            jax.ShapeDtypeStruct((b, hq, s), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qh, k, v, doh, oh, lse)
    dq = dq.swapaxes(1, 2)

    # --- dk/dv: grid (B, Hq, n_k, n_q), Q innermost -------------------------
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_dkv_kernel, causal=causal, window=window,
                          block_q=bq, block_k=bk, n_q=n_q, scale=scale),
        grid=(b, hq, n_k, n_q),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda b_, h, ik, iq: (b_, h, iq, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda b_, h, ik, iq, g=g: (b_, ik, h // g, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda b_, h, ik, iq, g=g: (b_, ik, h // g, 0)),
            pl.BlockSpec((1, 1, bq, d),
                         lambda b_, h, ik, iq: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, ik, iq: (b_, h, iq)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, ik, iq: (b_, h, iq)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, ik, iq: (b_, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, ik, iq: (b_, h, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, s, d), k.dtype),
            jax.ShapeDtypeStruct((b, hq, s, d), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bk, d), jnp.float32),
                        pltpu.VMEM((bk, d), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qh, k, v, doh, lse, delta)

    # group-sum the per-query-head dk/dv back to kv heads: (B,Hq,S,D) ->
    # (B,S,Hkv,D).  One small reduce; the kernels stay write-disjoint.
    dk = dk_h.reshape(b, hkv, g, s, d).sum(2).swapaxes(1, 2)
    dv = dv_h.reshape(b, hkv, g, s, d).sum(2).swapaxes(1, 2)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)
