"""Symmetric int8 KV-cache quantization: the canonical quant/dequant pair.

Decode at serving scale is HBM-bound on cache reads — every generated
token streams the whole KV prefix — so an int8 cache halves the pool
bytes and roughly doubles the slots a fixed HBM budget sustains (the
capacity model in ``launch/traffic.py``).  One quantization scheme is
used everywhere (model-layer writes, kernel-body dequant, jnp oracles):

  * **Granularity**: per-(cache row, kv head) symmetric absmax.  Each
    written row ``(…, Hkv, D)`` carries an f32 scale ``(…, Hkv, 1)`` —
    in the paged layout that is per (page, in-page offset, head), stored
    alongside the pool and sharded like it.  Row granularity is what
    makes quantize-on-write O(new token) (a per-page scale would need a
    whole-page rescan every decode write) and keeps garbage rows — the
    page-0 sink, unwritten slots — from poisoning any live row's scale.
  * **Zero init is safe**: unwritten rows hold scale 0, so dequant
    yields exact zeros; kpos masks them out of the softmax anyway.
  * **Scales are rank-matched** to their payload with a trailing
    singleton (``(B, L, Hkv, 1)`` next to ``(B, L, Hkv, D)``), so every
    layout-level treatment of a K/V leaf — sharding specs, page COW
    copies, admission scatters — applies to the scale leaf verbatim.

``quantize`` is the single write-side entry point and ``dequantize`` the
single read-side one; the Pallas kernels inline the same two-op dequant
(int8 -> f32 multiply by the broadcast scale) in VMEM so the HBM stream
stays int8.
"""
from __future__ import annotations

import jax.numpy as jnp

QMAX = 127.0
# absmax floor: rows of exact zeros quantize with scale 0 (dequant gives
# zeros back); any nonzero row divides by at least this
EPS = 1e-12

KV_DTYPES = ("f32", "bf16", "int8")


def resolve_kv_dtype(name):
    """CLI/config name -> jnp dtype (passthrough for dtype objects)."""
    table = {"f32": jnp.float32, "bf16": jnp.bfloat16, "int8": jnp.int8}
    if isinstance(name, str):
        if name not in table:
            raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, "
                             f"got {name!r}")
        return table[name]
    return jnp.dtype(name)


def is_quantized(dtype) -> bool:
    return jnp.dtype(dtype) == jnp.int8


def dtype_name(dtype) -> str:
    """jnp dtype -> the short CLI/report name ("f32", "bf16", "int8")."""
    return {"float32": "f32", "bfloat16": "bf16",
            "int8": "int8"}[jnp.dtype(dtype).name]


def quantize(x):
    """Symmetric per-(row, head) absmax quantization over the last dim.

    x (…, D) float -> (q (…, D) int8, scale (…, 1) f32) with
    ``q * scale ~= x``.  Deterministic round-to-nearest (no stochastic
    rounding: cache writes must be bit-reproducible across the engine's
    replay paths — prefix-sharing admission re-writes must land identical
    bytes)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = amax / QMAX
    q = jnp.round(xf / jnp.maximum(scale, EPS))
    q = jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)
    return q, scale


def dequantize(q, scale, dtype=jnp.float32):
    """q (…, D) int8, scale (…, 1) f32 -> (…, D) ``dtype``."""
    return (q.astype(jnp.float32) * scale).astype(dtype)
