"""Pallas TPU fused RMSNorm (forward + backward).

Every block of every assigned architecture runs 2+ RMSNorms per layer; the
naive HLO chain (square -> mean -> rsqrt -> mul -> mul) makes multiple HBM
passes over the (B*S, d) activation.  The forward reads x once and writes y
once, with the f32 reduction done in VMEM; with ``save_residuals`` it also
emits the per-row reciprocal RMS (rstd) — the only statistic the backward
needs.

The backward is one pass over (x, dy): per row-block it computes

    dx     = rstd * (dy * scale - x * rstd^2 * mean_d(dy * scale * x))
    dscale = sum_rows(dy * x * rstd)            (per-block partial)

and the tiny (n_blocks, d) dscale partials are summed outside the kernel —
cross-row reduction inside would serialize the grid.  Rows are tiled
(block_rows x d); d is padded by the dispatch layer to the 128-lane
boundary if needed.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._interpret import default_interpret


def _kernel(x_ref, scale_ref, o_ref, rstd_ref, *, eps: float, d_real: int):
    x = x_ref[...].astype(jnp.float32)          # (br, d)
    # mean of squares over the REAL feature width (padding contributes 0)
    var = jnp.sum(x * x, axis=-1, keepdims=True) / d_real
    rstd = jax.lax.rsqrt(var + eps)
    y = x * rstd * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)
    if rstd_ref is not None:
        rstd_ref[...] = rstd[:, 0]


def rmsnorm_fwd(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
                save_residuals: bool = False,
                interpret: Optional[bool] = None):
    """x (rows, d); scale (d,).  Returns normalized x (same dtype), plus the
    per-row rstd (rows,) f32 when ``save_residuals``."""
    rows, d = x.shape
    br = min(block_rows, rows)
    while rows % br:
        br //= 2
    if interpret is None:
        interpret = default_interpret()
    kern = functools.partial(_kernel, eps=eps, d_real=d)
    out_specs = [pl.BlockSpec((br, d), lambda i: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((rows, d), x.dtype)]
    if save_residuals:
        out_specs.append(pl.BlockSpec((br,), lambda i: (i,)))
        out_shape.append(jax.ShapeDtypeStruct((rows,), jnp.float32))
    else:
        def kern(x_ref, scale_ref, o_ref, _full=kern):
            _full(x_ref, scale_ref, o_ref, None)
    out = pl.pallas_call(
        kern,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, scale)
    if save_residuals:
        return out[0], out[1]
    return out[0]


def _bwd_kernel(x_ref, scale_ref, rstd_ref, dy_ref, dx_ref, dscale_ref, *,
                d_real: int):
    x = x_ref[...].astype(jnp.float32)           # (br, d)
    dy = dy_ref[...].astype(jnp.float32)
    s = scale_ref[...].astype(jnp.float32)       # (d,)
    r = rstd_ref[...][:, None]                   # (br, 1)
    dys = dy * s[None, :]
    c = jnp.sum(dys * x, axis=-1, keepdims=True) / d_real
    dx = (dys - x * (r * r) * c) * r
    dx_ref[...] = dx.astype(dx_ref.dtype)
    dscale_ref[...] = jnp.sum(dy * x * r, axis=0)[None, :]


def rmsnorm_bwd(x, scale, rstd, dy, *, block_rows: int = 256,
                interpret: Optional[bool] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-pass dx/dscale from the saved rstd.  x/dy (rows, d); scale (d,);
    rstd (rows,) f32.  Returns (dx (rows, d) x.dtype, dscale (d,) f32)."""
    rows, d = x.shape
    br = min(block_rows, rows)
    while rows % br:
        br //= 2
    if interpret is None:
        interpret = default_interpret()
    n_blocks = rows // br
    dx, dscale_part = pl.pallas_call(
        functools.partial(_bwd_kernel, d_real=d),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, d), x.dtype),
            jax.ShapeDtypeStruct((n_blocks, d), jnp.float32),
        ],
        interpret=interpret,
    )(x, scale, rstd, dy)
    return dx, dscale_part.sum(0)
