"""Pallas TPU fused RMSNorm (forward).

Every block of every assigned architecture runs 2+ RMSNorms per layer; the
naive HLO chain (square -> mean -> rsqrt -> mul -> mul) makes multiple HBM
passes over the (B*S, d) activation.  This kernel reads x once and writes y
once, with the f32 reduction done in VMEM.  Rows are tiled (block_rows x d);
d is padded by ops.py to the 128-lane boundary if needed.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, scale_ref, o_ref, *, eps: float, d_real: int):
    x = x_ref[...].astype(jnp.float32)          # (br, d)
    # mean of squares over the REAL feature width (padding contributes 0)
    var = jnp.sum(x * x, axis=-1, keepdims=True) / d_real
    y = x * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_fwd(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """x (rows, d); scale (d,).  Returns normalized x, same dtype."""
    rows, d = x.shape
    br = min(block_rows, rows)
    while rows % br:
        br //= 2
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    kern = functools.partial(_kernel, eps=eps, d_real=d)
    return pl.pallas_call(
        kern,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, scale)
