"""Mesh-aware kernel dispatch: the single entry point to the Pallas kernels.

Every attention / norm / optimizer call in the model layer routes through
here with ``backend="auto"``.  Resolution is keyed off the *lowering
target* — the dispatch mesh installed via ``repro.distributed.ctx.use_mesh``
(its device platform), not ``jax.default_backend()`` — so a CPU host
lowering a TPU mesh program picks the kernels the mesh will actually run.

Decision table (see DESIGN.md §kernel-dispatch for the full rationale):

  mesh (devices>1)  platform  shape alignment          -> backend
  ----------------  --------  -----------------------  --------------------
  decode_cp rules   any       local slice aligned      pallas_cp (decode
                                                       only; interpret
                                                       off-TPU)
  decode_cp rules   any       slice/batch misaligned   jnp (reason logged)
  yes               any       aligned + axes divide    pallas_shard_map
                                                       (interpret off-TPU)
  yes               any       axes don't divide        jnp (reason logged)
  no / 1-device     tpu       aligned                  pallas
  no / 1-device     cpu/gpu   any                      jnp (reason logged)
  any               any       seq/rows misaligned      jnp (reason logged)
  rules, no mesh    any       any                      jnp (reason logged)

``flash_attention_append`` (op ``flash_append``) follows the same table
with its own alignment row: the chunk C and key stream Sk must both be
128-multiples (linear layouts have Sk == pos0 + C, so chunk-multiple
pos0 and a 128-multiple chunk size keep every chunk of a prompt on the
fused path).

The shard_map'd paths partition (batch -> data axes, heads -> model) using
the specs from ``repro.distributed.sharding.attention_shard_spec``; the
``custom_vjp`` is defined *around* the shard_mapped calls so gradients flow
under a mesh (a bare ``pallas_call`` has no GSPMD partitioning rule — this
layer is what lets mesh training keep its fused kernels).  ``pallas_cp``
is the serving counterpart: the ``decode_cp`` rules shard the KV cache's
*sequence* dim, each shard runs the partials-emitting decode kernel over
its slice, and the flash-decoding combine is a psum of (m, l, acc) over
the rule's seq axes.  ``rmsnorm`` shard_maps over row blocks (replicated
scale, psum'd dscale) except under the seq-parallel residual layout,
which stays an explicit fallback.

Dispatch resolves at trace time; ``ctx.use_mesh`` / ``ctx.sharding_rules``
fold a dispatch token into the jit cache key (``compat.set_trace_token``)
so one jitted callable re-lowered under a different mesh re-resolves
instead of replaying the stale cached trace.

All alignment checks (MXU 128-lane sequence blocks, GQA head-group
divisibility, mesh-axis divisibility) live here, in one place, and every
resolution is recorded with its reason — ``decision_log()`` /
``decision_summary()`` let tests and the dry-run report *why* a given call
fell back to jnp.
"""
from __future__ import annotations

import functools
import threading
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from repro.distributed import ctx
from repro.distributed.sharding import (AttnShardSpec, DecodeCPSpec,
                                        RowShardSpec, attention_shard_spec,
                                        decode_cp_shard_spec,
                                        rmsnorm_shard_spec)
from repro.kernels import ref
from repro.kernels.decode_attention import (_per_slot, decode_attention_fwd,
                                            decode_attention_partials)
from repro.kernels.flash_attention import (
    flash_attention_append as flash_attention_append_fwd,
    flash_attention_fwd)
from repro.kernels.flash_attention_bwd import flash_attention_bwd
from repro.kernels.rmsnorm import rmsnorm_bwd, rmsnorm_fwd
from repro.kernels.shared_rmsprop import rmsprop_update_2d

LANES = 1024
_BACKENDS = ("auto", "jnp", "pallas", "pallas_shard_map")


# ---------------------------------------------------------------------------
# decision log
# ---------------------------------------------------------------------------

class Decision(NamedTuple):
    op: str
    backend: str  # "pallas" | "pallas_shard_map" | "pallas_cp" | "jnp"
    reason: str
    platform: str
    mesh_axes: Optional[Tuple[Tuple[str, int], ...]]


_LOG_LOCK = threading.Lock()
_LOG_CAP = 512
_log: list = []


def _decide(op: str, backend: str, reason: str,
            mesh=None, platform: Optional[str] = None) -> Decision:
    d = Decision(op, backend, reason,
                 platform or ctx.current_platform(),
                 tuple(dict(mesh.shape).items()) if mesh is not None
                 else None)
    with _LOG_LOCK:
        if len(_log) >= _LOG_CAP:
            del _log[:_LOG_CAP // 2]
        _log.append(d)
    return d


def decision_log() -> list:
    """Decisions recorded since the last clear (trace-time, newest last)."""
    with _LOG_LOCK:
        return list(_log)


def clear_decision_log() -> None:
    with _LOG_LOCK:
        _log.clear()


def last_decision(op: str) -> Optional[Decision]:
    with _LOG_LOCK:
        for d in reversed(_log):
            if d.op == op:
                return d
    return None


def decision_summary() -> list:
    """Deduped (op, backend, reason) counts — the dry-run's 'why did this
    lower the way it did' record."""
    counts: dict = {}
    for d in decision_log():
        key = (d.op, d.backend, d.reason)
        counts[key] = counts.get(key, 0) + 1
    return [{"op": op, "backend": be, "reason": rs, "count": n}
            for (op, be, rs), n in sorted(counts.items())]


def _quant_note(decision: Decision, quant: bool) -> Decision:
    """Amend the just-logged decision row with the int8-cache marker.

    Quantization does not change routing — every arm (bare pallas,
    shard_map, pallas_cp, paged delegates, jnp fallback) handles the int8
    cache — so the resolvers stay dtype-blind and the row's *reason* gains
    a suffix saying how the arm consumes the quantized bytes."""
    if not quant:
        return decision
    suffix = ("; int8 kv dequantized for jnp fallback"
              if decision.backend == "jnp"
              else "; int8 kv dequant-in-kernel")
    amended = decision._replace(reason=decision.reason + suffix)
    with _LOG_LOCK:
        if _log and _log[-1] == decision:
            _log[-1] = amended
    return amended


def _mesh_for_dispatch():
    """(mesh, platform) of the lowering target; mesh None when dispatch
    should treat the run as single-device."""
    mesh = ctx.current_mesh()
    platform = ctx.current_platform()
    if mesh is not None and ctx.mesh_devices(mesh) <= 1:
        mesh = None
    return mesh, platform


# ---------------------------------------------------------------------------
# flash attention (train / prefill)
# ---------------------------------------------------------------------------

def _flash_blocks(s: int) -> int:
    # largest block <= 512 dividing s (s is a multiple of 128 on this
    # path, so this terminates at >= 128)
    b = min(512, s)
    while s % b:
        b //= 2
    return b


def _flash_fwd_call(q, k, v, causal, window, shard, interpret,
                    save_residuals):
    def call(q, k, v):
        bq = bk = _flash_blocks(q.shape[1])
        return flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   block_q=bq, block_k=bk,
                                   save_residuals=save_residuals,
                                   interpret=interpret)
    if shard is None:
        return call(q, k, v)
    out_specs = (shard.qo, shard.lse) if save_residuals else shard.qo
    return shard_map(call, mesh=shard.mesh,
                     in_specs=(shard.qo, shard.kv, shard.kv),
                     out_specs=out_specs, check_rep=False)(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_pallas(q, k, v, causal, window, shard, interpret):
    return _flash_fwd_call(q, k, v, causal, window, shard, interpret, False)


def _flash_pallas_fwd(q, k, v, causal, window, shard, interpret):
    o, lse = _flash_fwd_call(q, k, v, causal, window, shard, interpret, True)
    return o, (q, k, v, o, lse)


def _flash_pallas_bwd(causal, window, shard, interpret, res, do):
    q, k, v, o, lse = res

    def call(q, k, v, o, lse, do):
        bq = bk = _flash_blocks(q.shape[1])
        return flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                                   window=window, block_q=bq, block_k=bk,
                                   interpret=interpret)
    if shard is None:
        return call(q, k, v, o, lse, do)
    return shard_map(call, mesh=shard.mesh,
                     in_specs=(shard.qo, shard.kv, shard.kv, shard.qo,
                               shard.lse, shard.qo),
                     out_specs=(shard.qo, shard.kv, shard.kv),
                     check_rep=False)(q, k, v, o, lse, do)


_flash_pallas.defvjp(_flash_pallas_fwd, _flash_pallas_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "window", "shard",
                                             "interpret"))
def _flash_call(q, k, v, causal, window, shard, interpret):
    return _flash_pallas(q, k, v, causal, window, shard, interpret)


def _flash_dense(q, k, v, causal, window):
    """jnp fallback — same flavor selection the model layer used to do:
    blockwise (never materializes S x S) for long causal sequences, dense
    sdpa otherwise."""
    s = q.shape[1]
    from repro.models import attention as attn
    if causal and s >= 2048 and s % 512 == 0:
        from repro.models.flash_jnp import flash_attention_jnp
        return flash_attention_jnp(q, k, v, True, window, 512)
    n_rep = q.shape[2] // k.shape[2]
    kk = attn._repeat_kv(k, n_rep)
    vv = attn._repeat_kv(v, n_rep)
    mask = attn.causal_mask(s, s, window=window) if causal else None
    return attn.sdpa(q, kk, vv, mask)


def _resolve_flash(b: int, s: int, hq: int, hkv: int, backend: str
                   ) -> Tuple[Decision, Optional[AttnShardSpec], bool]:
    if hq % hkv != 0:
        # every implementation (kernels, blockwise, reference) groups q
        # heads over kv heads — a non-multiple count is a config error
        raise ValueError(f"GQA needs q heads to be a multiple of kv "
                         f"heads, got {hq}/{hkv}")
    mesh, platform = _mesh_for_dispatch()
    interpret = platform != "tpu"
    aligned = 128 <= s and s % 128 == 0
    if backend == "jnp":
        return _decide("flash_attention", "jnp", "explicit backend"), \
            None, interpret
    if backend == "pallas":
        if not aligned:
            return _decide("flash_attention", "jnp",
                           f"explicit pallas but seq {s} below kernel "
                           "minimum (128-multiple); naive reference"), \
                None, interpret
        return _decide("flash_attention", "pallas", "explicit backend"), \
            None, interpret
    if backend == "pallas_shard_map":
        if not aligned:
            raise ValueError(f"cannot shard_map attention: seq {s} not "
                             "MXU-aligned (need a multiple of 128)")
        raw_mesh = ctx.current_mesh()   # honor even a 1-device mesh
        if raw_mesh is None:
            raise ValueError("backend='pallas_shard_map' needs a mesh "
                             "installed via ctx.use_mesh")
        spec, why = attention_shard_spec(raw_mesh, batch=b, n_q_heads=hq,
                                         n_kv_heads=hkv)
        if spec is None:
            raise ValueError(f"cannot shard_map attention: {why}")
        return _decide("flash_attention", "pallas_shard_map",
                       "explicit backend", raw_mesh), spec, interpret
    # auto
    if not aligned:
        return _decide("flash_attention", "jnp",
                       f"seq {s} not MXU-aligned (need a multiple of "
                       "128)"), None, interpret
    if mesh is not None:
        spec, why = attention_shard_spec(mesh, batch=b, n_q_heads=hq,
                                         n_kv_heads=hkv)
        if spec is None:
            return _decide("flash_attention", "jnp", why, mesh), \
                None, interpret
        return _decide("flash_attention", "pallas_shard_map",
                       "mesh axes divide batch/heads", mesh), \
            spec, interpret
    if ctx.current_rules():
        return _decide("flash_attention", "jnp",
                       "sharding rules active without a dispatch mesh "
                       "(install it via ctx.use_mesh)"), None, interpret
    if platform == "tpu":
        return _decide("flash_attention", "pallas",
                       "single-device tpu, aligned"), None, False
    return _decide("flash_attention", "jnp",
                   f"platform {platform}: Pallas kernels run interpret-"
                   "only off-TPU"), None, interpret


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    backend: str = "auto") -> jnp.ndarray:
    """q (B,S,Hq,D); k,v (B,S,Hkv,D) -> (B,S,Hq,D).

    Differentiable end-to-end on every backend: the Pallas paths carry a
    custom VJP whose backward is the fused recompute kernel pair in
    ``flash_attention_bwd`` (shard_mapped under a mesh); jnp fallbacks
    differentiate through their reference implementations."""
    assert backend in _BACKENDS, backend
    b, s, hq, _ = q.shape
    decision, shard, interpret = _resolve_flash(b, s, hq, k.shape[2],
                                                backend)
    if decision.backend == "jnp":
        if backend == "pallas":     # sub-kernel smoke shape: keep the
            return ref.flash_attention_ref(q, k, v, causal=causal,
                                           window=window)  # naive oracle
        return _flash_dense(q, k, v, causal, window)
    return _flash_call(q, k, v, causal, window, shard, interpret)


# ---------------------------------------------------------------------------
# append-mode flash attention (chunked prefill: Sq != Sk, q-offset grid)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("pos0", "window",
                                             "kpos_linear", "shard",
                                             "interpret"))
def _append_call(q, k, v, kpos, ks, vs, pos0, window, kpos_linear, shard,
                 interpret):
    def call(q, k, v, kpos, ks=None, vs=None):
        bq = _flash_blocks(q.shape[1])
        bk = _flash_blocks(k.shape[1])
        return flash_attention_append_fwd(q, k, v, kpos, pos0=pos0,
                                          window=window, block_q=bq,
                                          block_k=bk,
                                          kpos_linear=kpos_linear,
                                          interpret=interpret,
                                          k_scale=ks, v_scale=vs)
    if shard is None:
        return call(q, k, v, kpos, ks, vs)
    base = (shard.qo, shard.kv, shard.kv, shard.kpos_decode)
    if ks is None:
        return shard_map(call, mesh=shard.mesh, in_specs=base,
                         out_specs=shard.qo, check_rep=False)(q, k, v, kpos)
    # the rank-4 scale tensors (B, Sk, Hkv, 1) shard exactly like the
    # caches they annotate
    return shard_map(call, mesh=shard.mesh,
                     in_specs=base + (shard.kv, shard.kv),
                     out_specs=shard.qo,
                     check_rep=False)(q, k, v, kpos, ks, vs)


def _append_dense(q, k, v, kpos, pos0, window):
    """jnp fallback — dense sdpa with the kpos mask (XLA CPU lowers the
    4-D repeat_kv einsum better than the grouped 5-D oracle einsum)."""
    from repro.models import attention as attn
    c = q.shape[1]
    n_rep = q.shape[2] // k.shape[2]
    kk = attn._repeat_kv(k, n_rep)
    vv = attn._repeat_kv(v, n_rep)
    qpos = pos0 + jnp.arange(c)
    mask = (kpos[:, None, :] >= 0) & \
        (kpos[:, None, :] <= qpos[None, :, None])          # (B, C, Sk)
    if window is not None:
        mask &= kpos[:, None, :] > qpos[None, :, None] - window
    return attn.sdpa(q, kk, vv, mask[:, None])


def _resolve_append(b: int, c: int, sk: int, hq: int, hkv: int,
                    pos0: int, backend: str
                    ) -> Tuple[Decision, Optional[AttnShardSpec], bool]:
    """Append arm alignment rules: the chunk (c) and the key stream (sk)
    both need MXU-aligned 128-multiples; on the linear cache layout
    sk == pos0 + c, so a 128-multiple chunk size and chunk-multiple pos0
    make every chunk of a prompt eligible (serve rounds --chunk)."""
    if hq % hkv != 0:
        raise ValueError(f"GQA needs q heads to be a multiple of kv "
                         f"heads, got {hq}/{hkv}")
    mesh, platform = _mesh_for_dispatch()
    interpret = platform != "tpu"
    aligned = (128 <= c and c % 128 == 0 and 128 <= sk and sk % 128 == 0)
    why_align = (f"chunk {c} / key stream {sk} (pos0={pos0}) not "
                 "MXU-aligned (need 128-multiples)")
    if backend == "jnp":
        return _decide("flash_append", "jnp", "explicit backend"), \
            None, interpret
    if backend == "pallas":
        if not aligned:
            return _decide("flash_append", "jnp",
                           f"explicit pallas but {why_align}; naive "
                           "reference"), None, interpret
        return _decide("flash_append", "pallas", "explicit backend"), \
            None, interpret
    if backend == "pallas_shard_map":
        if not aligned:
            raise ValueError(f"cannot shard_map append attention: "
                             f"{why_align}")
        raw_mesh = ctx.current_mesh()   # honor even a 1-device mesh
        if raw_mesh is None:
            raise ValueError("backend='pallas_shard_map' needs a mesh "
                             "installed via ctx.use_mesh")
        spec, why = attention_shard_spec(raw_mesh, batch=b, n_q_heads=hq,
                                         n_kv_heads=hkv)
        if spec is None:
            raise ValueError(f"cannot shard_map append attention: {why}")
        return _decide("flash_append", "pallas_shard_map",
                       "explicit backend", raw_mesh), spec, interpret
    # auto
    if not aligned:
        return _decide("flash_append", "jnp", why_align), None, interpret
    if mesh is not None:
        spec, why = attention_shard_spec(mesh, batch=b, n_q_heads=hq,
                                         n_kv_heads=hkv)
        if spec is None:
            return _decide("flash_append", "jnp", why, mesh), \
                None, interpret
        return _decide("flash_append", "pallas_shard_map",
                       "mesh axes divide batch/heads", mesh), \
            spec, interpret
    if ctx.current_rules():
        return _decide("flash_append", "jnp",
                       "sharding rules active without a dispatch mesh "
                       "(install it via ctx.use_mesh)"), None, interpret
    if platform == "tpu":
        return _decide("flash_append", "pallas",
                       "single-device tpu, aligned"), None, False
    return _decide("flash_append", "jnp",
                   f"platform {platform}: Pallas kernels run interpret-"
                   "only off-TPU"), None, interpret


def flash_attention_append(q, k, v, kpos, *, pos0: int,
                           window: Optional[int] = None,
                           kpos_linear: bool = False,
                           k_scale=None, v_scale=None,
                           backend: str = "auto") -> jnp.ndarray:
    """Append-mode flash attention for chunked prefill.

    q (B,C,Hq,D) — a prompt chunk at absolute positions ``pos0 + i``;
    k,v (B,Sk,Hkv,D) — the key stream (cache prefix + chunk); kpos
    (B,Sk) [or (Sk,), broadcast] — absolute position per key row (-1 =
    invalid, the decode kernel's validity convention) -> (B,C,Hq,D).

    ``kpos_linear`` asserts key row index == absolute position wherever
    valid (full linear caches) and enables the ``tile_live`` prefix-tile
    skip; ring (rotated) layouts must leave it False.  With
    ``k_scale``/``v_scale`` ((B,Sk,Hkv,1) f32) the key stream is int8 and
    dequantized inside the kernel (jnp fallbacks dequantize up front) —
    same routing rules, annotated decision rows.  Serving-only: forward,
    no VJP.  Under a mesh the kernel shard_maps over (batch, heads) with
    the same ``AttnShardSpec`` the train/decode kernels use (kpos
    batch-sharded with q, scales sharded like the caches)."""
    assert backend in _BACKENDS, backend
    quant = k_scale is not None
    b, c, hq, _ = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    if kpos.ndim == 1:
        kpos = jnp.broadcast_to(kpos, (b, sk))
    decision, shard, interpret = _resolve_append(b, c, sk, hq, hkv, pos0,
                                                 backend)
    decision = _quant_note(decision, quant)
    if decision.backend == "jnp":
        if backend == "pallas":     # sub-kernel smoke shape: keep the
            if quant:               # naive oracle
                return ref.flash_attention_append_quant_ref(
                    q, k, v, k_scale, v_scale, kpos, pos0=pos0,
                    window=window)
            return ref.flash_attention_append_ref(q, k, v, kpos,
                                                  pos0=pos0,
                                                  window=window)
        if quant:
            k = ref.dequant_ref(k, k_scale, q.dtype)
            v = ref.dequant_ref(v, v_scale, q.dtype)
        return _append_dense(q, k, v, kpos, pos0, window)
    return _append_call(q, k, v, kpos, k_scale, v_scale, pos0, window,
                        kpos_linear, shard, interpret)


# ---------------------------------------------------------------------------
# decode attention (serving)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("shard", "interpret"))
def _decode_call(q, k_cache, v_cache, kpos, pos, ks, vs, shard, interpret):
    def call(q, kc, vc, kpos, pos, ks=None, vs=None):
        length = kc.shape[1]
        bk = min(1024, length)
        while length % bk:
            bk //= 2
        return decode_attention_fwd(q, kc, vc, kpos, pos, block_k=bk,
                                    interpret=interpret,
                                    k_scale=ks, v_scale=vs)
    if shard is None:
        return call(q, k_cache, v_cache, kpos, pos, ks, vs)
    base = (shard.q_decode, shard.kv, shard.kv, shard.kpos_decode,
            shard.pos_decode)
    if ks is None:
        return shard_map(call, mesh=shard.mesh, in_specs=base,
                         out_specs=shard.q_decode,
                         check_rep=False)(q, k_cache, v_cache, kpos, pos)
    # rank-4 scales (B, L, Hkv, 1) shard exactly like the caches
    return shard_map(call, mesh=shard.mesh,
                     in_specs=base + (shard.kv, shard.kv),
                     out_specs=shard.q_decode,
                     check_rep=False)(q, k_cache, v_cache, kpos, pos,
                                      ks, vs)


@functools.partial(jax.jit, static_argnames=("shard", "interpret"))
def _decode_cp_call(q, k_cache, v_cache, kpos, pos, ks, vs, shard,
                    interpret):
    """Context-parallel flash decoding: the cache's sequence dim is sharded
    over ``shard.seq_axes``; each shard runs the partials kernel over its
    slice and the combine is an O(B*Hq*D) psum of (m, l, acc) — the same
    correction math the pure-jnp ``attend_decode_cp`` combine used, now fed
    by the Pallas kernel."""
    axes = shard.seq_axes

    def call(q, kc, vc, kp, p, ks=None, vs=None):
        l_loc = kc.shape[1]
        bk = min(1024, l_loc)
        while l_loc % bk:
            bk //= 2
        acc, m, l = decode_attention_partials(q, kc, vc, kp, p, block_k=bk,
                                              interpret=interpret,
                                              k_scale=ks, v_scale=vs)
        m_max = jax.lax.pmax(m, axes)
        corr = jnp.exp(m - m_max)
        l_tot = jax.lax.psum(l * corr, axes)
        acc_tot = jax.lax.psum(acc * corr[..., None], axes)
        o = acc_tot / jnp.maximum(l_tot, 1e-30)[..., None]
        b, hkv, g, d = acc.shape
        return o.reshape(b, hkv * g, d).astype(q.dtype)

    base = (shard.q_decode, shard.kv, shard.kv, shard.kpos,
            shard.pos_decode)
    if ks is None:
        return shard_map(call, mesh=shard.mesh, in_specs=base,
                         out_specs=shard.q_decode,
                         check_rep=False)(q, k_cache, v_cache, kpos, pos)
    # the seq-sharded cache slice carries its seq-sharded scale slice
    return shard_map(call, mesh=shard.mesh,
                     in_specs=base + (shard.kv, shard.kv),
                     out_specs=shard.q_decode,
                     check_rep=False)(q, k_cache, v_cache, kpos, pos,
                                      ks, vs)


def _decode_dense(q, k_cache, v_cache, kpos, pos):
    from repro.models import attention as attn
    n_rep = q.shape[1] // k_cache.shape[2]
    kk = attn._repeat_kv(k_cache.astype(q.dtype), n_rep)
    vv = attn._repeat_kv(v_cache.astype(q.dtype), n_rep)
    valid = (kpos >= 0) & (kpos <= pos[:, None])      # (B, L) per slot
    mask = valid[:, None, None, :]
    return attn.sdpa(q[:, None], kk, vv, mask)[:, 0]


def _resolve_decode(b: int, length: int, hq: int, hkv: int, backend: str
                    ) -> Tuple[Decision, Any, bool]:
    """Returns (decision, spec, interpret); spec is an ``AttnShardSpec``
    for the (batch, heads) shard_map arm, a ``DecodeCPSpec`` for the
    context-parallel arm, or None."""
    if hq % hkv != 0:
        raise ValueError(f"GQA needs q heads to be a multiple of kv "
                         f"heads, got {hq}/{hkv}")
    mesh, platform = _mesh_for_dispatch()
    interpret = platform != "tpu"
    aligned = 128 <= length and length % 128 == 0
    rules = ctx.current_rules() or {}
    if backend == "jnp":
        return _decide("decode_attention", "jnp", "explicit backend"), \
            None, interpret
    if backend == "pallas":
        if not aligned:
            return _decide("decode_attention", "jnp",
                           f"explicit pallas but cache length {length} "
                           "below kernel minimum (128-multiple); naive "
                           "reference"), None, interpret
        return _decide("decode_attention", "pallas", "explicit backend"), \
            None, interpret
    if backend == "pallas_shard_map":
        raw_mesh = ctx.current_mesh()   # honor even a 1-device mesh
        if raw_mesh is None:
            raise ValueError("backend='pallas_shard_map' needs a mesh "
                             "installed via ctx.use_mesh")
        # misalignment is a logged fallback (like every auto arm), not a
        # crash: serving batch/head counts vary per request
        if not aligned:
            return _decide("decode_attention", "jnp",
                           f"explicit shard_map but cache length {length} "
                           "not MXU-aligned (need a multiple of 128); "
                           "reference", raw_mesh), None, interpret
        spec, why = attention_shard_spec(raw_mesh, batch=b, n_q_heads=hq,
                                         n_kv_heads=hkv)
        if spec is None:
            return _decide("decode_attention", "jnp",
                           f"explicit shard_map but {why}; reference",
                           raw_mesh), None, interpret
        return _decide("decode_attention", "pallas_shard_map",
                       "explicit backend", raw_mesh), spec, interpret
    if not aligned:
        return _decide("decode_attention", "jnp",
                       f"cache length {length} not MXU-aligned (need a "
                       "multiple of 128)"), None, interpret
    cp = rules.get("decode_cp")
    if cp is not None:
        cp_mesh = cp["mesh"]
        cp_interpret = ctx.mesh_platform(cp_mesh) != "tpu"
        spec, why = decode_cp_shard_spec(cp, batch=b, length=length)
        if spec is None:
            return _decide("decode_attention", "jnp",
                           f"decode_cp rules own the cache but {why}",
                           cp_mesh, ctx.mesh_platform(cp_mesh)), \
                None, cp_interpret
        return _decide("decode_attention", "pallas_cp",
                       "decode_cp layout: partials kernel per seq shard "
                       "+ (m,l,acc) psum combine",
                       cp_mesh, ctx.mesh_platform(cp_mesh)), \
            spec, cp_interpret
    if mesh is not None:
        spec, why = attention_shard_spec(mesh, batch=b, n_q_heads=hq,
                                         n_kv_heads=hkv)
        if spec is None:
            return _decide("decode_attention", "jnp", why, mesh), \
                None, interpret
        return _decide("decode_attention", "pallas_shard_map",
                       "mesh axes divide batch/heads", mesh), \
            spec, interpret
    if rules:
        return _decide("decode_attention", "jnp",
                       "sharding rules active without a dispatch mesh"), \
            None, interpret
    if platform == "tpu":
        return _decide("decode_attention", "pallas",
                       "single-device tpu, aligned"), None, False
    return _decide("decode_attention", "jnp",
                   f"platform {platform}: Pallas kernels run interpret-"
                   "only off-TPU"), None, interpret


def decode_attention(q, k_cache, v_cache, kpos, pos=None, *,
                     k_scale=None, v_scale=None,
                     backend: str = "auto") -> jnp.ndarray:
    """q (B,Hq,D); caches (B,L,Hkv,D); kpos (B,L); pos (B,) -> (B,Hq,D).

    Positions are per batch slot (continuous batching: every sequence can
    be at its own decode depth).  Lockstep callers may pass kpos (L,) and
    scalar pos — both are broadcast to the per-slot layout here, so the
    scalar-``pos`` path is a thin wrapper over the same kernels.

    One fast path serves both cache layouts: under the replicated-cache
    layout the kernel is shard_mapped over (batch, heads); when the
    ``decode_cp`` rules own the cache's sequence dim it resolves to
    ``pallas_cp`` — the partials kernel per sequence shard plus the
    flash-decoding psum combine.

    With ``k_scale``/``v_scale`` ((B,L,Hkv,1) f32) the caches are int8;
    every arm consumes them (dequant inside the kernel bodies, up-front
    dequant on the jnp fallback) under the same routing rules, with the
    decision row annotated."""
    assert backend in _BACKENDS, backend
    quant = k_scale is not None
    b, hq, _ = q.shape
    length, hkv = k_cache.shape[1], k_cache.shape[2]
    if pos is None:
        pos = jnp.max(kpos, axis=-1) if kpos.ndim == 2 else jnp.max(kpos)
    # normalization helper shared with the kernel entry points
    kpos, pos = _per_slot(kpos, pos, b)
    decision, shard, interpret = _resolve_decode(b, length, hq, hkv,
                                                 backend)
    decision = _quant_note(decision, quant)
    if decision.backend == "jnp":
        if backend == "pallas":     # sub-kernel smoke shape: keep the
            if quant:               # naive oracle
                return ref.decode_attention_quant_ref(
                    q, k_cache, v_cache, k_scale, v_scale, kpos, pos)
            return ref.decode_attention_ref(q, k_cache, v_cache, kpos,
                                            pos)
        if quant:
            k_cache = ref.dequant_ref(k_cache, k_scale, q.dtype)
            v_cache = ref.dequant_ref(v_cache, v_scale, q.dtype)
        return _decode_dense(q, k_cache, v_cache, kpos, pos)
    if decision.backend == "pallas_cp":
        return _decode_cp_call(q, k_cache, v_cache, kpos, pos, k_scale,
                               v_scale, shard, interpret)
    return _decode_call(q, k_cache, v_cache, kpos, pos, k_scale, v_scale,
                        shard, interpret)


# ---------------------------------------------------------------------------
# paged KV cache layout (page pool + per-slot page table)
# ---------------------------------------------------------------------------
#
# The paged arms are an indirection layer, not a new kernel family: the
# decode and append kernels already read key validity from a runtime
# per-row ``kpos`` map, so a paged cache lowers as (1) a page-table gather
# producing a dense per-slot view, (2) the paged kpos map (-1 on unmapped
# pages), then (3) a delegated call into the existing ``decode_attention``
# / ``flash_attention_append`` arms.  The gathered view is *statically*
# sliced to the logical cache length so the delegated call sees the exact
# shapes the contiguous layout produces — paged and contiguous compute
# streams are bitwise identical, which is what the engine parity tests
# pin.  Alignment rule: ``page_size`` must be a 128-multiple so page
# boundaries coincide with the kernels' key-block tiles; smaller or odd
# page sizes fall back to the jnp oracle with a logged reason.  Every
# paged call logs two decision rows — its own (op ``decode_paged`` /
# ``append_paged``) plus the delegated op's row.

def _paged_misalignment(page_size: int) -> Optional[str]:
    if page_size < 128 or page_size % 128 != 0:
        return (f"page size {page_size} not MXU-aligned (need a "
                "128-multiple so page boundaries coincide with key-block "
                "tiles)")
    return None


def decode_attention_paged(q, k_pool, v_pool, page_table, pos, *,
                           length: Optional[int] = None,
                           k_scale=None, v_scale=None,
                           backend: str = "auto") -> jnp.ndarray:
    """Paged-layout decode.  q (B,Hq,D); pools (P,page_size,Hkv,D);
    page_table (B,M) int32 (-1 = unmapped, 0 = reserved garbage sink);
    pos (B,) or scalar -> (B,Hq,D).

    ``length`` statically truncates the gathered view to the logical
    cache length (M * page_size may over-cover); passing the contiguous
    layout's cache_len makes the delegated call's shapes — and therefore
    its dispatch decision and reduction order — identical to the
    contiguous path.  With ``k_scale``/``v_scale`` ((P,page_size,Hkv,1)
    f32) the pools are int8; the scale pools are gathered through the
    same page table and ride into the delegated call — the contiguous
    quant arms do the rest."""
    assert backend in _BACKENDS, backend
    quant = k_scale is not None
    ps = k_pool.shape[1]
    m = page_table.shape[1]
    length = m * ps if length is None else length
    why = _paged_misalignment(ps)
    if why is None and (length < 128 or length % 128 != 0):
        why = (f"logical length {length} not MXU-aligned (need a "
               "128-multiple)")
    if why is not None:
        if quant:
            _decide("decode_paged", "jnp",
                    why + "; int8 kv dequantized for jnp fallback")
            return ref.decode_attention_paged_quant_ref(
                q, k_pool, v_pool, k_scale, v_scale, page_table, pos,
                length=length)
        _decide("decode_paged", "jnp", why)
        return ref.decode_attention_paged_ref(q, k_pool, v_pool,
                                              page_table, pos,
                                              length=length)
    k = ref.paged_gather_ref(k_pool, page_table)[:, :length]
    v = ref.paged_gather_ref(v_pool, page_table)[:, :length]
    kpos = ref.paged_kpos_ref(page_table, ps)[:, :length]
    ks = vs = None
    if quant:
        ks = ref.paged_gather_ref(k_scale, page_table)[:, :length]
        vs = ref.paged_gather_ref(v_scale, page_table)[:, :length]
    o = decode_attention(q, k, v, kpos, pos, k_scale=ks, v_scale=vs,
                         backend=backend)
    inner = last_decision("decode_attention")
    _decide("decode_paged", inner.backend if inner else "jnp",
            "page-gathered dense view, delegated to decode_attention" +
            ("; int8 pool + scale pool gathered together" if quant else ""))
    return o


def flash_attention_append_paged(q, k_pool, v_pool, page_table,
                                 k_chunk, v_chunk, *, pos0: int,
                                 k_scale=None, v_scale=None,
                                 ks_chunk=None, vs_chunk=None,
                                 backend: str = "auto") -> jnp.ndarray:
    """Paged-layout append-mode prefill.  q (B,C,Hq,D) at absolute
    positions pos0 + i; pools hold the already-written prefix [0, pos0)
    behind page_table (B,M); k_chunk/v_chunk (B,C,Hkv,D) are the chunk's
    own K/V (not yet in the pool, or written by the caller — the key
    stream uses these tensors, not pool rows).

    Linear layouts only (no window: ring caches stay contiguous).  The
    gathered prefix keeps key row index == absolute position wherever
    mapped, so the delegated call runs with ``kpos_linear=True`` and
    keeps the tile_live prefix-tile skip.

    Quantized pools pass scale pools via ``k_scale``/``v_scale`` and the
    chunk *already quantized* (int8 chunk + ``ks_chunk``/``vs_chunk``
    (B,C,Hkv,1)) — the same bytes the caller's cache write lands, so
    prefill attention and later decode reads see identical dequantized
    values."""
    assert backend in _BACKENDS, backend
    quant = k_scale is not None
    ps = k_pool.shape[1]
    b, c = q.shape[0], q.shape[1]
    why = _paged_misalignment(ps)
    if why is not None:
        if quant:
            _decide("append_paged", "jnp",
                    why + "; int8 kv dequantized for jnp fallback")
            return ref.flash_attention_append_paged_quant_ref(
                q, k_pool, v_pool, k_scale, v_scale, page_table,
                k_chunk, v_chunk, ks_chunk, vs_chunk, pos0=pos0)
        _decide("append_paged", "jnp", why)
        return ref.flash_attention_append_paged_ref(
            q, k_pool, v_pool, page_table, k_chunk, v_chunk, pos0=pos0)
    ks_all = vs_all = None
    if pos0 == 0:
        k_all, v_all = k_chunk, v_chunk
        ks_all, vs_all = ks_chunk, vs_chunk
        kpos = jnp.arange(c)
    else:
        n_pre = -(-pos0 // ps)
        pt = page_table[:, :n_pre]
        k_pre = ref.paged_gather_ref(k_pool, pt)[:, :pos0]
        v_pre = ref.paged_gather_ref(v_pool, pt)[:, :pos0]
        if not quant:
            k_pre = k_pre.astype(q.dtype)
            v_pre = v_pre.astype(q.dtype)
        kpos_pre = ref.paged_kpos_ref(pt, ps)[:, :pos0]
        k_all = jnp.concatenate([k_pre, k_chunk], axis=1)
        v_all = jnp.concatenate([v_pre, v_chunk], axis=1)
        kpos_chunk = jnp.broadcast_to(pos0 + jnp.arange(c), (b, c))
        kpos = jnp.concatenate([kpos_pre, kpos_chunk], axis=1)
        if quant:
            ks_pre = ref.paged_gather_ref(k_scale, pt)[:, :pos0]
            vs_pre = ref.paged_gather_ref(v_scale, pt)[:, :pos0]
            ks_all = jnp.concatenate([ks_pre, ks_chunk], axis=1)
            vs_all = jnp.concatenate([vs_pre, vs_chunk], axis=1)
    o = flash_attention_append(q, k_all, v_all, kpos, pos0=pos0,
                               kpos_linear=True, k_scale=ks_all,
                               v_scale=vs_all, backend=backend)
    inner = last_decision("flash_append")
    _decide("append_paged", inner.backend if inner else "jnp",
            "page-gathered prefix + chunk, delegated to flash_append" +
            ("; int8 pool + scale pool gathered together" if quant else ""))
    return o


# ---------------------------------------------------------------------------
# speculative verify (ragged per-row depths as one append chunk)
# ---------------------------------------------------------------------------
#
# Verification of k drafted tokens is exactly a k-token append chunk —
# except each batch row sits at its own decode depth ``pos[j]``, while
# ``flash_attention_append`` wants one static ``pos0``.  Both masks the
# append kernel applies are relative: causal is ``kpos <= qpos`` and the
# sliding window is ``kpos > qpos - window``, so adding a common constant
# to every key position *and* every query position of one row changes
# nothing.  Re-basing row j by ``shift - pos[j]`` (``shift`` a static
# upper bound on pos — callers pass the logical cache length) therefore
# turns the ragged verify batch into a single append call at
# ``pos0 = shift``, with no new kernel and no per-row loop.  RoPE stays
# the model layer's job at the *true* absolute positions.

def flash_attention_verify(q, k, v, kpos, *, pos, shift: int,
                           window: Optional[int] = None,
                           k_scale=None, v_scale=None,
                           backend: str = "auto") -> jnp.ndarray:
    """Speculative-verify attention: score K drafted tokens per slot in
    one fused append launch.

    q (B,K,Hq,D) — row j's draft chunk at absolute positions
    ``pos[j] + i`` (decode's per-slot depths, not prefill's static
    pos0); k,v (B,Sk,Hkv,D) — key stream (cache prefix + the chunk's
    own K/V); kpos (B,Sk) absolute position per key row (-1 invalid);
    pos (B,) int32; ``shift`` static, >= every pos -> (B,K,Hq,D).

    Rows shift by different amounts, so key row index no longer equals
    shifted position: the delegated call always runs with
    ``kpos_linear=False`` (ring layouts required that anyway).  With
    ``k_scale``/``v_scale`` the key stream is int8 — scales ride into
    the delegated quant arm unchanged (the shift touches positions
    only, never payloads)."""
    assert backend in _BACKENDS, backend
    quant = k_scale is not None
    b = q.shape[0]
    sk = k.shape[1]
    if kpos.ndim == 1:
        kpos = jnp.broadcast_to(kpos, (b, sk))
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    kpos_s = jnp.where(kpos >= 0, kpos - pos[:, None] + shift, -1)
    o = flash_attention_append(q, k, v, kpos_s, pos0=shift, window=window,
                               kpos_linear=False, k_scale=k_scale,
                               v_scale=v_scale, backend=backend)
    inner = last_decision("flash_append")
    _decide("flash_verify", inner.backend if inner else "jnp",
            f"per-row depths re-based to static pos0 (shift={shift}), "
            "delegated to flash_append" +
            ("; int8 key stream + scales ride through" if quant else ""))
    return o


def flash_attention_verify_paged(q, k_pool, v_pool, page_table,
                                 k_chunk, v_chunk, *, pos, length: int,
                                 k_scale=None, v_scale=None,
                                 ks_chunk=None, vs_chunk=None,
                                 backend: str = "auto") -> jnp.ndarray:
    """Paged-layout speculative verify.  q (B,K,Hq,D) at absolute
    positions ``pos[j] + i``; pools hold the committed prefix behind
    page_table (B,M); k_chunk/v_chunk (B,K,Hkv,D) are the draft chunk's
    own K/V (NOT in the pool — commit happens after acceptance, so the
    pool never needs rolling back); ``length`` statically truncates the
    gathered view to the logical cache length.

    Speculatively pre-allocated pages may already be mapped for
    positions >= pos[j] but hold garbage rows, so the gathered prefix
    kpos is clamped to ``<= pos - 1`` per row — uncommitted pool rows
    are invisible no matter what the allocator did ahead of the verify.
    Quantized pools gather their scale pools through the same table and
    take the chunk already quantized (``ks_chunk``/``vs_chunk``), the
    same int8 bytes a later commit writes — verify logits and
    post-commit decode reads see identical dequantized values."""
    assert backend in _BACKENDS, backend
    quant = k_scale is not None
    ps = k_pool.shape[1]
    b, kq = q.shape[0], q.shape[1]
    n_pre = -(-length // ps)
    pt = page_table[:, :n_pre]
    k_pre = ref.paged_gather_ref(k_pool, pt)[:, :length]
    v_pre = ref.paged_gather_ref(v_pool, pt)[:, :length]
    if not quant:
        k_pre = k_pre.astype(q.dtype)
        v_pre = v_pre.astype(q.dtype)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    kpos_pre = ref.paged_kpos_ref(pt, ps)[:, :length]
    kpos_pre = jnp.where(kpos_pre <= pos[:, None] - 1, kpos_pre, -1)
    kpos_chunk = pos[:, None] + jnp.arange(kq)
    kpos = jnp.concatenate([kpos_pre, kpos_chunk], axis=1)
    k_all = jnp.concatenate([k_pre, k_chunk], axis=1)
    v_all = jnp.concatenate([v_pre, v_chunk], axis=1)
    ks_all = vs_all = None
    if quant:
        ks_pre = ref.paged_gather_ref(k_scale, pt)[:, :length]
        vs_pre = ref.paged_gather_ref(v_scale, pt)[:, :length]
        ks_all = jnp.concatenate([ks_pre, ks_chunk], axis=1)
        vs_all = jnp.concatenate([vs_pre, vs_chunk], axis=1)
    o = flash_attention_verify(q, k_all, v_all, kpos, pos=pos,
                               shift=length, k_scale=ks_all,
                               v_scale=vs_all, backend=backend)
    inner = last_decision("flash_verify")
    _decide("verify_paged", inner.backend if inner else "jnp",
            "page-gathered prefix (kpos clamped below each row's pos) "
            "+ draft chunk, delegated to flash_verify" +
            ("; int8 pool + scale pool gathered together" if quant else ""))
    return o


# ---------------------------------------------------------------------------
# fused rmsnorm (fwd + one-pass vjp)
# ---------------------------------------------------------------------------

def _rmsnorm_fwd_call(x2, scale, eps, shard, interpret, save_residuals):
    def call(x2, scale):
        return rmsnorm_fwd(x2, scale, eps=eps,
                           save_residuals=save_residuals,
                           interpret=interpret)
    if shard is None:
        return call(x2, scale)
    from jax.sharding import PartitionSpec as P
    out_specs = (shard.rows, shard.rstd) if save_residuals else shard.rows
    return shard_map(call, mesh=shard.mesh,
                     in_specs=(shard.rows, P(None)),
                     out_specs=out_specs, check_rep=False)(x2, scale)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _rmsnorm_pallas(x2, scale, eps, shard, interpret):
    return _rmsnorm_fwd_call(x2, scale, eps, shard, interpret, False)


def _rmsnorm_pallas_fwd(x2, scale, eps, shard, interpret):
    y, rstd = _rmsnorm_fwd_call(x2, scale, eps, shard, interpret, True)
    return y, (x2, scale, rstd)


def _rmsnorm_pallas_bwd(eps, shard, interpret, res, dy):
    x2, scale, rstd = res

    def call(x2, scale, rstd, dy):
        dx, dscale = rmsnorm_bwd(x2, scale, rstd, dy, interpret=interpret)
        if shard is not None:
            # scale is replicated: sum the per-shard dscale partials
            dscale = jax.lax.psum(dscale, shard.axes)
        return dx, dscale
    if shard is None:
        dx, dscale = call(x2, scale, rstd, dy)
    else:
        from jax.sharding import PartitionSpec as P
        dx, dscale = shard_map(call, mesh=shard.mesh,
                               in_specs=(shard.rows, P(None), shard.rstd,
                                         shard.rows),
                               out_specs=(shard.rows, P(None)),
                               check_rep=False)(x2, scale, rstd, dy)
    return dx, dscale.astype(scale.dtype)


_rmsnorm_pallas.defvjp(_rmsnorm_pallas_fwd, _rmsnorm_pallas_bwd)


@functools.partial(jax.jit, static_argnames=("eps", "shard", "interpret"))
def _rmsnorm_call(x2, scale, eps, shard, interpret):
    return _rmsnorm_pallas(x2, scale, eps, shard, interpret)


def _resolve_rmsnorm(rows: int, d: int, backend: str
                     ) -> Tuple[Decision, Optional[RowShardSpec], bool]:
    mesh, platform = _mesh_for_dispatch()
    interpret = platform != "tpu"
    aligned = rows >= 8 and d % 128 == 0
    if backend == "jnp":
        return _decide("rmsnorm", "jnp", "explicit backend"), None, \
            interpret
    if backend in ("pallas", "pallas_shard_map"):
        if not aligned:
            return _decide("rmsnorm", "jnp",
                           f"explicit pallas but rows={rows}/d={d} below "
                           "tile minimum (8 rows, 128-lane d); "
                           "reference"), None, interpret
        if backend == "pallas_shard_map":
            raw_mesh = ctx.current_mesh()   # honor even a 1-device mesh
            if raw_mesh is None:
                raise ValueError("backend='pallas_shard_map' needs a mesh "
                                 "installed via ctx.use_mesh")
            spec, why = rmsnorm_shard_spec(raw_mesh, rows=rows,
                                           rules=ctx.current_rules())
            if spec is None:
                return _decide("rmsnorm", "jnp",
                               f"explicit shard_map but {why}; reference",
                               raw_mesh), None, interpret
            return _decide("rmsnorm", "pallas_shard_map",
                           "explicit backend", raw_mesh), spec, interpret
        return _decide("rmsnorm", "pallas", "explicit backend"), None, \
            interpret
    if not aligned:
        return _decide("rmsnorm", "jnp",
                       f"rows={rows}/d={d} below tile minimum (8 rows, "
                       "128-lane d)"), None, interpret
    if mesh is not None:
        spec, why = rmsnorm_shard_spec(mesh, rows=rows,
                                       rules=ctx.current_rules())
        if spec is None:
            return _decide("rmsnorm", "jnp", why, mesh), None, interpret
        return _decide("rmsnorm", "pallas_shard_map",
                       "row blocks divide the mesh axes; scale "
                       "replicated, dscale psum'd in the vjp", mesh), \
            spec, interpret
    if ctx.current_rules():
        return _decide("rmsnorm", "jnp",
                       "sharding rules active without a dispatch mesh "
                       "(install it via ctx.use_mesh)"), None, interpret
    if platform == "tpu":
        return _decide("rmsnorm", "pallas", "single-device tpu, aligned"), \
            None, False
    return _decide("rmsnorm", "jnp",
                   f"platform {platform}: Pallas kernels run interpret-"
                   "only off-TPU"), None, interpret


def rmsnorm(x, scale, *, eps: float = 1e-6,
            backend: str = "auto") -> jnp.ndarray:
    """Fused RMSNorm over the last dim of an arbitrary-rank activation.

    Differentiable on every backend: the Pallas paths carry the one-pass
    dx/dscale vjp from ``rmsnorm_bwd`` (shard_mapped over row blocks under
    a mesh, with the dscale partials psum'd); the jnp path is plain AD
    through the reference."""
    assert backend in _BACKENDS, backend
    shape = x.shape
    d = shape[-1]
    rows = x.size // d
    decision, shard, interpret = _resolve_rmsnorm(rows, d, backend)
    if decision.backend == "jnp":
        return ref.rmsnorm_ref(x, scale, eps=eps)
    y = _rmsnorm_call(x.reshape(rows, d), scale, eps, shard, interpret)
    return y.reshape(shape)


# ---------------------------------------------------------------------------
# fused shared-RMSProp
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("lr", "alpha", "eps"))
def rmsprop_update(g, grad, *, lr, alpha: float = 0.99,
                   eps: float = 0.1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused Shared-RMSProp for an arbitrary-shaped parameter leaf.
    Returns (new_g, update)."""
    shape = g.shape
    n = g.size
    if n < LANES:
        return ref.rmsprop_update_ref(g, grad, lr=lr, alpha=alpha, eps=eps)
    rows = -(-n // LANES)
    pad = rows * LANES - n
    gf = jnp.pad(g.reshape(-1), (0, pad)).reshape(rows, LANES)
    df = jnp.pad(grad.reshape(-1), (0, pad)).reshape(rows, LANES)
    br = 256
    while rows % br:
        br //= 2
    new_g, upd = rmsprop_update_2d(gf, df, jnp.asarray(lr, g.dtype),
                                   alpha=alpha, eps=eps, block_rows=br)
    unpad = lambda x: x.reshape(-1)[:n].reshape(shape)
    return unpad(new_g), unpad(upd)


# ---------------------------------------------------------------------------
# op registry — the dispatch contract, machine-checked by tools/audit
# ---------------------------------------------------------------------------

class OpContract(NamedTuple):
    """One dispatch op's invariants, in checkable form.

    ``tools/audit``'s contract passes cross-check every row: the entry is
    callable, the named jnp oracle (and quant oracle, when the op carries
    an int8 arm) exists in ``ref``, the resolver's every return path emits
    a decision row, delegating ops name a registered delegate, and quant
    ops annotate their rows via ``_quant_note`` / inline int8 reasons.
    New ops MUST be registered here — the auditor also checks the reverse
    direction (any public entry with a ``backend`` parameter that is
    missing from the registry fails the audit)."""
    entry: Any                    # public dispatch callable
    oracle: str                   # jnp oracle name in kernels/ref.py
    quant_oracle: Optional[str]   # int8 oracle name; None = no quant arm
    resolver: Optional[str]       # _resolve_* fn emitting decision rows,
    #                               None for delegating/registry-free ops
    delegate: Optional[str]       # op key this arm delegates to (paged
    #                               indirection), else None


KERNEL_OPS = {
    "flash_attention": OpContract(flash_attention, "flash_attention_ref",
                                  None, "_resolve_flash", None),
    "flash_append": OpContract(flash_attention_append,
                               "flash_attention_append_ref",
                               "flash_attention_append_quant_ref",
                               "_resolve_append", None),
    "decode_attention": OpContract(decode_attention, "decode_attention_ref",
                                   "decode_attention_quant_ref",
                                   "_resolve_decode", None),
    "decode_paged": OpContract(decode_attention_paged,
                               "decode_attention_paged_ref",
                               "decode_attention_paged_quant_ref",
                               None, "decode_attention"),
    "append_paged": OpContract(flash_attention_append_paged,
                               "flash_attention_append_paged_ref",
                               "flash_attention_append_paged_quant_ref",
                               None, "flash_append"),
    "flash_verify": OpContract(flash_attention_verify,
                               "flash_attention_append_ref",
                               "flash_attention_append_quant_ref",
                               None, "flash_append"),
    "verify_paged": OpContract(flash_attention_verify_paged,
                               "flash_attention_append_paged_ref",
                               "flash_attention_append_paged_quant_ref",
                               None, "flash_verify"),
    "rmsnorm": OpContract(rmsnorm, "rmsnorm_ref", None, "_resolve_rmsnorm",
                          None),
    "rmsprop_update": OpContract(rmsprop_update, "rmsprop_update_ref",
                                 None, None, None),
}
