"""Shared interpret-mode default for the Pallas kernel entry points.

Every kernel resolves ``interpret=None`` from the *lowering target* —
``ctx.current_platform()``, the dispatch mesh's device platform — never
from ``jax.default_backend()`` (PR 2 policy): a CPU host lowering a TPU
mesh program must compile the real kernels, and a GPU host must stay in
interpret mode (these are TPU kernels).  ``tools/audit``'s
``no-default-backend`` pass enforces that no kernel/serve module grows a
``jax.default_backend()`` call back.
"""
from __future__ import annotations

from repro.distributed import ctx


def default_interpret() -> bool:
    """True when the lowering target cannot run compiled Mosaic kernels."""
    return ctx.current_platform() != "tpu"
