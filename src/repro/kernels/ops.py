"""DEPRECATED back-compat shim for the kernel entry points.

The real logic lives in ``repro.kernels.dispatch`` — one place that owns
backend resolution (mesh platform, shape alignment, GQA divisibility),
shard_map partitioning, and the custom VJPs.  Importing this module emits
a ``DeprecationWarning``; update imports to ``repro.kernels.dispatch``
(same names, same signatures).  This shim will be removed once nothing in
the tree references it.
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.kernels.ops is deprecated; import the kernel entry points from "
    "repro.kernels.dispatch instead (same names, same signatures)",
    DeprecationWarning, stacklevel=2)

from repro.kernels.dispatch import (  # noqa: F401,E402
    decode_attention,
    flash_attention,
    flash_attention_append,
    rmsnorm,
    rmsprop_update,
)

__all__ = ["decode_attention", "flash_attention", "flash_attention_append",
           "rmsnorm", "rmsprop_update"]
