"""Jit'd public wrappers around the Pallas kernels.

These are the entry points the model/optimizer layers call with
``backend="pallas"``; each handles layout, padding, and falls back to the
jnp reference for shapes the kernels don't support (tiny smoke sizes).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_fwd
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention_bwd import flash_attention_bwd
from repro.kernels.rmsnorm import rmsnorm_fwd
from repro.kernels.shared_rmsprop import rmsprop_update_2d

LANES = 1024


def _flash_blocks(s: int) -> int:
    # largest block <= 512 dividing s (s is a multiple of 128 on this
    # path, so this terminates at >= 128)
    b = min(512, s)
    while s % b:
        b //= 2
    return b


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _flash_pallas(q, k, v, causal, window):
    bq = bk = _flash_blocks(q.shape[1])
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=bq, block_k=bk)


def _flash_pallas_fwd(q, k, v, causal, window):
    bq = bk = _flash_blocks(q.shape[1])
    o, lse = flash_attention_fwd(q, k, v, causal=causal, window=window,
                                 block_q=bq, block_k=bk,
                                 save_residuals=True)
    return o, (q, k, v, o, lse)


def _flash_pallas_bwd(causal, window, res, do):
    q, k, v, o, lse = res
    bq = bk = _flash_blocks(q.shape[1])
    return flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                               window=window, block_q=bq, block_k=bk)


_flash_pallas.defvjp(_flash_pallas_fwd, _flash_pallas_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None) -> jnp.ndarray:
    """q (B,S,Hq,D); k,v (B,S,Hkv,D) -> (B,S,Hq,D).

    Differentiable end-to-end: the Pallas path carries a custom VJP whose
    backward is the fused recompute kernel in ``flash_attention_bwd``; the
    small-shape fallback differentiates through the jnp reference."""
    s = q.shape[1]
    if s < 128 or s % 128 != 0:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash_pallas(q, k, v, causal, window)


@jax.jit
def decode_attention(q, k_cache, v_cache, kpos,
                     pos=None) -> jnp.ndarray:
    """q (B,Hq,D); caches (B,L,Hkv,D); kpos (L,) -> (B,Hq,D)."""
    if pos is None:
        pos = jnp.max(kpos)
    length = k_cache.shape[1]
    if length < 128 or length % 128 != 0:
        return ref.decode_attention_ref(q, k_cache, v_cache, kpos, pos)
    bk = min(1024, length)
    while length % bk:
        bk //= 2
    return decode_attention_fwd(q, k_cache, v_cache, kpos, pos, block_k=bk)


@functools.partial(jax.jit, static_argnames=("lr", "alpha", "eps"))
def rmsprop_update(g, grad, *, lr, alpha: float = 0.99,
                   eps: float = 0.1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused Shared-RMSProp for an arbitrary-shaped parameter leaf.
    Returns (new_g, update)."""
    shape = g.shape
    n = g.size
    if n < LANES:
        return ref.rmsprop_update_ref(g, grad, lr=lr, alpha=alpha, eps=eps)
    rows = -(-n // LANES)
    pad = rows * LANES - n
    gf = jnp.pad(g.reshape(-1), (0, pad)).reshape(rows, LANES)
    df = jnp.pad(grad.reshape(-1), (0, pad)).reshape(rows, LANES)
    br = 256
    while rows % br:
        br //= 2
    new_g, upd = rmsprop_update_2d(gf, df, jnp.asarray(lr, g.dtype),
                                   alpha=alpha, eps=eps, block_rows=br)
    unpad = lambda x: x.reshape(-1)[:n].reshape(shape)
    return unpad(new_g), unpad(upd)


@functools.partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x, scale, *, eps: float = 1e-6) -> jnp.ndarray:
    """Fused RMSNorm over the last dim of an arbitrary-rank activation."""
    shape = x.shape
    d = shape[-1]
    rows = x.size // d
    if rows < 8 or d % 128 != 0:
        return ref.rmsnorm_ref(x, scale, eps=eps)
    y = rmsnorm_fwd(x.reshape(rows, d), scale, eps=eps)
    return y.reshape(shape)
