"""Back-compat re-exports of the kernel entry points.

The real logic lives in ``repro.kernels.dispatch`` — one place that owns
backend resolution (mesh platform, shape alignment, GQA divisibility),
shard_map partitioning, and the custom VJPs.  Import from there in new
code; this module only keeps the historical ``kernels.ops`` names alive.
"""
from __future__ import annotations

from repro.kernels.dispatch import (  # noqa: F401
    decode_attention,
    flash_attention,
    rmsnorm,
    rmsprop_update,
)

__all__ = ["decode_attention", "flash_attention", "rmsnorm",
           "rmsprop_update"]
