"""Pallas TPU decode attention: one query token per sequence vs a KV cache.

The memory-bound phase of serving: each step streams the KV cache from HBM
once.  Grid: (batch, kv_heads, n_kv_blocks) — all G query heads that share a
KV head are packed into one (G x D) @ (D x block_k) MXU matmul per block, so
GQA costs one cache read regardless of the query-head fan-out.  Online
softmax state lives in VMEM scratch across the innermost KV dimension.

Positions are **per slot** (continuous batching): ``pos (B,)`` is each
sequence's current decode position and ``kpos (B, L)`` the absolute position
held by each of its cache slots (-1 = unwritten), so every batch row can sit
at a different decode depth — a just-admitted request next to one that is
thousands of tokens deep.  ``kpos`` also handles ring-buffer
(sliding-window) caches where slot order is rotated.  Lockstep callers pass
broadcast views; the dispatch layer normalizes scalar ``pos`` / 1-D ``kpos``
automatically.

Two entry points share the kernel body:

  * ``decode_attention_fwd``      — normalized output (B, Hq, D).
  * ``decode_attention_partials`` — per-call ``(acc, m, l)`` flash-decoding
    partials, for the context-parallel path: each seq shard runs the kernel
    over its local cache slice and the cross-shard combine is an O(B*Hq*D)
    psum of the partials (dispatch's ``pallas_cp`` arm) instead of an
    all-gather of the multi-GB cache.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels._interpret import default_interpret

NEG = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, kpos_ref, *refs,
            block_k: int, n_k: int, scale: float, partials: bool,
            quant: bool):
    if quant:
        ks_ref, vs_ref, *refs = refs
    if partials:
        acc_out_ref, m_out_ref, l_out_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                         # (G, D)
    k = k_ref[0, :, 0, :]                   # (bk, D)
    v = v_ref[0, :, 0, :]                   # (bk, D)
    if quant:
        # dequant in VMEM: the HBM stream stays int8, the per-(row, head)
        # f32 scales ((bk, 1) blocks) broadcast over the lane dim
        k = k.astype(jnp.float32) * ks_ref[0, :, 0, :]
        v = v.astype(jnp.float32) * vs_ref[0, :, 0, :]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = kpos_ref[0, :]                   # (bk,) — this row's slot map
    pos = pos_ref[pl.program_id(0)]         # this row's decode position
    valid = (kpos >= 0) & (kpos <= pos)
    s = jnp.where(valid[None, :], s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + \
        jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        if partials:
            # unnormalized flash-decoding state; a fully-masked slice keeps
            # m=NEG, so its correction exp(m - pmax(m)) underflows to 0 and
            # the slice vanishes in the cross-shard combine
            acc_out_ref[0, 0] = acc_ref[...]
            m_out_ref[0, 0] = m_ref[...]
            l_out_ref[0, 0] = l_ref[...]
        else:
            l_safe = jnp.maximum(l_ref[...], 1e-30)
            o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]) \
                .astype(o_ref.dtype)


def _per_slot(kpos, pos, batch: int):
    """Normalize lockstep (kpos (L,), pos ()) inputs to the per-slot layout
    the kernel reads (kpos (B, L), pos (B,))."""
    if kpos.ndim == 1:
        kpos = jnp.broadcast_to(kpos, (batch,) + kpos.shape)
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (batch,))
    return kpos, pos


def _call(q, k_cache, v_cache, kpos, pos, *, block_k: int, partials: bool,
          interpret: Optional[bool], k_scale=None, v_scale=None):
    b, hq, d = q.shape
    length = k_cache.shape[1]
    hkv = k_cache.shape[2]
    g = hq // hkv
    bk = min(block_k, length)
    assert length % bk == 0
    n_k = length // bk
    quant = k_scale is not None
    kpos, pos = _per_slot(kpos, pos, b)
    if interpret is None:
        interpret = default_interpret()

    qg = q.reshape(b, hkv, g, d)
    kern = functools.partial(_kernel, block_k=bk, n_k=n_k, scale=d ** -0.5,
                             partials=partials, quant=quant)
    blk4 = pl.BlockSpec((1, 1, g, d), lambda b_, h, ik: (b_, h, 0, 0))
    blk3 = pl.BlockSpec((1, 1, g), lambda b_, h, ik: (b_, h, 0))
    if partials:
        out_specs = [blk4, blk3, blk3]
        out_shape = [jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
                     jax.ShapeDtypeStruct((b, hkv, g), jnp.float32),
                     jax.ShapeDtypeStruct((b, hkv, g), jnp.float32)]
    else:
        out_specs = blk4
        out_shape = jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype)
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),   # pos (B,)
        pl.BlockSpec((1, 1, g, d), lambda b_, h, ik: (b_, h, 0, 0)),
        pl.BlockSpec((1, bk, 1, d), lambda b_, h, ik: (b_, ik, h, 0)),
        pl.BlockSpec((1, bk, 1, d), lambda b_, h, ik: (b_, ik, h, 0)),
        pl.BlockSpec((1, bk), lambda b_, h, ik: (b_, ik)),
    ]
    operands = [pos.astype(jnp.int32), qg, k_cache, v_cache, kpos]
    if quant:
        # per-(row, head) f32 scales (B, L, Hkv, 1) ride next to the caches
        in_specs += [
            pl.BlockSpec((1, bk, 1, 1), lambda b_, h, ik: (b_, ik, h, 0)),
            pl.BlockSpec((1, bk, 1, 1), lambda b_, h, ik: (b_, ik, h, 0)),
        ]
        operands += [k_scale.astype(jnp.float32),
                     v_scale.astype(jnp.float32)]
    return pl.pallas_call(
        kern,
        grid=(b, hkv, n_k),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


def decode_attention_fwd(q, k_cache, v_cache, kpos, pos, *,
                         block_k: int = 1024,
                         interpret: Optional[bool] = None,
                         k_scale=None, v_scale=None) -> jnp.ndarray:
    """q (B,Hq,D); caches (B,L,Hkv,D); kpos (B,L) [or (L,) lockstep];
    pos (B,) [or () lockstep] -> (B,Hq,D).

    With ``k_scale``/``v_scale`` ((B, L, Hkv, 1) f32) the caches are int8
    and dequantized inside the kernel body (VMEM), so HBM traffic stays
    int8."""
    b, hq, d = q.shape
    out = _call(q, k_cache, v_cache, kpos, pos, block_k=block_k,
                partials=False, interpret=interpret,
                k_scale=k_scale, v_scale=v_scale)
    return out.reshape(b, hq, d)


def decode_attention_partials(q, k_cache, v_cache, kpos, pos, *,
                              block_k: int = 1024,
                              interpret: Optional[bool] = None,
                              k_scale=None, v_scale=None):
    """Flash-decoding partials over a (local) cache slice.

    Same shapes as ``decode_attention_fwd`` but returns the unnormalized
    online-softmax state ``(acc (B,Hkv,G,D) f32, m (B,Hkv,G) f32,
    l (B,Hkv,G) f32)``; the caller combines across slices with
    ``o = psum(acc * exp(m - pmax(m))) / psum(l * exp(m - pmax(m)))``.
    """
    acc, m, l = _call(q, k_cache, v_cache, kpos, pos, block_k=block_k,
                      partials=True, interpret=interpret,
                      k_scale=k_scale, v_scale=v_scale)
    return acc, m, l
