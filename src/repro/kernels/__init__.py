from repro.kernels import dispatch, ref  # noqa: F401
