"""Minimal pytree checkpointing (npz) — replicated-safe: arrays are pulled
to host with fully-addressable gather before save."""
from __future__ import annotations

import os

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, tree) -> None:
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(jax.device_get(x))
              for i, x in enumerate(leaves)}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    np.savez(tmp, treedef=str(treedef), **arrays)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def restore(path: str, like):
    """Restore into the structure of ``like`` (shapes must match)."""
    data = np.load(path, allow_pickle=False)
    leaves, treedef = _flatten(like)
    new_leaves = [data[f"leaf_{i}"] for i in range(len(leaves))]
    for old, new in zip(leaves, new_leaves):
        assert old.shape == new.shape, (old.shape, new.shape)
    return jax.tree.unflatten(treedef, new_leaves)
