from repro.optim.optimizers import OPTIMIZERS, apply_updates  # noqa: F401
from repro.optim import schedules  # noqa: F401
