"""The paper's three optimizers (§4.5), as pure functional updates.

  * shared_rmsprop — non-centered RMSProp whose second-moment accumulator g
    is SHARED across actor-learners (Eq. 8–9).  The paper's key optimizer
    finding (Fig. 8): sharing g greatly improves robustness.
  * rmsprop        — identical math, but g is per-worker (the runner carries
    one state per worker, i.e. vmapped).
  * momentum_sgd   — per-worker momentum vector m_i = α m_i + (1-α) Δθ.

API: ``opt.init(params) -> state``; ``opt.update(grads, state, lr) ->
(updates, state)``; apply with ``apply_updates(params, updates)``.  Updates
are *subtracted* (gradient descent).  The fused Pallas kernel in
repro.kernels.shared_rmsprop implements the same elementwise math one HBM
pass; ``shared_rmsprop(fused=True)`` routes through it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Params], Any]
    update: Callable[..., Tuple[Params, Any]]  # (grads, state, lr) -> ...


def shared_rmsprop(*, alpha: float = 0.99, eps: float = 0.1,
                   fused: bool = False) -> Optimizer:
    def init(params):
        return {"g": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, lr):
        if fused:
            from repro.kernels import dispatch as kops

            def upd(g_acc, dg):
                return kops.rmsprop_update(g_acc, dg, lr=lr, alpha=alpha,
                                           eps=eps)
            out = jax.tree.map(upd, state["g"], grads)
            new_g = jax.tree.map(lambda o: o[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
            updates = jax.tree.map(lambda o: o[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
            return updates, {"g": new_g}
        new_g = jax.tree.map(
            lambda g, dg: alpha * g + (1 - alpha) * jnp.square(dg),
            state["g"], grads)
        updates = jax.tree.map(
            lambda dg, g: lr * dg / jnp.sqrt(g + eps), grads, new_g)
        return updates, {"g": new_g}

    return Optimizer("shared_rmsprop", init, update)


# per-worker RMSProp is the same math; the distinction (shared vs per-worker
# accumulator) lives in the async runner, which either carries ONE state or
# one state PER worker.
def rmsprop(**kw) -> Optimizer:
    opt = shared_rmsprop(**kw)
    return dataclasses.replace(opt, name="rmsprop")


def momentum_sgd(*, alpha: float = 0.9) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, lr):
        new_m = jax.tree.map(lambda m, dg: alpha * m + (1 - alpha) * dg,
                             state["m"], grads)
        updates = jax.tree.map(lambda m: lr * m, new_m)
        return updates, {"m": new_m}

    return Optimizer("momentum_sgd", init, update)


def apply_updates(params: Params, updates: Params) -> Params:
    return jax.tree.map(lambda p, u: (p - u).astype(p.dtype), params, updates)


OPTIMIZERS = {
    "shared_rmsprop": shared_rmsprop,
    "rmsprop": rmsprop,
    "momentum_sgd": momentum_sgd,
}
