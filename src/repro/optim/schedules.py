"""Learning-rate schedules and per-worker hyperparameter sampling.

The paper anneals lr linearly to 0 over training and samples the initial lr
per experiment from LogUniform(1e-4, 1e-2) (§5.1).  MiniCPM's WSD
(warmup-stable-decay) schedule is included because the assigned minicpm-2b
config cites it as the model's training-recipe signature.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_anneal(lr0, step, total_steps):
    frac = jnp.clip(1.0 - step / total_steps, 0.0, 1.0)
    return lr0 * frac


def log_uniform(key, lo: float = 1e-4, hi: float = 1e-2, shape=()):
    u = jax.random.uniform(key, shape)
    return jnp.exp(jnp.log(lo) + u * (jnp.log(hi) - jnp.log(lo)))


def wsd(lr0, step, total_steps, *, warmup_frac: float = 0.01,
        decay_frac: float = 0.1, floor: float = 0.1):
    """Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395 §4)."""
    warm = warmup_frac * total_steps
    decay_start = (1.0 - decay_frac) * total_steps
    warm_lr = lr0 * step / jnp.maximum(warm, 1)
    decay_t = (step - decay_start) / jnp.maximum(total_steps - decay_start, 1)
    decay_lr = lr0 * (floor ** jnp.clip(decay_t, 0.0, 1.0))
    return jnp.where(step < warm, warm_lr,
                     jnp.where(step < decay_start, lr0, decay_lr))


SCHEDULES = {"linear": linear_anneal, "wsd": wsd,
             "constant": lambda lr0, step, total: lr0 * jnp.ones_like(step,
                                                                      jnp.float32)}
