"""Dump the top trip-count-weighted collective ops for one dry-run case."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re, sys
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.core import llm_a3c
from repro.distributed import ctx, sharding
from repro.launch import specs as specs_mod, hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.optim import optimizers as opt_mod


def compile_case(arch, shape):
    cfg = get_config(arch)
    cfg = specs_mod.maybe_long_variant(cfg, shape)
    mesh = make_production_mesh()
    kind, in_specs = specs_mod.input_specs(cfg, shape)
    bsz = specs_mod.INPUT_SHAPES[shape]["batch"]
    p_specs = specs_mod.params_specs(cfg)
    p_shard = sharding.param_shardings(cfg, mesh, p_specs)
    rules = sharding.activation_rules(mesh, batch_size=bsz, cfg=cfg)
    with jax.sharding.set_mesh(mesh), ctx.sharding_rules(rules):
        if kind == "train":
            opt = opt_mod.shared_rmsprop()
            opt_specs = jax.eval_shape(opt.init, p_specs)
            b_shard = sharding.batch_shardings(mesh, in_specs, batch_size=bsz)
            lowered = jax.jit(llm_a3c.make_train_step(cfg, opt),
                in_shardings=(p_shard, {"g": p_shard}, b_shard, None),
                out_shardings=(p_shard, {"g": p_shard}, None)).lower(
                p_specs, opt_specs, in_specs, jax.ShapeDtypeStruct((), jnp.int32))
        elif kind == "decode":
            serve_step = llm_a3c.make_serve_step(cfg)
            b_shard = sharding.batch_shardings(mesh, in_specs["batch"], batch_size=bsz)
            c_shard = sharding.cache_shardings(cfg, mesh, in_specs["cache"], batch_size=bsz)
            lowered = jax.jit(serve_step,
                in_shardings=(p_shard, c_shard, b_shard, None, None),
                out_shardings=(None, None, c_shard)).lower(
                p_specs, in_specs["cache"], in_specs["batch"],
                in_specs["pos"], in_specs["key"])
        else:
            def prefill(params, batch):
                out = M.forward(cfg, params, batch)
                return out["logits"][:, -1]
            b_shard = sharding.batch_shardings(mesh, in_specs, batch_size=bsz)
            lowered = jax.jit(prefill, in_shardings=(p_shard, b_shard)).lower(p_specs, in_specs)
        return lowered.compile()


def top_collectives(text, n=15):
    comps = H.split_computations(text)
    sym = H.build_symbols(comps)
    tallies = {name: H.tally_computation(c, sym) for name, c in comps.items()}
    entry = next(nm for nm, c in comps.items() if c.is_entry)
    weights = {}
    def walk(name, w, depth=0):
        t = tallies.get(name)
        if t is None or depth > 40: return
        for callee in t.calls:
            weights[callee] = weights.get(callee, 0) + w
            walk(callee, w, depth + 1)
        for cond, body in t.whiles:
            k = H.trip_count(comps, cond)
            for cn in (cond, body):
                weights[cn] = weights.get(cn, 0) + w * k
                walk(cn, w * k, depth + 1)
    weights[entry] = 1.0
    walk(entry, 1.0)
    rows = []
    for name, c in comps.items():
        w = weights.get(name, 0)
        if not w: continue
        for line in c.lines:
            m = re.match(r"(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?.+?\)?)\s+"
                         r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(?:-start)?\(", line)
            if m:
                nm, rt, kd = m.groups()
                ob = H._type_bytes(rt)
                mult = 2 if kd == "all-reduce" else 1
                meta = re.search(r'op_name="([^"]+)"', line)
                rows.append((w * ob * mult, w, ob, kd, (meta.group(1) if meta else nm)[-110:]))
    rows.sort(reverse=True)
    tot = sum(r[0] for r in rows)
    print(f"total weighted collective bytes/dev: {tot/1e9:.1f} GB")
    for r in rows[:n]:
        print(f"{r[0]/1e9:9.2f}GB w={r[1]:6.0f} sz={r[2]/1e6:8.1f}MB {r[3]:18s} {r[4]}")


if __name__ == "__main__":
    comp = compile_case(sys.argv[1], sys.argv[2])
    top_collectives(comp.as_text())
