"""Small-scope exhaustive interleaving check of the serve engine's
``PageAllocator`` (family ``allocator``).

Drives REAL ``PageAllocator`` instances (via ``launch.serve``'s
``AllocatorModel`` export) through every interleaving of
alloc / reserve / reserved-alloc / unreserve / incref / release /
COW-fork / preempt up to a bounded depth — the small-scope hypothesis:
refcount/version/reservation bugs that exist at all show up within a
handful of operations on a handful of pages.  Invariants checked on
every reached state:

  * refcounts never negative, and exactly equal to the live hold count;
  * the free list never contains a held page (or duplicates), and page 0
    (the garbage sink) is never handed out;
  * ``0 <= reserved <= len(free)`` — the admission-reservation invariant
    that makes reserved allocations infallible (an overbooked reserve
    would let decode fail on pages admission already promised);
  * a page's version never changes while a reference is live (so an
    index entry recorded at acquire time stays valid exactly as long as
    the page does);
  * every recycle (refcount returning to 0) bumps the version by exactly
    one — the property that makes stale ``PrefixIndex`` entries fail
    validation instead of aliasing a reissued page.

Coverage is part of the contract: the run must actually reach a COW
fork, a recycled-page reuse, a reserved allocation and a preemption, and
the reached state count must clear ``STATE_FLOOR`` — a silently-shrunk
op vocabulary (or collapsed state space) fails ``--strict`` instead of
vacuously passing.  Counters land in ``AUDIT.json``
(``allocator_model`` block).
"""
from __future__ import annotations

from typing import List, Optional

from tools.audit.framework import PassResult, Violation, ensure_importable

DEPTH = 6
N_PAGES = 4
# the full op vocabulary reaches 762 states at DEPTH=6/N_PAGES=4 (the
# pre-speculation model reached 217, pre-reservation 145): the floor sits
# between the last two, so dropping the spec/rewind/commit families — or
# the reserve/preempt ones — trips it while honest refactors keep slack
STATE_FLOOR = 600


def _canon(alloc, holds):
    return (tuple(alloc.free), tuple(int(r) for r in alloc.ref),
            tuple(int(v) for v in alloc.version),
            int(getattr(alloc, "reserved", 0)), holds)


def _invariants(alloc, holds, loc: str) -> List[Violation]:
    v: List[Violation] = []

    def V(msg):
        v.append(Violation("alloc-interleaving", loc, 0, msg))
    counts = {}
    for h in holds:                    # (page, version, kind) triples
        counts[h[0]] = counts.get(h[0], 0) + 1
    for p in range(alloc.n_pages):
        r = int(alloc.ref[p])
        if r < 0:
            V(f"page {p}: negative refcount {r}")
        if p == 0 and (r != 0 or 0 in counts):
            V("page 0 (garbage sink) was handed out")
        if p >= 1 and r != counts.get(p, 0):
            V(f"page {p}: refcount {r} != live hold count "
              f"{counts.get(p, 0)}")
    if len(set(alloc.free)) != len(alloc.free):
        V(f"free list has duplicates: {alloc.free}")
    held = set(counts)
    dup = held & set(alloc.free)
    if dup:
        V(f"pages {sorted(dup)} simultaneously held and on the free list")
    if 0 in alloc.free:
        V("page 0 (garbage sink) is on the free list")
    reserved = int(getattr(alloc, "reserved", 0))
    if reserved < 0:
        V(f"negative reservation count {reserved}")
    if reserved > len(alloc.free):
        V(f"reserved {reserved} exceeds free {len(alloc.free)} — a "
          "reserved allocation admission already promised could fail")
    for h in holds:
        p, ver = h[0], h[1]
        cur = int(alloc.version[p])
        if cur != ver:
            V(f"page {p}: version moved {ver} -> {cur} while a reference "
              "is live (use-after-recycle without version bump)")
    return v


def explore(model, depth: int = DEPTH) -> "tuple[List[Violation], dict]":
    """BFS every op interleaving to ``depth``, checking invariants on
    each transition.  ``model`` is ``launch.serve.AllocatorModel`` (or a
    fixture with an intentionally broken allocator_cls)."""
    violations: List[Violation] = []
    alloc0, holds0 = model.initial()
    loc = f"allocator:{type(alloc0).__name__}"
    violations.extend(_invariants(alloc0, holds0, loc))
    frontier = [(alloc0, holds0)]
    seen = {_canon(alloc0, holds0)}
    stats = {"depth": depth, "n_pages": model.n_pages,
             "states_explored": 1, "ops_applied": 0,
             "cow_forks": 0, "recycle_reuse": 0,
             "reserve_ops": 0, "reserved_allocs": 0, "preempts": 0,
             "spec_allocs": 0, "rewinds": 0, "spec_commits": 0}
    for _ in range(depth):
        nxt = []
        for alloc, holds in frontier:
            for op in model.enabled_ops(alloc, holds):
                will_pop = alloc.free[-1] \
                    if op[0] in ("alloc", "alloc_r", "cow", "spec") \
                    and alloc.free else None
                recycled = will_pop is not None and \
                    int(alloc.version[will_pop]) > 0
                recycle_before = None
                if op[0] == "release":
                    p_rel = holds[op[1]][0]
                    recycle_before = (p_rel, int(alloc.ref[p_rel]),
                                      int(alloc.version[p_rel]))
                try:
                    a2, h2 = model.apply(alloc, holds, op)
                except Exception as e:
                    violations.append(Violation(
                        "alloc-interleaving", loc, 0,
                        f"op {op!r} raised {e!r} though enabled"))
                    continue
                stats["ops_applied"] += 1
                if op[0] == "cow":
                    stats["cow_forks"] += 1
                elif op[0] == "reserve":
                    stats["reserve_ops"] += 1
                elif op[0] == "alloc_r":
                    stats["reserved_allocs"] += 1
                elif op[0] == "preempt":
                    stats["preempts"] += 1
                elif op[0] == "spec":
                    stats["spec_allocs"] += 1
                elif op[0] == "rewind":
                    stats["rewinds"] += 1
                elif op[0] == "commit":
                    stats["spec_commits"] += 1
                if recycled:
                    stats["recycle_reuse"] += 1
                errs = _invariants(a2, h2, loc)
                if recycle_before is not None:
                    p_rel, r_before, v_before = recycle_before
                    if r_before == 1:          # this release recycles
                        v_after = int(a2.version[p_rel])
                        if v_after != v_before + 1:
                            errs.append(Violation(
                                "alloc-interleaving", loc, 0,
                                f"recycling page {p_rel} moved version "
                                f"{v_before} -> {v_after}, expected "
                                f"{v_before + 1} — stale index entries "
                                "would alias the reissued page"))
                        if p_rel not in a2.free:
                            errs.append(Violation(
                                "alloc-interleaving", loc, 0,
                                f"page {p_rel} recycled but not returned "
                                "to the free list (leak)"))
                if errs:
                    trimmed = errs[:4]
                    for e in trimmed:
                        e.message += f" [after op {op!r}]"
                    violations.extend(trimmed)
                    continue                     # don't explore past a bug
                key = _canon(a2, h2)
                if key not in seen:
                    seen.add(key)
                    nxt.append((a2, h2))
        frontier = nxt
        stats["states_explored"] = len(seen)
    return violations, stats


def replay_trace(allocator, trace) -> List[Violation]:
    """Apply a raw op trace (``("alloc",) | ("incref", p) |
    ("decref", p) | ("spec_alloc",) | ("rewind", p) | ("commit", p)``)
    to a live allocator, checking invariant basics after every op — the
    harness the known-bad fixtures run under.

    ``spec_alloc`` marks the page it hands out as a speculative hold;
    ``rewind`` is the rejected-draft rollback (decref + unmark) and
    ``commit`` resolves a speculative hold into a committed one.  A
    verify round resolves EVERY page it pre-allocated, one way or the
    other, so any page still marked speculative when the trace ends is a
    rollback leak — the engine would never decref it (``rewind`` skips
    committed pages, ``_free_slot_pages`` only walks the table) and the
    pool shrinks by one page per leaky round."""
    v: List[Violation] = []
    loc = f"allocator:{type(allocator).__name__}"
    spec_held: set = set()
    for i, op in enumerate(trace):
        try:
            if op[0] == "alloc":
                p = allocator.alloc()
                if p == 0:
                    v.append(Violation("alloc-interleaving", loc, 0,
                                       f"step {i}: alloc handed out the "
                                       "reserved sink page 0"))
            elif op[0] == "spec_alloc":
                p = allocator.alloc()
                if p == 0:
                    v.append(Violation("alloc-interleaving", loc, 0,
                                       f"step {i}: spec_alloc handed out "
                                       "the reserved sink page 0"))
                spec_held.add(p)
            elif op[0] == "incref":
                allocator.incref(op[1])
            elif op[0] == "decref":
                allocator.decref(op[1])
            elif op[0] == "rewind":
                if op[1] not in spec_held:
                    v.append(Violation(
                        "alloc-interleaving", loc, 0,
                        f"step {i}: rewind of page {op[1]} which holds "
                        "no speculative reference"))
                    return v
                allocator.decref(op[1])
                spec_held.discard(op[1])
            elif op[0] == "commit":
                if op[1] not in spec_held:
                    v.append(Violation(
                        "alloc-interleaving", loc, 0,
                        f"step {i}: commit of page {op[1]} which holds "
                        "no speculative reference"))
                    return v
                spec_held.discard(op[1])
            else:
                raise ValueError(f"unknown op {op!r}")
        except (RuntimeError, ValueError) as e:
            v.append(Violation("alloc-interleaving", loc, 0,
                               f"step {i}: op {op!r} raised {e!r}"))
            return v
        neg = [int(p) for p in range(allocator.n_pages)
               if allocator.ref[p] < 0]
        if neg:
            v.append(Violation(
                "alloc-interleaving", loc, 0,
                f"step {i}: op {op!r} drove refcount(s) negative on "
                f"page(s) {neg} — decref without a matching reference"))
            return v
    leaked = sorted(p for p in spec_held if allocator.ref[p] > 0)
    if leaked:
        v.append(Violation(
            "alloc-interleaving", loc, 0,
            f"trace ended with speculative hold(s) on page(s) {leaked} "
            "never rewound or committed — each leaky verify round "
            "shrinks the pool by a page (refcount leak on rollback)"))
    return v


def run_allocator_checks(root: str, *, depth: int = DEPTH,
                         n_pages: int = N_PAGES) -> List[PassResult]:
    ensure_importable(root)
    from repro.launch.serve import AllocatorModel
    violations, stats = explore(AllocatorModel(n_pages=n_pages),
                                depth=depth)
    if not stats["cow_forks"]:
        violations.append(Violation(
            "alloc-interleaving", "tools/audit/alloc_model.py", 0,
            "interleaving never reached a COW fork — scope too small to "
            "mean anything"))
    if not stats["recycle_reuse"]:
        violations.append(Violation(
            "alloc-interleaving", "tools/audit/alloc_model.py", 0,
            "interleaving never re-issued a recycled page — the "
            "version-bump path is unexercised"))
    if not stats["reserved_allocs"]:
        violations.append(Violation(
            "alloc-interleaving", "tools/audit/alloc_model.py", 0,
            "interleaving never consumed a reservation — the admission "
            "backpressure path (reserve -> alloc_r) is unexercised"))
    if not stats["preempts"]:
        violations.append(Violation(
            "alloc-interleaving", "tools/audit/alloc_model.py", 0,
            "interleaving never preempted a hold — the decode-exhaustion "
            "recovery path is unexercised"))
    if not stats["spec_allocs"]:
        violations.append(Violation(
            "alloc-interleaving", "tools/audit/alloc_model.py", 0,
            "interleaving never pre-allocated a speculative page — the "
            "verify-round pre-map path is unexercised"))
    if not stats["rewinds"]:
        violations.append(Violation(
            "alloc-interleaving", "tools/audit/alloc_model.py", 0,
            "interleaving never rewound a speculative hold — the "
            "rejected-draft rollback path is unexercised"))
    if not stats["spec_commits"]:
        violations.append(Violation(
            "alloc-interleaving", "tools/audit/alloc_model.py", 0,
            "interleaving never committed a speculative hold — the "
            "accepted-draft path is unexercised"))
    if depth >= DEPTH and n_pages >= N_PAGES \
            and stats["states_explored"] < STATE_FLOOR:
        violations.append(Violation(
            "alloc-interleaving", "tools/audit/alloc_model.py", 0,
            f"state space collapsed: {stats['states_explored']} states "
            f"< floor {STATE_FLOOR} — the model's op vocabulary shrank "
            "(preempt/reserve/release must all stay modeled)"))
    return [PassResult("alloc-interleaving", "allocator", violations,
                       stats)]
