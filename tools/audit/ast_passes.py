"""AST lint passes (family ``ast``) — the pluggable generalization of the
old ``tools/check_no_ops_import.py`` script.

Each pass is a class with a ``name``, a ``scope`` (repo subdirs it walks)
and a ``check_file(rel, tree, lines)`` hook returning violations; a pass
may also implement ``finalize(root)`` for whole-tree checks (e.g. "the
deleted shim file must not exist").  Register new passes in ``PASSES``.

An inline ``# lint: allow-<pass-name>`` (or the legacy
``lint: allow-ops-ref``) comment on the offending line suppresses that
line — used by tests that assert an import *fails*.
"""
from __future__ import annotations

import ast
import os
import re
from typing import List, Optional

from tools.audit.framework import PassResult, Violation, iter_py_files

KERNEL_MODULES = frozenset({"flash_attention", "flash_attention_bwd",
                            "decode_attention", "rmsnorm",
                            "shared_rmsprop"})
_STEP_NAME = re.compile(r"(^|_)step(_|$)")


def _allowed(lines: List[str], lineno: int, name: str) -> bool:
    if not 1 <= lineno <= len(lines):
        return False
    line = lines[lineno - 1]
    return f"lint: allow-{name}" in line or "lint: allow-ops-ref" in line


def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.random.key' for an Attribute/Name chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class AstPass:
    name = ""
    description = ""
    scope = ("src",)

    def check_file(self, rel: str, tree: ast.AST,
                   lines: List[str]) -> List[Violation]:
        raise NotImplementedError

    def finalize(self, root: str) -> List[Violation]:
        return []

    def _v(self, rel: str, line: int, msg: str) -> Violation:
        return Violation(self.name, rel, line, msg)


# built by concatenation so this module's own AST never holds the literal
# the pass hunts for (the linter must pass its own lint)
_OPS = "repro.kernels" + ".ops"


class NoOpsImportPass(AstPass):
    """The kernels ops shim served one deprecation cycle (PR 5) and is
    deleted (PR 6); nothing may import it or re-grow the shim file."""
    name = "no-ops-import"
    description = "no imports of the deleted kernels.ops shim"
    scope = ("src", "tests", "benchmarks", "tools", "examples")

    def check_file(self, rel, tree, lines):
        out = []
        in_kernels = os.path.basename(os.path.dirname(rel)) == "kernels"

        def flag(node, what):
            if not _allowed(lines, node.lineno, self.name):
                out.append(self._v(rel, node.lineno,
                                   f"kernels.ops is deleted ({what}); use "
                                   "repro.kernels.dispatch"))
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == _OPS or a.name.startswith(_OPS + "."):
                        flag(node, f"import {a.name}")
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                names = {a.name for a in node.names}
                if mod == _OPS:
                    flag(node, f"from {mod} import ...")
                elif mod in ("repro.kernels", "kernels") and "ops" in names:
                    flag(node, f"from {mod} import ops")
                elif node.level >= 1 and mod == "kernels" and "ops" in names:
                    flag(node, "from .kernels import ops")
                elif node.level >= 1 and not mod and "ops" in names \
                        and in_kernels:
                    flag(node, "from . import ops")
            elif isinstance(node, ast.Constant) and node.value == _OPS:
                flag(node, "string reference")
        return out

    def finalize(self, root):
        shim = os.path.join(root, "src", "repro", "kernels", "ops.py")
        if os.path.exists(shim):
            return [self._v("src/repro/kernels/ops.py", 0,
                            "deleted shim file has grown back")]
        return []


class KernelImportContainmentPass(AstPass):
    """Pallas kernel implementation modules are reachable only through
    ``kernels/dispatch.py`` — model/launch/core code importing a kernel
    directly bypasses backend resolution, alignment checks, and the
    decision log."""
    name = "kernel-import-containment"
    description = "no Pallas kernel module imported outside kernels/"
    scope = ("src",)

    def check_file(self, rel, tree, lines):
        norm = rel.replace(os.sep, "/")
        if "/repro/kernels/" in norm:
            return []                    # intra-package imports are fine
        out = []

        def flag(node, mod):
            if not _allowed(lines, node.lineno, self.name):
                out.append(self._v(
                    rel, node.lineno,
                    f"kernel module '{mod}' imported outside "
                    "kernels/dispatch.py; route through "
                    "repro.kernels.dispatch"))
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    parts = a.name.split(".")
                    if "kernels" in parts and parts[-1] in KERNEL_MODULES:
                        flag(node, parts[-1])
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                parts = mod.split(".")
                if parts[-1] in KERNEL_MODULES and "kernels" in parts:
                    flag(node, parts[-1])
                elif parts[-1] == "kernels":
                    for a in node.names:
                        if a.name in KERNEL_MODULES:
                            flag(node, a.name)
        return out


class NoDefaultBackendPass(AstPass):
    """Kernel and serve paths must resolve the platform from the lowering
    target (``ctx.current_platform()``), never from
    ``jax.default_backend()`` — a CPU host lowering a TPU mesh program
    would otherwise pick interpret-mode kernels for the TPU (PR 2
    policy; ``repro.distributed.ctx`` is the single authority and is
    exempt)."""
    name = "no-default-backend"
    description = "no jax.default_backend() in kernel/serve paths"
    scope = ("src",)
    _paths = ("repro/kernels/", "repro/launch/")
    _exempt = ()

    def check_file(self, rel, tree, lines):
        norm = rel.replace(os.sep, "/")
        if not any(p in norm for p in self._paths):
            return []
        out = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                dotted = _dotted(node.func)
                if dotted and dotted.endswith("default_backend") and \
                        not _allowed(lines, node.lineno, self.name):
                    out.append(self._v(
                        rel, node.lineno,
                        "jax.default_backend() in a kernel/serve path; "
                        "use ctx.current_platform() (or "
                        "kernels._interpret.default_interpret) so the "
                        "lowering TARGET decides"))
        return out


class StepKeyPass(AstPass):
    """PRNG keys must be threaded into step functions, not rebuilt inside
    them: ``jax.random.key(seed)`` re-created per step yields correlated
    streams (the PR 4 serve-sampling bug class).  Flags any
    ``jax.random.key`` / ``jax.random.PRNGKey`` call lexically inside a
    function whose name contains a ``step`` segment (``decode_step``,
    ``make_serve_step``'s inner fns, ...)."""
    name = "no-step-key-rebuild"
    description = "no jax.random.key() rebuilt inside step functions"
    scope = ("src",)
    _key_fns = ("random.key", "random.PRNGKey")

    def check_file(self, rel, tree, lines):
        out = []
        pass_ = self

        class V(ast.NodeVisitor):
            def __init__(self):
                self.stack: List[str] = []

            def _in_step(self):
                return any(_STEP_NAME.search(n) for n in self.stack)

            def visit_FunctionDef(self, node):
                self.stack.append(node.name)
                self.generic_visit(node)
                self.stack.pop()
            visit_AsyncFunctionDef = visit_FunctionDef

            def visit_Call(self, node):
                dotted = _dotted(node.func) or ""
                if self._in_step() and \
                        any(dotted.endswith(k) for k in pass_._key_fns) \
                        and not _allowed(lines, node.lineno, pass_.name):
                    out.append(pass_._v(
                        rel, node.lineno,
                        f"{dotted}(...) rebuilt inside step function "
                        f"'{self.stack[-1]}': thread the key in and "
                        "fold_in per step instead (correlated-streams "
                        "bug class)"))
                self.generic_visit(node)

        V().visit(tree)
        return out


class FallbackReasonPass(AstPass):
    """Every dispatch decision row must carry a non-empty reason string —
    a bare jnp fallback with no logged reason is undiagnosable from the
    dry-run/serve dispatch summaries."""
    name = "fallback-reason"
    description = "every _decide() call passes a non-empty reason"
    scope = ("src",)

    def check_file(self, rel, tree, lines):
        norm = rel.replace(os.sep, "/")
        if "repro/kernels/" not in norm:
            return []
        out = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func) or ""
            if dotted.split(".")[-1] != "_decide":
                continue
            reason = node.args[2] if len(node.args) >= 3 else None
            if reason is None:
                reason = next((kw.value for kw in node.keywords
                               if kw.arg == "reason"), None)
            if reason is None:
                out.append(self._v(rel, node.lineno,
                                   "_decide() without a reason argument"))
            elif isinstance(reason, ast.Constant) and \
                    isinstance(reason.value, str) and not \
                    reason.value.strip():
                out.append(self._v(rel, node.lineno,
                                   "_decide() with an empty reason "
                                   "string"))
        return out


PASSES = (NoOpsImportPass(), KernelImportContainmentPass(),
          NoDefaultBackendPass(), StepKeyPass(), FallbackReasonPass())


def run_pass(p: AstPass, root: str, files=None) -> PassResult:
    """Run one AST pass over its scope (or an explicit file list — the
    fixture tests point passes at ``tools/audit/fixtures``)."""
    paths = files if files is not None else iter_py_files(root, p.scope)
    violations, parsed = [], 0
    for path in paths:
        rel = os.path.relpath(path, root)
        try:
            src = open(path, encoding="utf-8", errors="replace").read()
            tree = ast.parse(src, filename=path)
        except SyntaxError as e:
            violations.append(Violation(p.name, rel, e.lineno or 0,
                                        f"syntax error: {e.msg}"))
            continue
        parsed += 1
        violations.extend(p.check_file(rel, tree, src.splitlines()))
    if files is None:
        violations.extend(p.finalize(root))
    return PassResult(p.name, "ast", violations, {"files": parsed})


def run_ast_passes(root: str, only=None) -> List[PassResult]:
    return [run_pass(p, root) for p in PASSES
            if only is None or p.name in only]
