"""Known-bad: interpret-mode keyed off the HOST platform.  A CPU host
lowering a TPU mesh program would pick interpreted kernels for the TPU."""
import jax


def pick_interpret():
    return jax.default_backend() == "cpu"    # flagged: host, not target
