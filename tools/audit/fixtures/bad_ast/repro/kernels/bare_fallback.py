"""Known-bad: decision rows without a reason — a bare jnp fallback that
cannot be diagnosed from the dispatch summary."""


def _decide(op, backend, reason=None):
    return (op, backend, reason)


def resolve(aligned):
    if not aligned:
        _decide("flash_attention", "jnp", "")     # flagged: empty reason
        return "jnp"
    _decide("flash_attention", "pallas")          # flagged: no reason arg
    return "pallas"
