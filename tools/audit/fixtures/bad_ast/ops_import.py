"""Known-bad: every spelling of the deleted kernels.ops shim import."""
import repro.kernels.ops                     # noqa: F401
from repro.kernels import ops                # noqa: F401
from repro.kernels.ops import flash_attention  # noqa: F401

ENTRY = "repro.kernels.ops"
