"""Known-bad: PRNG keys rebuilt inside step functions (correlated
streams across steps — the serve-sampling bug class)."""
import jax


def decode_step(logits, seed):
    key = jax.random.key(seed)               # flagged: rebuilt per step
    return jax.random.categorical(key, logits)


def make_serve_step(seed):
    def step_fn(logits):
        key = jax.random.PRNGKey(0)          # flagged: inner step fn
        return jax.random.categorical(key, logits)
    return step_fn


def warmup(seed):
    # NOT flagged: not a step function — keys may be built at setup time
    return jax.random.key(seed)
