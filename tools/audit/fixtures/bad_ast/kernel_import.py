"""Known-bad: kernel modules imported directly, bypassing dispatch."""
from repro.kernels import flash_attention          # noqa: F401
from repro.kernels.decode_attention import decode_attention_fwd  # noqa: F401
import repro.kernels.rmsnorm                       # noqa: F401
