"""Known-bad dispatch source for the resolver-decision-rows check: the
resolver below has a return path that picks an arm WITHOUT emitting a
decision row — exactly the silent-fallback bug the contract forbids.
``contracts.check_decision_rows`` is pointed at this file via its
``dispatch_src`` override."""


def _decide(op, backend, reason):
    return (op, backend, reason)


def _resolve_flash(b, s, hq, hkv, backend):
    if backend == "jnp":
        return _decide("flash_attention", "jnp", "explicit backend"), None
    if s % 128:
        return None, None        # flagged: silent jnp fallback, no row
    return _decide("flash_attention", "pallas", "aligned"), "spec"
