"""Known-bad page allocator for the interleaving check: recycling a page
does NOT bump its version (stale prefix-index entries would alias the
reissued page) and refcounts may go negative.  Plus the raw underflow
trace the replay harness must catch on the REAL allocator's op
vocabulary."""
import numpy as np


class NoVersionBumpAllocator:
    """Same surface as launch.serve.PageAllocator, minus the safety."""

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self.free = list(range(n_pages - 1, 0, -1))
        self.ref = np.zeros(n_pages, np.int32)
        self.version = np.zeros(n_pages, np.int64)

    def alloc(self) -> int:
        if not self.free:
            raise RuntimeError("page pool exhausted")
        p = self.free.pop()
        self.ref[p] = 1
        return p

    def incref(self, p: int) -> None:
        self.ref[p] += 1

    def decref(self, p: int) -> None:
        self.ref[p] -= 1
        if self.ref[p] <= 0:
            # BUG 1: no version bump — a recycled page is
            # indistinguishable from the page an old index entry named
            # BUG 2: <= 0 masks refcount underflow instead of failing
            self.free.append(p)


# alloc on a fresh 4-page pool hands out page 3 (LIFO); the second decref
# has no matching reference and must be reported as underflow
UNDERFLOW_TRACE = (("alloc",), ("decref", 3), ("decref", 3))
