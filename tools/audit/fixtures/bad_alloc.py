"""Known-bad page allocators for the interleaving check: one whose
recycling does NOT bump versions (stale prefix-index entries would alias
the reissued page) with refcounts that may go negative, and one whose
``reserve`` never checks capacity (overbooked reservations let a
reserved allocation — which admission promised cannot fail — fail at
decode time).  Plus the raw underflow trace the replay harness must
catch on the REAL allocator's op vocabulary."""
import numpy as np


class NoVersionBumpAllocator:
    """Same surface as launch.serve.PageAllocator, minus the safety."""

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self.free = list(range(n_pages - 1, 0, -1))
        self.ref = np.zeros(n_pages, np.int32)
        self.version = np.zeros(n_pages, np.int64)
        self.reserved = 0

    def try_alloc(self, *, reserved: bool = False):
        if reserved:
            if not self.free:
                return None
            self.reserved -= 1
        elif len(self.free) <= self.reserved:
            return None
        p = self.free.pop()
        self.ref[p] = 1
        return p

    def alloc(self) -> int:
        p = self.try_alloc()
        if p is None:
            raise RuntimeError("page pool exhausted")
        return p

    def reserve(self, n: int) -> bool:
        if len(self.free) - self.reserved < n:
            return False
        self.reserved += n
        return True

    def unreserve(self, n: int) -> None:
        self.reserved -= n

    def incref(self, p: int) -> None:
        self.ref[p] += 1

    def decref(self, p: int) -> None:
        self.ref[p] -= 1
        if self.ref[p] <= 0:
            # BUG 1: no version bump — a recycled page is
            # indistinguishable from the page an old index entry named
            # BUG 2: <= 0 masks refcount underflow instead of failing
            self.free.append(p)


class PhantomReserveAllocator(NoVersionBumpAllocator):
    """Reservation accounting without capacity checks: ``reserve``
    always succeeds, so ``reserved`` can exceed the free list and the
    "reserved allocs never fail" guarantee is a lie.  The interleaving
    check must flag the overbooked state."""

    def __init__(self, n_pages: int):
        super().__init__(n_pages)
        self.version = np.zeros(n_pages, np.int64)

    def reserve(self, n: int) -> bool:
        self.reserved += n     # BUG: no free-list capacity check
        return True

    def decref(self, p: int) -> None:
        # keep THIS fixture's version discipline correct so the only
        # violation the explorer reports is the reservation one
        self.ref[p] -= 1
        if self.ref[p] == 0:
            self.version[p] += 1
            self.free.append(p)


# alloc on a fresh 4-page pool hands out page 3 (LIFO); the second decref
# has no matching reference and must be reported as underflow
UNDERFLOW_TRACE = (("alloc",), ("decref", 3), ("decref", 3))

# a verify round pre-allocates two speculative pages (a fresh 4-page pool
# hands out 1 then 2) but only rewinds the second: page 1's reference is
# never resolved, so the replay harness must flag it as a rollback leak —
# the bug class where the engine's rejected-token rewind loop misses a
# page that verify mapped
LEAKY_ROLLBACK_TRACE = (("spec_alloc",), ("spec_alloc",), ("rewind", 2))
