"""Known-bad Pallas kernel for the kernel checker: one ``pallas_call``
that (a) walks its input index map off the end of the array, (b) lets
two *parallel* grid points write the same output block, and (c) asks for
more VMEM scratch than the per-step budget.  ``tests/test_audit.py``
captures it under ``PallasCapture`` and asserts ``check_record`` reports
all three."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

ROWS, D = 1024, 256
BLOCK = 256


def _kernel(x_ref, o_ref, scratch):
    o_ref[...] = x_ref[...]


def run():
    x = jnp.zeros((ROWS, D), jnp.float32)
    return pl.pallas_call(
        _kernel,
        grid=(ROWS // BLOCK, 2),
        in_specs=[
            # off-by-one: walks one block past the end of x
            pl.BlockSpec((BLOCK, D), lambda i, j: (i + 1, 0)),
        ],
        # every j writes the same block i — but j is marked "parallel"
        out_specs=pl.BlockSpec((BLOCK, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ROWS, D), jnp.float32),
        # 64 MiB scratch: 4x the 16 MiB default budget
        scratch_shapes=[pltpu.VMEM((4096, 4096), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=True,
    )(x)
