"""Dispatch-contract auditor (family ``contract``).

Imports ``repro.kernels.dispatch`` / ``repro.kernels.ref`` and statically
cross-checks the registries against each other and against the sharding
rules — the invariants here are exactly the ones a new kernel arm is most
likely to miss:

  * registry-oracles      every registered op's entry / ref-oracle /
                          quant-oracle / resolver / delegate actually
                          exist, and every public dispatch entry with a
                          ``backend=`` parameter is registered.
  * resolver-decision-rows  every return path of every resolver (and of
                          the delegating paged entries) emits a decision
                          row — no arm can be picked silently.
  * quant-note            every op with a quant oracle amends its
                          decision row for the int8 case.
  * cache-leaf-sharding   every cache leaf produced by
                          ``models.attention`` (f32/int8 x contiguous/
                          paged, incl. the ks|vs|kps|vps scale leaves)
                          matches an explicit rule in
                          ``sharding.cache_shardings``, and scale leaves
                          are rank-matched to their payloads so both hit
                          the SAME rule.
"""
from __future__ import annotations

import ast
import inspect
import os
import re
from typing import List

from tools.audit.framework import PassResult, Violation, ensure_importable


def _contains_decide(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            f = n.func
            name = f.attr if isinstance(f, ast.Attribute) else \
                getattr(f, "id", None)
            if name == "_decide":
                return True
    return False


def _scan_returns(stmts, decided: bool, missing: List[int]) -> None:
    """Flag every ``return`` not preceded (in its own or an enclosing
    block) by a ``_decide`` call and not containing one itself.  A
    ``_decide`` inside a nested branch does NOT mark the code after the
    branch as decided — the branch may not execute."""
    for st in stmts:
        if isinstance(st, ast.Return):
            if not decided and not (st.value is not None
                                    and _contains_decide(st.value)):
                missing.append(st.lineno)
        elif isinstance(st, (ast.Expr, ast.Assign, ast.AugAssign,
                             ast.AnnAssign)):
            if _contains_decide(st):
                decided = True
        elif isinstance(st, (ast.If, ast.For, ast.While)):
            _scan_returns(st.body, decided, missing)
            _scan_returns(st.orelse, decided, missing)
        elif isinstance(st, ast.Try):
            _scan_returns(st.body, decided, missing)
            for h in st.handlers:
                _scan_returns(h.body, decided, missing)
            _scan_returns(st.orelse, decided, missing)
            _scan_returns(st.finalbody, decided, missing)
        elif isinstance(st, ast.With):
            _scan_returns(st.body, decided, missing)


def _refs_name(fn_node: ast.AST, name: str) -> bool:
    return any(isinstance(n, ast.Name) and n.id == name
               for n in ast.walk(fn_node))


def _has_int8_marker(fn_node: ast.AST) -> bool:
    return any(isinstance(n, ast.Constant) and isinstance(n.value, str)
               and "int8" in n.value for n in ast.walk(fn_node))


def _function_defs(tree: ast.Module) -> dict:
    return {n.name: n for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def check_registry_oracles(root: str) -> PassResult:
    ensure_importable(root)
    from repro.kernels import dispatch, ref
    v: List[Violation] = []
    loc = "src/repro/kernels/dispatch.py"

    def V(msg):
        v.append(Violation("registry-oracles", loc, 0, msg))

    ops = dispatch.KERNEL_OPS
    for name, c in ops.items():
        if not callable(c.entry):
            V(f"op '{name}': entry is not callable")
        if not callable(getattr(ref, c.oracle, None)):
            V(f"op '{name}': oracle '{c.oracle}' missing from ref.py")
        if c.quant_oracle is not None and \
                not callable(getattr(ref, c.quant_oracle, None)):
            V(f"op '{name}': quant oracle '{c.quant_oracle}' missing "
              "from ref.py")
        if c.resolver is not None and \
                not callable(getattr(dispatch, c.resolver, None)):
            V(f"op '{name}': resolver '{c.resolver}' missing from "
              "dispatch.py")
        if c.delegate is not None and c.delegate not in ops:
            V(f"op '{name}': delegate '{c.delegate}' is not a registered "
              "op")
        if c.resolver is None and c.delegate is None and \
                name not in ("rmsprop_update",):
            V(f"op '{name}': neither resolver nor delegate — how is its "
              "backend picked?")

    # reverse direction: every public dispatch entry taking backend= must
    # be registered, else it escapes all contract/kernel checks
    registered = {c.entry.__name__ for c in ops.values()}
    for fname, fn in vars(dispatch).items():
        if fname.startswith("_") or not inspect.isfunction(fn):
            continue
        if fn.__module__ != dispatch.__name__:
            continue
        try:
            params = inspect.signature(fn).parameters
        except (TypeError, ValueError):
            continue
        if "backend" in params and fname not in registered:
            V(f"public entry '{fname}' takes backend= but is not in "
              "KERNEL_OPS — unauditable arm")
    return PassResult("registry-oracles", "contract", v,
                      {"ops": len(ops), "entries_scanned": len(registered)})


def check_decision_rows(root: str, dispatch_src: str = None) -> PassResult:
    """AST check: every return path of every resolver and every delegating
    entry emits a decision row (``dispatch_src`` overrides the file for
    fixture tests)."""
    ensure_importable(root)
    from repro.kernels import dispatch
    path = dispatch_src or os.path.join(root, "src", "repro", "kernels",
                                        "dispatch.py")
    rel = os.path.relpath(path, root)
    tree = ast.parse(open(path, encoding="utf-8").read(), filename=path)
    defs = _function_defs(tree)
    targets = []
    for name, c in dispatch.KERNEL_OPS.items():
        if c.resolver is not None:
            targets.append(c.resolver)
        if c.delegate is not None:
            targets.append(c.entry.__name__)
    v: List[Violation] = []
    checked = 0
    for t in sorted(set(targets)):
        node = defs.get(t)
        if node is None:
            v.append(Violation("resolver-decision-rows", rel, 0,
                               f"'{t}' referenced by KERNEL_OPS but not "
                               "defined at module top level"))
            continue
        checked += 1
        missing: List[int] = []
        _scan_returns(node.body, False, missing)
        for ln in missing:
            v.append(Violation(
                "resolver-decision-rows", rel, ln,
                f"return path in '{t}' without a _decide() decision row "
                "— this arm would be picked silently"))
    return PassResult("resolver-decision-rows", "contract", v,
                      {"functions_checked": checked})


def check_quant_note(root: str, dispatch_src: str = None) -> PassResult:
    """Every op with a quant oracle must amend its decision row for the
    int8 case: its entry references ``_quant_note`` (contiguous arms) or
    carries an explicit int8 reason amendment (delegating paged arms)."""
    ensure_importable(root)
    from repro.kernels import dispatch
    path = dispatch_src or os.path.join(root, "src", "repro", "kernels",
                                        "dispatch.py")
    rel = os.path.relpath(path, root)
    tree = ast.parse(open(path, encoding="utf-8").read(), filename=path)
    defs = _function_defs(tree)
    v: List[Violation] = []
    checked = 0
    for name, c in dispatch.KERNEL_OPS.items():
        if c.quant_oracle is None:
            continue
        node = defs.get(c.entry.__name__)
        if node is None:
            continue        # registry-oracles already flags this
        checked += 1
        if not (_refs_name(node, "_quant_note") or _has_int8_marker(node)):
            v.append(Violation(
                "quant-note", rel, node.lineno,
                f"quantized op '{name}' ({c.entry.__name__}) never amends "
                "its decision row for int8 (_quant_note or an int8 reason "
                "string)"))
    return PassResult("quant-note", "contract", v,
                      {"quant_ops_checked": checked})


def _sharding_patterns(root: str) -> List[str]:
    """The ``re.search(<pattern>, ps)`` constants inside
    ``cache_shardings`` — the explicit leaf rules."""
    path = os.path.join(root, "src", "repro", "distributed", "sharding.py")
    tree = ast.parse(open(path, encoding="utf-8").read(), filename=path)
    fn = _function_defs(tree).get("cache_shardings")
    pats = []
    if fn is not None:
        for n in ast.walk(fn):
            if isinstance(n, ast.Call) and \
                    isinstance(n.func, ast.Attribute) and \
                    n.func.attr == "search" and n.args and \
                    isinstance(n.args[0], ast.Constant) and \
                    isinstance(n.args[0].value, str):
                pats.append(n.args[0].value)
    return pats


def check_cache_leaf_sharding(root: str) -> PassResult:
    ensure_importable(root)
    import jax
    import jax.numpy as jnp
    from repro.models import attention

    rel = "src/repro/distributed/sharding.py"
    v: List[Violation] = []
    pats = _sharding_patterns(root)
    if not pats:
        v.append(Violation("cache-leaf-sharding", rel, 0,
                           "no re.search pattern constants found in "
                           "cache_shardings — rules are not auditable"))
        return PassResult("cache-leaf-sharding", "contract", v,
                          {"patterns": 0})

    def trees():
        for dtype, tag in ((jnp.bfloat16, "bf16"), (jnp.int8, "int8")):
            yield tag + "/contiguous", jax.eval_shape(
                lambda: attention.init_kv_cache(2, 1024, 2, 64, dtype))
            yield tag + "/paged", jax.eval_shape(
                lambda: attention.init_paged_kv_cache(
                    2, 1024, 2, 64, page_size=128, n_pages=24,
                    dtype=dtype))

    scale_to_payload = {"ks": "k", "vs": "v", "kps": "kp", "vps": "vp"}
    leaves_checked = 0
    for tag, tree in trees():
        for leaf_name, leaf in tree.items():
            leaves_checked += 1
            ps = "/" + leaf_name       # path string as _path_str renders it
            if leaf.ndim == 0 or ps.endswith("index"):
                continue               # scalar/index rule (non-regex arm)
            hits = [p for p in pats if re.search(p, ps)]
            if not hits:
                v.append(Violation(
                    "cache-leaf-sharding", rel, 0,
                    f"cache leaf '{leaf_name}' ({tag}, shape "
                    f"{tuple(leaf.shape)}) matches no explicit rule in "
                    "cache_shardings — it would fall to the SSM/state "
                    "heuristic"))
            payload = scale_to_payload.get(leaf_name)
            if payload is not None:
                pl_leaf = tree[payload]
                if leaf.ndim != pl_leaf.ndim:
                    v.append(Violation(
                        "cache-leaf-sharding", rel, 0,
                        f"scale leaf '{leaf_name}' rank {leaf.ndim} != "
                        f"payload '{payload}' rank {pl_leaf.ndim} — "
                        "layout treatments no longer apply verbatim"))
                pl_hits = [p for p in pats if re.search(p, "/" + payload)]
                if hits and pl_hits and hits != pl_hits:
                    v.append(Violation(
                        "cache-leaf-sharding", rel, 0,
                        f"scale leaf '{leaf_name}' matches {hits} but "
                        f"payload '{payload}' matches {pl_hits} — the "
                        "pair must hit the same rule"))
    return PassResult("cache-leaf-sharding", "contract", v,
                      {"patterns": len(pats),
                       "leaves_checked": leaves_checked})


def run_contract_passes(root: str, only=None) -> List[PassResult]:
    checks = {
        "registry-oracles": check_registry_oracles,
        "resolver-decision-rows": check_decision_rows,
        "quant-note": check_quant_note,
        "cache-leaf-sharding": check_cache_leaf_sharding,
    }
    return [fn(root) for name, fn in checks.items()
            if only is None or name in only]
