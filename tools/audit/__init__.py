"""repro-audit: one runner over every static invariant the repo keeps.

``python -m tools.audit`` runs four pass families (AST lints, dispatch
contracts, Pallas kernel checks, allocator interleaving) and writes the
machine-readable ``AUDIT.json`` next to the BENCH artifacts.  See
``framework`` for the report schema and DESIGN.md §static-analysis for
the invariants themselves.
"""
from __future__ import annotations

from typing import List, Optional

from tools.audit.framework import (DEFAULT_VMEM_BUDGET, PassResult,
                                   build_report, ensure_importable,
                                   repo_root, summary_line, write_report)

FAMILIES = ("ast", "contract", "kernel", "allocator")


def run_audit(root: Optional[str] = None, *, strict: bool = False,
              only: Optional[set] = None,
              vmem_budget: int = DEFAULT_VMEM_BUDGET) -> dict:
    """Run every registered pass (or the ``only`` subset, by pass name or
    family name) and return the report dict."""
    root = root or repo_root()
    ensure_importable(root)
    from tools.audit import alloc_model, ast_passes, contracts, kernel_check

    def want(family: str, names) -> Optional[set]:
        if only is None:
            return None
        if family in only:
            return None             # whole family selected -> no filter
        sel = {n for n in names if n in only}
        return sel or set()         # empty set -> skip family

    results: List[PassResult] = []

    ast_names = [p.name for p in ast_passes.PASSES]
    sel = want("ast", ast_names)
    if sel is None or sel:
        results += ast_passes.run_ast_passes(root, only=sel)

    contract_names = ["registry-oracles", "resolver-decision-rows",
                      "quant-note", "cache-leaf-sharding"]
    sel = want("contract", contract_names)
    if sel is None or sel:
        results += contracts.run_contract_passes(root, only=sel)

    sel = want("kernel", ["kernel-check"])
    if sel is None or sel:
        results += kernel_check.run_kernel_checks(root,
                                                  vmem_budget=vmem_budget)

    sel = want("allocator", ["alloc-interleaving"])
    if sel is None or sel:
        results += alloc_model.run_allocator_checks(root)

    if only is not None and not results:
        raise SystemExit(f"--only matched no registered pass: "
                         f"{sorted(only)}")
    return build_report(results, root, strict=strict)


def quick_summary(root: Optional[str] = None) -> str:
    """The one-liner ``benchmarks/run.py --quick`` prints: the cheap
    families only (AST + contracts), no kernel abstract-eval."""
    report = run_audit(root, only={"ast", "contract"})
    return summary_line(report)
