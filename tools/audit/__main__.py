"""CLI: ``python -m tools.audit [--strict] [--json PATH] [--only NAME]``.

Exit codes: 0 = all passes clean; 1 = violations found (always, not just
under --strict — --strict additionally fails the run on pass *errors*
recorded as violations, and is what CI runs); 2 = usage error.
"""
from __future__ import annotations

import argparse
import sys

from tools.audit import DEFAULT_VMEM_BUDGET, run_audit
from tools.audit.framework import repo_root, summary_line, write_report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.audit",
        description="Static-analysis suite: AST lints, dispatch "
                    "contracts, Pallas kernel checks, allocator "
                    "interleaving.")
    ap.add_argument("--strict", action="store_true",
                    help="CI mode: nonzero exit on any violation")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write AUDIT.json here (default: "
                         "<repo>/AUDIT.json for full runs; subset runs "
                         "via --only write no report unless --json is "
                         "given)")
    ap.add_argument("--only", action="append", default=None,
                    metavar="NAME",
                    help="run only this pass or family (repeatable); "
                         "families: ast, contract, kernel, allocator")
    ap.add_argument("--vmem-budget", type=int, default=DEFAULT_VMEM_BUDGET,
                    help="per-grid-step VMEM budget in bytes for the "
                         "kernel checker (default 16 MiB)")
    ap.add_argument("--root", default=None, help="repo root override")
    args = ap.parse_args(argv)

    root = args.root or repo_root()
    report = run_audit(root, strict=args.strict,
                       only=set(args.only) if args.only else None,
                       vmem_budget=args.vmem_budget)

    for p in report["passes"]:
        mark = "ok  " if p["status"] == "ok" else "FAIL"
        print(f"  {mark} [{p['family']}] {p['name']}"
              + (f"  ({len(p['violations'])} violation(s))"
                 if p["violations"] else ""))
        for v in p["violations"]:
            loc = f"{v['path']}:{v['line']}" if v["line"] else v["path"]
            print(f"       {loc}: {v['message']}")
    print(summary_line(report))

    # Only a FULL run may claim the default <repo>/AUDIT.json slot: a
    # --only subset silently overwriting the committed artifact would
    # misrepresent 1-pass coverage as the whole suite.
    out = args.json if args.json else (
        None if args.only else f"{root}/AUDIT.json")
    if out:
        write_report(report, out)
        print(f"report: {out}")
    return 1 if report["summary"]["violations"] else 0


if __name__ == "__main__":
    sys.exit(main())
