"""repro-audit plumbing: pass/violation types, file walking, the report.

A *pass* is one machine-checked invariant (or a tight family of them).
Every pass returns a :class:`PassResult`; the runner aggregates them into
the machine-readable ``AUDIT.json`` report that CI uploads next to the
``BENCH_*.json`` artifacts, so the perf trajectory and the invariant
trajectory live side by side.

Pass families (see DESIGN.md §static-analysis):

  * ``ast``       — pluggable AST lints over the tree (``ast_passes.py``)
  * ``contract``  — dispatch/ref/sharding registry cross-checks
                    (``contracts.py``)
  * ``kernel``    — grid/BlockSpec abstract-eval checks over every
                    registered Pallas kernel (``kernel_check.py``)
  * ``allocator`` — small-scope exhaustive interleaving check of the
                    serve engine's ``PageAllocator`` (``alloc_model.py``)

Adding a pass: implement it in the matching module, give it a unique
``name``, and register it in that module's ``PASSES`` tuple (AST passes)
or its ``run_*`` entry point — the runner discovers passes through those
module-level registries only, so a pass that is not registered does not
run (and ``tools.audit --only <name>`` will say so).
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional

DEFAULT_VMEM_BUDGET = 16 * 2 ** 20      # one TPU core's VMEM, bytes
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "results", ".venv",
             "fixtures"}                # fixtures are known-bad on purpose


@dataclasses.dataclass
class Violation:
    pass_name: str
    path: str          # repo-relative file, or a logical location
    line: int
    message: str

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.pass_name}] {self.message}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class PassResult:
    name: str
    family: str                       # "ast"|"contract"|"kernel"|"allocator"
    violations: List[Violation]
    stats: Dict[str, object]

    @property
    def ok(self) -> bool:
        return not self.violations

    def as_dict(self) -> dict:
        return {"name": self.name, "family": self.family,
                "status": "ok" if self.ok else "fail",
                "violations": [v.as_dict() for v in self.violations],
                "stats": self.stats}


def repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def ensure_importable(root: str) -> None:
    """Make ``repro`` (src layout) importable for the contract/kernel
    passes without requiring the caller to have exported PYTHONPATH."""
    src = os.path.join(root, "src")
    if src not in sys.path:
        sys.path.insert(0, src)


def iter_py_files(root: str, subdirs) -> List[str]:
    """All .py files under ``root/<subdir>`` for each subdir, skipping
    caches and the known-bad fixtures."""
    out = []
    for sub in subdirs:
        base = os.path.join(root, sub)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = sorted(d for d in dirnames if d not in SKIP_DIRS)
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(os.path.join(dirpath, fn))
    return out


def provenance(root: str) -> dict:
    info: dict = {}
    try:
        info["git_sha"] = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root, capture_output=True,
            text=True, timeout=10).stdout.strip()
    except Exception:
        info["git_sha"] = None
    try:
        import jax
        info["jax_version"] = jax.__version__
    except Exception:
        info["jax_version"] = None
    return info


def build_report(results: List[PassResult], root: str, *,
                 strict: bool) -> dict:
    n_viol = sum(len(r.violations) for r in results)
    report = {
        "tool": "repro-audit",
        "strict": strict,
        "provenance": provenance(root),
        "passes": [r.as_dict() for r in results],
        "summary": {
            "passes_total": len(results),
            "passes_ok": sum(r.ok for r in results),
            "passes_failed": sum(not r.ok for r in results),
            "violations": n_viol,
        },
    }
    alloc = next((r for r in results if r.family == "allocator"), None)
    if alloc is not None:
        # surfaced at top level so CI / tests can assert the state-count
        # coverage of the interleaving check without digging
        report["allocator_model"] = dict(alloc.stats)
    return report


def write_report(report: dict, path: str) -> None:
    with open(path, "w", encoding="utf-8") as f:
        json.dump(report, f, indent=2, sort_keys=False)
        f.write("\n")


def summary_line(report: dict) -> str:
    """One-line pass/fail summary (``benchmarks/run.py --quick`` prints
    this next to the perf rows)."""
    s = report["summary"]
    status = "ok" if s["passes_failed"] == 0 else "FAIL"
    return (f"audit,{status},passes={s['passes_ok']}/{s['passes_total']},"
            f"violations={s['violations']}")
