"""Pallas kernel checker (family ``kernel``).

Abstract-evals every registered kernel's grid + BlockSpec structure
without tracing on real data: ``pl.pallas_call`` is monkeypatched with a
recording stub (kernel bodies never run) and each representative case is
driven under ``jax.eval_shape``, so the checks see exactly the grid,
BlockSpecs, out_shapes and scratch shapes the real lowering would.

Per captured call, three proofs over every grid point:

  * index-map bounds   every BlockSpec index map stays inside
                       ``ceil(dim / block)`` for every grid index — a
                       map that walks off the array reads (or writes)
                       padding garbage.
  * disjoint writes    two grid points mapping to the SAME output block
                       may differ only in dims marked "arbitrary"
                       (sequential) in ``dimension_semantics``; differing
                       in a "parallel" dim is a grid-level write race.
  * VMEM footprint     per-step block + scratch bytes stay under a
                       configurable budget (default 16 MiB — one core).

Representative shapes use small blocks (128/256) so every kernel runs a
multi-block grid and the index maps are exercised off the origin.
"""
from __future__ import annotations

import functools
import itertools
import math
from typing import Dict, List, Optional

from tools.audit.framework import (DEFAULT_VMEM_BUDGET, PassResult,
                                   Violation, ensure_importable)


class Record:
    """One captured pallas_call: specs + shapes, no kernel execution."""

    def __init__(self, name, grid, in_specs, out_specs, out_shape,
                 scratch_shapes, compiler_params, operand_shapes):
        self.name = name
        self.grid = grid
        self.in_specs = in_specs
        self.out_specs = out_specs
        self.out_shape = out_shape
        self.scratch_shapes = scratch_shapes
        self.compiler_params = compiler_params
        self.operand_shapes = operand_shapes   # [(shape, dtype), ...]

    @property
    def semantics(self):
        cp = self.compiler_params
        sem = getattr(cp, "dimension_semantics", None) if cp is not None \
            else None
        if sem is None:
            sem = ("arbitrary",) * len(self.grid)   # TPU default: sequential
        return tuple(sem)


def _aslist(x):
    if x is None:
        return []
    return list(x) if isinstance(x, (list, tuple)) else [x]


class PallasCapture:
    """Monkeypatch ``pallas.pallas_call`` with a stub that records the
    call and returns a zeros tree of ``out_shape`` — kernel modules
    resolve ``pl.pallas_call`` at call time, so patching the module
    attribute intercepts every kernel."""

    def __init__(self):
        self.records: List[Record] = []
        self.case: str = "?"

    def __enter__(self):
        from jax.experimental import pallas as pl
        self._pl, self._orig = pl, pl.pallas_call
        cap = self

        def stub(kernel, *, grid=None, in_specs=None, out_specs=None,
                 out_shape=None, scratch_shapes=None, compiler_params=None,
                 interpret=False, **kw):
            def run(*operands):
                import jax.numpy as jnp
                cap.records.append(Record(
                    cap.case, tuple(grid) if grid is not None else (),
                    _aslist(in_specs), _aslist(out_specs),
                    _aslist(out_shape), _aslist(scratch_shapes),
                    compiler_params,
                    [(tuple(o.shape), o.dtype) for o in operands]))
                outs = [jnp.zeros(s.shape, s.dtype)
                        for s in _aslist(out_shape)]
                return outs if isinstance(out_shape, (list, tuple)) \
                    else outs[0]
            return run
        pl.pallas_call = stub
        return self

    def __exit__(self, *exc):
        self._pl.pallas_call = self._orig
        return False


def _grid_points(grid):
    return itertools.product(*(range(int(n)) for n in grid))


def _block_dims(block_shape):
    # a None entry is a squeezed dim of size 1
    return tuple(1 if b is None else int(b) for b in block_shape)


def _check_spec(rec: Record, spec, shape, kind: str, i: int,
                v: List[Violation]) -> Optional[Dict[tuple, list]]:
    """Bounds-check one BlockSpec against its array shape for every grid
    point; returns {block_index_tuple: [grid points]} for disjointness."""
    loc = f"kernel:{rec.name}"
    bs = getattr(spec, "block_shape", None)
    imap = getattr(spec, "index_map", None)
    if bs is None or imap is None:
        return None                       # SMEM / whole-array operand
    blk = _block_dims(bs)
    if len(blk) != len(shape):
        v.append(Violation("kernel-check", loc, 0,
                           f"{kind}[{i}]: block rank {len(blk)} != array "
                           f"rank {len(shape)} (shape {shape})"))
        return None
    nblk = tuple(max(1, math.ceil(d / b)) for d, b in zip(shape, blk))
    blocks: Dict[tuple, list] = {}
    for gp in _grid_points(rec.grid):
        try:
            idx = imap(*gp)
        except Exception as e:
            v.append(Violation("kernel-check", loc, 0,
                               f"{kind}[{i}]: index map raised {e!r} at "
                               f"grid point {gp}"))
            return None
        idx = tuple(int(x) for x in (idx if isinstance(idx, tuple)
                                     else (idx,)))
        if len(idx) != len(blk):
            v.append(Violation("kernel-check", loc, 0,
                               f"{kind}[{i}]: index map returns "
                               f"{len(idx)} indices for rank-{len(blk)} "
                               "blocks"))
            return None
        for d, (x, n) in enumerate(zip(idx, nblk)):
            if not 0 <= x < n:
                v.append(Violation(
                    "kernel-check", loc, 0,
                    f"{kind}[{i}]: index map out of bounds at grid point "
                    f"{gp}: dim {d} block index {x} outside [0, {n}) "
                    f"(shape {shape}, block {blk})"))
                return blocks
        blocks.setdefault(idx, []).append(gp)
    return blocks


def check_record(rec: Record, *, vmem_budget: int = DEFAULT_VMEM_BUDGET
                 ) -> List[Violation]:
    import numpy as np
    v: List[Violation] = []
    loc = f"kernel:{rec.name}"
    if any(int(n) <= 0 for n in rec.grid):
        v.append(Violation("kernel-check", loc, 0,
                           f"degenerate grid {rec.grid}"))
        return v

    # --- input index maps: in-bounds only -----------------------------
    n_ops = len(rec.operand_shapes)
    if rec.in_specs and len(rec.in_specs) != n_ops:
        v.append(Violation("kernel-check", loc, 0,
                           f"{len(rec.in_specs)} in_specs for {n_ops} "
                           "operands"))
    vmem = 0
    for i, (spec, (shape, dtype)) in enumerate(
            zip(rec.in_specs, rec.operand_shapes)):
        blocks = _check_spec(rec, spec, shape, "in", i, v)
        if blocks is not None:
            bs = _block_dims(spec.block_shape)
            vmem += int(np.prod(bs)) * np.dtype(dtype).itemsize

    # --- output index maps: in-bounds + write-disjointness -------------
    sem = rec.semantics
    for i, (spec, sd) in enumerate(zip(rec.out_specs, rec.out_shape)):
        shape = tuple(sd.shape)
        blocks = _check_spec(rec, spec, shape, "out", i, v)
        if blocks is None:
            continue
        bs = _block_dims(spec.block_shape)
        vmem += int(np.prod(bs)) * np.dtype(sd.dtype).itemsize
        for bidx, gps in blocks.items():
            if len(gps) < 2:
                continue
            first = gps[0]
            for gp in gps[1:]:
                racy = [d for d, (a, b) in enumerate(zip(first, gp))
                        if a != b and d < len(sem) and sem[d] == "parallel"]
                if racy:
                    v.append(Violation(
                        "kernel-check", loc, 0,
                        f"out[{i}]: grid points {first} and {gp} both "
                        f"write block {bidx} but differ in parallel grid "
                        f"dim(s) {racy} — write race (mark them "
                        "'arbitrary' or split the block)"))
                    break
            else:
                continue
            break

    # --- per-step VMEM footprint ---------------------------------------
    for s in rec.scratch_shapes:
        shape = getattr(s, "shape", None)
        dtype = getattr(s, "dtype", None)
        if shape is not None and dtype is not None:
            vmem += int(np.prod(shape)) * np.dtype(dtype).itemsize
    if vmem > vmem_budget:
        v.append(Violation(
            "kernel-check", loc, 0,
            f"per-step VMEM footprint {vmem} bytes exceeds budget "
            f"{vmem_budget} (blocks + scratch)"))
    return v


# ---------------------------------------------------------------------------
# representative cases — every KERNEL_OPS entry must appear here (or
# delegate to one that does)
# ---------------------------------------------------------------------------

B, S, C, L, HQ, HKV, D = 2, 512, 256, 1024, 4, 2, 64
POS0 = 256


def _cases():
    import jax.numpy as jnp
    from repro.kernels import (decode_attention as da,
                               flash_attention as fa,
                               flash_attention_bwd as fb,
                               rmsnorm as rn,
                               shared_rmsprop as sr)

    def z(shape, dt=jnp.bfloat16):
        return jnp.zeros(shape, dt)

    def kpos(n):
        return jnp.zeros((B, n), jnp.int32)

    q4, kv4 = z((B, S, HQ, D)), z((B, S, HKV, D))
    lse = z((B, HQ, S), jnp.float32)
    qc = z((B, C, HQ, D))
    qd, cache = z((B, HQ, D)), z((B, L, HKV, D))
    cache8, scale = z((B, L, HKV, D), jnp.int8), z((B, L, HKV, 1),
                                                   jnp.float32)
    k8, s8 = z((B, S, HKV, D), jnp.int8), z((B, S, HKV, 1), jnp.float32)
    pos = jnp.zeros((B,), jnp.int32)
    x2, sc2 = z((512, 512)), z((512,))
    g2 = z((512, 1024), jnp.float32)

    return {
        "flash_attention": [
            ("flash_fwd", lambda: fa.flash_attention_fwd(
                q4, kv4, kv4, causal=True, block_q=128, block_k=128,
                save_residuals=True, interpret=True)),
            ("flash_fwd_window", lambda: fa.flash_attention_fwd(
                q4, kv4, kv4, causal=True, window=256, block_q=256,
                block_k=128, interpret=True)),
            ("flash_bwd", lambda: fb.flash_attention_bwd(
                q4, kv4, kv4, q4, lse, q4, causal=True, block_q=128,
                block_k=128, interpret=True)),
        ],
        "flash_append": [
            ("append", lambda: fa.flash_attention_append(
                qc, kv4, kv4, kpos(S), pos0=POS0, block_q=128,
                block_k=128, interpret=True)),
            ("append_quant", lambda: fa.flash_attention_append(
                qc, k8, k8, kpos(S), pos0=POS0, block_q=128, block_k=128,
                k_scale=s8, v_scale=s8, interpret=True)),
        ],
        # speculative verify delegates to flash_append after re-basing
        # per-row depths to a static pos0 = cache_len: one q block of
        # drafted tokens against a deep prefix keystream.  The q-offset
        # index maps run far off the origin here (pos0 >> chunk), the
        # regime a bad offset map walks out of bounds in.
        "flash_verify": [
            ("verify_append", lambda: fa.flash_attention_append(
                z((B, 128, HQ, D)), z((B, L + 128, HKV, D)),
                z((B, L + 128, HKV, D)), kpos(L + 128), pos0=L,
                block_q=128, block_k=128, interpret=True)),
        ],
        "decode_attention": [
            ("decode_fwd", lambda: da.decode_attention_fwd(
                qd, cache, cache, kpos(L), pos, block_k=256,
                interpret=True)),
            ("decode_partials", lambda: da.decode_attention_partials(
                qd, cache, cache, kpos(L), pos, block_k=256,
                interpret=True)),
            ("decode_quant", lambda: da.decode_attention_fwd(
                qd, cache8, cache8, kpos(L), pos, block_k=256,
                k_scale=scale, v_scale=scale, interpret=True)),
        ],
        "rmsnorm": [
            ("rmsnorm_fwd", lambda: rn.rmsnorm_fwd(
                x2, sc2, block_rows=128, save_residuals=True,
                interpret=True)),
            ("rmsnorm_bwd", lambda: rn.rmsnorm_bwd(
                x2, sc2, z((512,), jnp.float32), x2, block_rows=128,
                interpret=True)),
        ],
        "rmsprop_update": [
            ("rmsprop_2d", lambda: sr.rmsprop_update_2d(
                g2, g2, jnp.float32(1e-3), block_rows=128,
                interpret=True)),
        ],
    }


def run_kernel_checks(root: str, *,
                      vmem_budget: int = DEFAULT_VMEM_BUDGET
                      ) -> List[PassResult]:
    ensure_importable(root)
    import jax
    from repro.kernels import dispatch

    cases = _cases()
    v: List[Violation] = []
    records: List[Record] = []
    with PallasCapture() as cap:
        for op, case_list in cases.items():
            for name, fn in case_list:
                cap.case = name
                before = len(cap.records)
                try:
                    jax.eval_shape(fn)
                except Exception as e:
                    v.append(Violation("kernel-check", f"kernel:{name}", 0,
                                       f"abstract eval failed: {e!r}"))
                    continue
                if len(cap.records) == before:
                    v.append(Violation(
                        "kernel-check", f"kernel:{name}", 0,
                        "case captured no pallas_call — kernel path not "
                        "exercised"))
        records = cap.records

    grid_points = 0
    for rec in records:
        grid_points += int(math.prod(int(n) for n in rec.grid)) \
            if rec.grid else 0
        v.extend(check_record(rec, vmem_budget=vmem_budget))

    # coverage: every registered op has cases, directly or via delegate
    covered = set(cases)
    for op, c in dispatch.KERNEL_OPS.items():
        if op in covered:
            continue
        if c.delegate is not None and c.delegate in covered:
            continue
        v.append(Violation("kernel-check", "tools/audit/kernel_check.py",
                           0, f"registered op '{op}' has no "
                           "representative case (and no covered "
                           "delegate)"))
    stats = {"cases": sum(len(c) for c in cases.values()),
             "pallas_calls": len(records),
             "grid_points_checked": grid_points,
             "vmem_budget": vmem_budget}
    return [PassResult("kernel-check", "kernel", v, stats)]
