#!/usr/bin/env python
"""Lint: fail if anything in the tree imports the deleted kernels.ops shim.

``repro.kernels.ops`` was a deprecation shim over ``repro.kernels.dispatch``
(PR 5); after one full cycle it is deleted.  This walks every tracked
Python file and flags any import of the old module so it cannot grow back:

    python tools/check_no_ops_import.py

Exit 0 when clean, 1 with a file:line listing otherwise.  Runs as a CI
step and from tests/test_kernels.py so it is also a tier-1 test.
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SELF = os.path.abspath(__file__)

# any spelling of the import: "import repro.kernels.ops",
# "from repro.kernels import ops", "from repro.kernels.ops import ...",
# "from .kernels import ops", "from . import ops" inside kernels/
PATTERNS = (
    re.compile(r"^\s*import\s+repro\.kernels\.ops\b"),
    re.compile(r"^\s*from\s+repro\.kernels\.ops\s+import\b"),
    re.compile(r"^\s*from\s+repro\.kernels\s+import\s+.*\bops\b"),
    re.compile(r"^\s*from\s+\.kernels\s+import\s+.*\bops\b"),
    re.compile(r"[\"']repro\.kernels\.ops[\"']"),
)
KERNELS_LOCAL = re.compile(r"^\s*from\s+\.\s+import\s+.*\bops\b")
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "results"}


def scan(path: str) -> list:
    hits = []
    in_kernels = os.sep + os.path.join("kernels", "") in path + os.sep \
        and os.path.basename(os.path.dirname(path)) == "kernels"
    with open(path, encoding="utf-8", errors="replace") as f:
        for ln, line in enumerate(f, 1):
            if "lint: allow-ops-ref" in line:
                continue          # e.g. the test asserting the import FAILS
            pats = PATTERNS + ((KERNELS_LOCAL,) if in_kernels else ())
            if any(p.search(line) for p in pats):
                hits.append((path, ln, line.rstrip()))
    return hits


def main() -> int:
    shim = os.path.join(ROOT, "src", "repro", "kernels", "ops.py")
    hits = []
    if os.path.exists(shim):
        hits.append((shim, 0, "shim file still exists"))
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for fn in filenames:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            if os.path.abspath(path) == SELF:
                continue
            hits += scan(path)
    if hits:
        print("kernels.ops is deleted; update these to "
              "repro.kernels.dispatch:")
        for path, ln, line in hits:
            print(f"  {os.path.relpath(path, ROOT)}:{ln}: {line}")
        return 1
    print("ok: no kernels.ops imports")
    return 0


if __name__ == "__main__":
    sys.exit(main())
